"""The op plugin API in action: fill-holes + connected-component labeling.

    PYTHONPATH=src python examples/fill_and_label.py

Both workloads reach every engine purely through the `repro.ops` registry
(DESIGN.md §2.4, docs/OPS.md) — `solve()` is called *by name* with the raw
image, the spec builds the state and extracts the result, and no engine
code knows either op exists.  Results are checked against the sequential
references (`repro/fill/ref.py`, `repro/label/ref.py`); scipy, when
installed, agrees with both (tests/test_fill_label.py).
"""

import numpy as np

from repro.fill.ref import fill_holes_bfs
from repro.label.ref import label_wavefront, relabel_sequential
from repro.ops import get_op, list_ops
from repro.solve import solve


def main():
    print(f"registered ops: {list_ops()}")
    rng = np.random.default_rng(0)

    # --- fill-holes: border-seeded reconstruction of the complement -------
    img = rng.random((128, 128)) < 0.45
    img[30:60, 30:60] = True          # a big object ...
    img[40:50, 40:50] = False         # ... with a guaranteed hole
    ref = fill_holes_bfs(img, connectivity=4)
    spec = get_op("fill_holes")
    op = spec.factory()
    for engine, kw in [("frontier", {}),
                       ("tiled", dict(tile=32, queue_capacity=16)),
                       ("hybrid", dict(tile=32, n_workers=2,
                                       n_device_workers=1))]:
        out, s = solve("fill_holes", img, engine=engine, **kw)
        filled = np.asarray(spec.extract(op, out))
        assert np.array_equal(filled, ref)
        print(f"fill_holes / {engine:9s}: holes filled="
              f"{int(filled.sum() - img.sum()):4d} rounds={s.rounds} "
              f"tile_drains={s.tiles_processed} — matches BFS ref")

    # --- labeling: monotone max-label flood fill --------------------------
    fg = rng.random((128, 128)) < 0.55
    ref_lab = label_wavefront(fg, connectivity=8)
    lspec = get_op("label")
    lop = lspec.factory()
    for engine, kw in [("frontier", {}),
                       ("tiled-pallas", dict(tile=32, queue_capacity=16))]:
        out, s = solve("label", fg, engine=engine, **kw)
        lab = np.asarray(lspec.extract(lop, out))
        assert np.array_equal(lab, ref_lab)
        n = len(np.unique(lab[lab > 0]))
        print(f"label      / {engine:12s}: {n} components, rounds={s.rounds} "
              f"tile_drains={s.tiles_processed} — matches wavefront ref")
    compact = relabel_sequential(ref_lab)
    print(f"labels compacted to 1..{compact.max()}")
    print("OK")


if __name__ == "__main__":
    main()
