"""Quickstart: the IWPP core API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic tissue image, runs morphological reconstruction and the
euclidean distance transform through three IWPP engines (dense frontier,
tiled active-set, Pallas-kernel tiles), and checks them against the paper's
sequential algorithms.
"""

import jax.numpy as jnp
import numpy as np

from repro.core.frontier import run_dense
from repro.core.tiles import run_tiled
from repro.data.images import bg_disks, seeded_marker, tissue_image
from repro.edt.ops import EdtOp, distance_map
from repro.edt.ref import edt_wavefront
from repro.kernels.ops import tile_solver_morph
from repro.morph.ops import MorphReconstructOp
from repro.morph.ref import reconstruct_fh


def main():
    # --- morphological reconstruction (paper Algorithm 2 / 5) -------------
    _, mask = tissue_image(256, 256, coverage=0.8, seed=0)
    marker = seeded_marker(mask, n_seeds=12, seed=0)
    ref = reconstruct_fh(marker.copy(), mask, connectivity=8)

    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)))

    out, stats = run_dense(op, state, "frontier")
    assert np.array_equal(np.asarray(out["J"]), ref.astype(np.int32))
    print(f"morph / dense frontier : {int(stats.rounds)} rounds, "
          f"{int(stats.sources_processed)} queued sources — matches FH ref")

    out, tstats = run_tiled(op, state, tile=64, queue_capacity=16)
    assert np.array_equal(np.asarray(out["J"]), ref.astype(np.int32))
    print(f"morph / tiled queue    : {int(tstats.outer_rounds)} outer rounds, "
          f"{int(tstats.tiles_processed)} tile drains — matches FH ref")

    out, _ = run_tiled(op, state, tile=64, queue_capacity=16,
                       tile_solver=tile_solver_morph(8, interpret=True))
    assert np.array_equal(np.asarray(out["J"]), ref.astype(np.int32))
    print("morph / Pallas kernel  : interpret-mode tile drain — matches FH ref")

    # --- euclidean distance transform (paper Algorithm 3 / 6) -------------
    fg = bg_disks(256, 256, coverage=0.9, n_disks=3, seed=1)
    ref_M, _ = edt_wavefront(fg, connectivity=8)
    eop = EdtOp(connectivity=8)
    est = eop.make_state(jnp.asarray(fg))
    out, stats = run_dense(eop, est, "frontier")
    M = np.asarray(distance_map(out))
    assert np.array_equal(M, ref_M)
    print(f"edt   / dense frontier : {int(stats.rounds)} rounds, max dist "
          f"{np.sqrt(M.max()):.1f}px — matches Algorithm 3 ref")
    print("OK")


if __name__ == "__main__":
    main()
