"""Quickstart: the IWPP `solve()` API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic tissue image, runs morphological reconstruction and the
euclidean distance transform through the unified ``solve()`` dispatcher —
named engines plus cost-model ``engine="auto"`` — and checks every result
against the paper's sequential algorithms.  README.md has the engine
matrix, docs/ENGINES.md the per-engine reference; DESIGN.md §4 the
dispatch architecture.
"""

import jax.numpy as jnp
import numpy as np

from repro.data.images import bg_disks, seeded_marker, tissue_image
from repro.edt.ops import EdtOp, distance_map
from repro.edt.ref import edt_wavefront
from repro.morph.ops import MorphReconstructOp
from repro.morph.ref import reconstruct_fh
from repro.solve import solve


def main():
    # --- morphological reconstruction (paper Algorithm 2 / 5) -------------
    _, mask = tissue_image(256, 256, coverage=0.8, seed=0)
    marker = seeded_marker(mask, n_seeds=12, seed=0)
    ref = reconstruct_fh(marker.copy(), mask, connectivity=8).astype(np.int32)

    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)))

    for engine, kw in [("frontier", {}),
                       ("tiled", dict(tile=64, queue_capacity=16)),
                       ("tiled-pallas", dict(tile=64, queue_capacity=16)),
                       ("scheduler", dict(tile=64, n_workers=2)),
                       # the paper's cooperative CPU+device pool: host
                       # threads + a batched device drain stream on ONE
                       # demand-driven queue (DESIGN.md §2.3)
                       ("hybrid", dict(tile=64, n_workers=2,
                                       n_device_workers=1))]:
        out, s = solve(op, state, engine=engine, **kw)
        assert np.array_equal(np.asarray(out["J"]), ref)
        print(f"morph / {engine:13s}: rounds={s.rounds} "
              f"sources={s.sources_processed} tile_drains={s.tiles_processed} "
              f"overflows={s.overflow_events} — matches FH ref")

    # engine="auto": the cost model sees sparse seeds -> tiled hierarchy.
    out, s = solve(op, state, engine="auto")
    assert np.array_equal(np.asarray(out["J"]), ref)
    print(f"morph / auto         -> picked {s.engine!r} (tile={s.tile}, "
          f"predicted cost {s.predicted_cost:.0f}) — matches FH ref")

    # --- euclidean distance transform (paper Algorithm 3 / 6) -------------
    fg = bg_disks(256, 256, coverage=0.9, n_disks=3, seed=1)
    ref_M, _ = edt_wavefront(fg, connectivity=8)
    eop = EdtOp(connectivity=8)
    est = eop.make_state(jnp.asarray(fg))
    for engine in ("frontier", "auto"):
        out, s = solve(eop, est, engine=engine)
        M = np.asarray(distance_map(out))
        assert np.array_equal(M, ref_M)
        print(f"edt   / {engine:13s}: ran {s.engine!r}, rounds={s.rounds}, "
              f"max dist {np.sqrt(M.max()):.1f}px — matches Algorithm 3 ref")
    print("OK")


if __name__ == "__main__":
    main()
