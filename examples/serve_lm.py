"""Batched serving demo: continuous-batching engine over a small model.

    PYTHONPATH=src python examples/serve_lm.py [--arch xlstm-350m] [--n 6]

Submits more requests than slots; the engine prefillsinto free slots,
decodes all active slots in one batched step, and recycles slots as
requests finish.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import smoke_config
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--n", type=int, default=6, help="number of requests")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, n_slots=args.slots, max_len=128)

    rng = np.random.default_rng(0)
    pending = [Request(rid=i, prompt=rng.integers(2, cfg.vocab_size, 8)
                       .astype(np.int32), max_new=args.max_new)
               for i in range(args.n)]
    done = []
    t0 = time.perf_counter()
    steps = 0
    while pending or eng.active:
        while pending and eng.add_request(pending[0]):
            print(f"[serve] admitted request {pending[0].rid} "
                  f"(slots busy: {len(eng.active)}/{args.slots})")
            pending.pop(0)
        done.extend(eng.step())
        steps += 1
        for r in [d for d in done if d.out is not None][len(done) - 1:]:
            pass
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in done)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"[serve] req {r.rid}: {len(r.out)} tokens -> {r.out[:8]}...")
    print(f"[serve] {len(done)} requests, {tokens} tokens in {dt:.1f}s "
          f"({steps} engine steps, {tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
