"""End-to-end training driver: a ~100M-parameter LM on the synthetic
pipeline, with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --steps 30          # quick
    PYTHONPATH=src python examples/train_lm.py --steps 300         # full

The config is a gemma2-family block at ~100M params (8 layers, d=768,
tied 32k vocab).  On a laptop-class CPU a step is a few seconds; on real
accelerators point --arch at any registry config and launch via
repro.launch.train with a mesh.
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config
from repro.ckpt.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.data.pipeline import DataIterator
from repro.models.counting import param_count
from repro.models.transformer import init_params
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step


def config_100m():
    base = get_config("gemma2-27b")
    return dataclasses.replace(
        base, name="gemma2-100m", n_layers=8, d_model=768, n_heads=8,
        n_kv_heads=4, head_dim=96, d_ff=2048, vocab_size=32768,
        attn_scale=(768 / 8) ** -0.5, window_pattern=(512, -1),
        train_microbatches=1, remat="none")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args(argv)

    cfg = config_100m()
    print(f"[train_lm] {cfg.name}: {param_count(cfg) / 1e6:.1f}M params")
    shape = ShapeSpec("ex", args.seq, args.batch, "train")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start, st, _ = restore(args.ckpt_dir,
                               like={"params": params, "opt": opt})
        params, opt = st["params"], st["opt"]
        print(f"[train_lm] restored step {start}")
    step_fn = jax.jit(make_train_step(
        cfg, OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)))
    it = DataIterator(cfg, shape, start_step=start)
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None

    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step_fn(params, opt, batch)
        if step == start or (step + 1) % 10 == 0:
            print(f"[train_lm] step {step + 1:4d} loss {float(m['loss']):.4f} "
                  f"({(time.perf_counter() - t0):.0f}s)")
        if ckpt and (step + 1) % 50 == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt})
    if ckpt:
        ckpt.wait()
    print("[train_lm] done")


if __name__ == "__main__":
    main()
