"""The paper's motivating pipeline, end to end: whole-slide-style image ->
IWPP operators -> patch features for a multimodal model.

    PYTHONPATH=src python examples/segmentation_pipeline.py

Stages (paper §1: segmentation substages built on these low-level ops):
  1. synthetic tissue tile (marker/mask pair);
  2. morphological reconstruction (tiled IWPP engine) — h-dome/noise
     suppression, the paper's reconstruction-from-markers;
  3. euclidean distance transform of the cleaned foreground (IWPP) —
     the watershed-separation substrate;
  4. local-maxima object markers from the distance map;
  5. patch embeddings + M-RoPE position grid for the qwen2-vl-2b backbone
     (its vision frontend is a stub per the assignment — the IWPP stages
     here play the role of the preprocessing that feeds it), and one
     forward pass of the reduced backbone over those patches.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeSpec
from repro.configs.registry import smoke_config
from repro.data.images import tissue_image
from repro.edt.ops import EdtOp, distance_map
from repro.models.transformer import forward, init_params
from repro.morph.ops import MorphReconstructOp
from repro.solve import solve


def main():
    H = W = 256
    marker, mask = tissue_image(H, W, coverage=0.7, seed=3)
    print(f"[1] tissue tile {H}x{W}, fg={100 * (mask > 0).mean():.0f}%")

    # 2. reconstruction: fills domes from the (mask - h) marker; the
    #    difference mask - recon is the h-dome (bright object) map.
    op = MorphReconstructOp(connectivity=8)
    st = op.make_state(jnp.asarray(marker.astype(np.int32)),
                       jnp.asarray(mask.astype(np.int32)))
    out, stats = solve(op, st, engine="auto")
    recon = np.asarray(out["J"])
    domes = mask.astype(np.int32) - recon
    print(f"[2] reconstruction via {stats.engine!r}: rounds={stats.rounds}, "
          f"tile drains={stats.tiles_processed}; "
          f"h-dome pixels: {(domes > 5).sum()}")

    # 3. EDT on the cleaned foreground
    fg = jnp.asarray(domes > 5)
    eop = EdtOp(connectivity=8)
    eout, estats = solve(eop, eop.make_state(~fg), engine="auto")
    dist = np.sqrt(np.asarray(distance_map(eout), np.float64))
    print(f"[3] EDT via {estats.engine!r}: max interior distance "
          f"{dist.max():.1f}px")

    # 4. object markers = local maxima of the distance map (3x3)
    pad = np.pad(dist, 1, constant_values=-1)
    nb = np.stack([pad[1 + dr:H + 1 + dr, 1 + dc:W + 1 + dc]
                   for dr in (-1, 0, 1) for dc in (-1, 0, 1)
                   if (dr, dc) != (0, 0)])
    peaks = (dist > 1.0) & (dist >= nb.max(axis=0))
    print(f"[4] watershed markers: {int(peaks.sum())} object seeds")

    # 5. patchify -> embeddings for the VLM backbone stub
    cfg = smoke_config("qwen2-vl-2b")
    P = 16
    patches = dist.reshape(H // P, P, W // P, P).mean(axis=(1, 3))
    n_patch = patches.size
    feats = np.zeros((1, n_patch, cfg.d_model), np.float32)
    feats[0, :, 0] = patches.reshape(-1) / max(patches.max(), 1e-6)
    feats[0, :, 1] = peaks.reshape(H // P, P, W // P, P).sum(axis=(1, 3)) \
                          .reshape(-1)
    t = np.zeros(n_patch, np.int32)
    hh, ww = np.mgrid[0:H // P, 0:W // P].astype(np.int32)
    pos = np.stack([np.broadcast_to(t, (1, n_patch)),
                    hh.reshape(1, -1), ww.reshape(1, -1)])
    params = init_params(cfg, jax.random.PRNGKey(0))
    hidden, _ = forward(params, cfg, {"embeds": jnp.asarray(feats),
                                      "positions": jnp.asarray(pos)})
    print(f"[5] qwen2-vl backbone over {n_patch} patch embeddings -> "
          f"hidden {tuple(hidden.shape)}, finite="
          f"{bool(jnp.isfinite(hidden.astype(jnp.float32)).all())}")
    print("OK")


if __name__ == "__main__":
    main()
