"""Sequential references for the euclidean distance transform (EDT).

* ``edt_bruteforce`` — exact EDT by exhaustive nearest-background search.
  O(N_fg * N_bg); only for tiny test images.  Used to bound the
  approximation error of the neighborhood algorithm (paper Fig. 3 shows the
  8-neighborhood Danielsson scheme is not exact but tightly bounded).
* ``edt_wavefront`` — the paper's Algorithm 3: queue-based Danielsson
  propagation of Voronoi pointers.  This is the semantics every parallel
  engine must reproduce (identical *distance map*; the Voronoi diagram may
  differ on ties, paper §3.4).

Convention: the input is a boolean image, True = foreground.  Distances are
from each pixel to the nearest background (False) pixel; background pixels
have distance 0.  Images with no background pixel get the far-sentinel
distance everywhere.
"""

from __future__ import annotations

from collections import deque

import numpy as np

from repro.morph.ref import N4, N8

# Far sentinel: coordinates such that any in-image pixel is closer to any
# other in-image pixel than to the sentinel.  Grids must be < 8192 px so
# squared distances stay within int32 (2*(8192+16384)^2 < 2^31).
SENTINEL = -16384
MAX_GRID = 8192


def _check(shape):
    if max(shape) > MAX_GRID:
        raise ValueError(f"grid {shape} exceeds MAX_GRID={MAX_GRID} (int32 dist overflow)")


def edt_bruteforce(fg: np.ndarray) -> np.ndarray:
    """Exact squared EDT, O(N^2).  Tiny images only."""
    _check(fg.shape)
    H, W = fg.shape
    bg = np.argwhere(~fg)
    out = np.zeros((H, W), dtype=np.int64)
    if len(bg) == 0:
        out[:] = 2 * (SENTINEL - MAX_GRID) ** 2
        return out
    rr, cc = np.mgrid[0:H, 0:W]
    for r in range(H):
        d = (bg[:, 0][None, :] - r) ** 2 + (bg[:, 1][None, :] - cc[r][:, None]) ** 2
        out[r] = d.min(axis=1)
    return out


def edt_wavefront(fg: np.ndarray, connectivity: int = 8):
    """Paper Algorithm 3.  Returns (squared distance map, VR pointer array).

    VR[r, c] = (row, col) of the currently nearest background pixel.
    """
    _check(fg.shape)
    nbrs = N8 if connectivity == 8 else N4
    H, W = fg.shape
    VR = np.empty((H, W, 2), dtype=np.int32)
    VR[..., 0], VR[..., 1] = np.mgrid[0:H, 0:W]
    VR[fg] = (SENTINEL, SENTINEL)

    def dist2(r, c, v):
        return (r - int(v[0])) ** 2 + (c - int(v[1])) ** 2

    # Initialization: background pixels adjacent to a foreground pixel.
    q: deque = deque()
    for r in range(H):
        for c in range(W):
            if not fg[r, c]:
                for dr, dc in nbrs:
                    rr, cc = r + dr, c + dc
                    if 0 <= rr < H and 0 <= cc < W and fg[rr, cc]:
                        q.append((r, c))
                        break

    # Wavefront propagation.
    while q:
        r, c = q.popleft()
        vp = VR[r, c]
        for dr, dc in nbrs:
            rr, cc = r + dr, c + dc
            if 0 <= rr < H and 0 <= cc < W:
                if dist2(rr, cc, vp) < dist2(rr, cc, VR[rr, cc]):
                    VR[rr, cc] = vp
                    q.append((rr, cc))

    rgrid, cgrid = np.mgrid[0:H, 0:W]
    M = (rgrid - VR[..., 0].astype(np.int64)) ** 2 + (cgrid - VR[..., 1].astype(np.int64)) ** 2
    return M, VR
