"""Euclidean distance transform as an IWPP `PropagationOp` (paper Alg. 3/6).

State pytree: {"vr": (2, H, W) int32 Voronoi pointers, "valid": bool (H, W)}.
vr[0] = row, vr[1] = col of the currently-nearest background pixel; the far
sentinel marks "no background known yet".

The per-round update replaces Algorithm 6's atomicCAS retry loop: each pixel
q min-reduces the candidate distances offered by all frontier neighbors in
one vector expression, so the read-modify-write race the GPU handles with
CAS cannot occur (DESIGN.md §2).  The update is commutative and monotone
(distance only decreases), satisfying the IWPP contract; the converged
distance map equals the sequential reference (ties in VR may resolve
differently — paper §3.4's argument).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.pattern import PropagationOp, shift2d
from repro.edt.ref import SENTINEL


def _grids(H, W):
    r = jax.lax.broadcasted_iota(jnp.int32, (H, W), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (H, W), 1)
    return r, c


@dataclasses.dataclass(frozen=True)
class EdtOp(PropagationOp):
    """Danielsson-style Voronoi-pointer propagation."""

    @property
    def static_leaves(self):
        return ("valid", "row", "col")

    def make_state(self, fg: jnp.ndarray, valid=None):
        """fg: bool (H, W), True = foreground.

        Coordinate grids are *state leaves* (not regenerated per-round) so
        that tiled/sharded engines, which see local blocks, still compute
        distances in global coordinates.
        """
        H, W = fg.shape
        r, c = _grids(H, W)
        s = jnp.int32(SENTINEL)
        if valid is None:
            valid = jnp.ones((H, W), dtype=bool)
        # Invalid cells start (and stay — see round()) at the sentinel: a
        # non-valid background pixel must never offer distance 0.
        bg = ~fg & valid
        vr = jnp.stack([jnp.where(bg, r, s), jnp.where(bg, c, s)])
        return {"vr": vr, "valid": valid, "row": r, "col": c}

    def pad_value(self, state):
        return {"vr": jnp.int32(SENTINEL), "valid": False,
                "row": jnp.int32(SENTINEL), "col": jnp.int32(SENTINEL)}

    def init_frontier(self, state) -> jnp.ndarray:
        """Background pixels with >=1 foreground neighbor (Alg. 3 lines 4-5)."""
        vr = state["vr"]
        r, c = state["row"], state["col"]
        H, W = vr.shape[-2:]
        is_bg = (vr[0] == r) & (vr[1] == c)
        s = jnp.int32(SENTINEL)
        any_fg_nbr = jnp.zeros((H, W), dtype=bool)
        for dr, dc in self.offsets:
            nbr_r = shift2d(vr[0], dr, dc, s)
            # out-of-image neighbors (fill==SENTINEL) look like fg; exclude
            # them by also requiring the neighbor be in-bounds via valid.
            nbr_valid = shift2d(state["valid"], dr, dc, False)
            any_fg_nbr = any_fg_nbr | ((nbr_r == s) & nbr_valid)
        return is_bg & any_fg_nbr & state["valid"]

    def _dist2(self, r, c, vr_r, vr_c):
        dr = r - vr_r
        dc = c - vr_c
        return dr * dr + dc * dc

    def round(self, state, frontier) -> Tuple[dict, jnp.ndarray]:
        vr = state["vr"]
        r, c = state["row"], state["col"]
        s = jnp.int32(SENTINEL)
        best_r, best_c = vr[0], vr[1]
        best_d = self._dist2(r, c, best_r, best_c)
        src_r = jnp.where(frontier, vr[0], s)
        src_c = jnp.where(frontier, vr[1], s)
        for dr, dc in self.offsets:
            cand_r = shift2d(src_r, dr, dc, s)
            cand_c = shift2d(src_c, dr, dc, s)
            cand_d = self._dist2(r, c, cand_r, cand_c)
            upd = cand_d < best_d
            best_r = jnp.where(upd, cand_r, best_r)
            best_c = jnp.where(upd, cand_c, best_c)
            best_d = jnp.where(upd, cand_d, best_d)
        changed = ((best_r != vr[0]) | (best_c != vr[1])) & state["valid"]
        # Non-valid cells keep sentinel pointers so they can never propagate.
        best_r = jnp.where(state["valid"], best_r, s)
        best_c = jnp.where(state["valid"], best_c, s)
        new_state = dict(state)
        new_state["vr"] = jnp.stack([best_r, best_c])
        return new_state, changed


def edt(fg, *, connectivity: int = 8, engine: str = "auto", **solve_kw):
    """One-call squared EDT through the solve() dispatcher.

    ``fg``: bool (H, W), True = foreground; distances are to the nearest
    background pixel.  Returns (squared distance map, SolveStats); see
    repro.solve.ENGINES for the engine names.  Thin registry-backed
    wrapper over the ``"edt"`` :class:`~repro.ops.OpSpec`.
    """
    from repro.ops import run_op
    return run_op("edt", jnp.asarray(fg), connectivity=connectivity,
                  engine=engine, **solve_kw)


def distance_map(state) -> jnp.ndarray:
    """Squared distance map from the converged Voronoi pointers (Alg. 3 l.13)."""
    vr = state["vr"]
    r, c = state["row"], state["col"]
    dr = r - vr[0]
    dc = c - vr[1]
    return dr * dr + dc * dc
