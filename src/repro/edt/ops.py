"""Euclidean distance transform as an IWPP `PropagationOp` (paper Alg. 3/6).

State pytree: {"vr": (2, H, W) int32 Voronoi pointers, "valid": bool (H, W)}.
vr[0] = row, vr[1] = col of the currently-nearest background pixel; the far
sentinel marks "no background known yet".

The per-round update replaces Algorithm 6's atomicCAS retry loop: each pixel
q min-reduces the candidate distances offered by all frontier neighbors in
one vector expression, so the read-modify-write race the GPU handles with
CAS cannot occur (DESIGN.md §2).  The update is commutative and monotone
(distance only decreases), satisfying the IWPP contract; the converged
distance map equals the sequential reference (ties in VR may resolve
differently — paper §3.4's argument).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.pattern import PropagationOp, shiftnd
from repro.edt.ref import SENTINEL

# Coordinate state-leaf names per spatial rank: the trailing two axes keep
# their historical names so 2D states are byte-identical pytrees; 3D adds
# the depth plane in front (vr component order == leaf order == axis order).
COORD_LEAVES = {2: ("row", "col"), 3: ("dep", "row", "col")}


def _grids(shape):
    """One int32 coordinate plane per spatial axis (broadcasted_iota — 1-D
    iota does not lower on TPU)."""
    return tuple(jax.lax.broadcasted_iota(jnp.int32, tuple(shape), a)
                 for a in range(len(shape)))


@dataclasses.dataclass(frozen=True)
class EdtOp(PropagationOp):
    """Danielsson-style Voronoi-pointer propagation (2D images or 3D
    volumes — the rank follows the connectivity name, DESIGN.md §2.7)."""

    @property
    def coord_leaves(self):
        return COORD_LEAVES[self.ndim]

    @property
    def static_leaves(self):
        return ("valid",) + self.coord_leaves

    def make_state(self, fg: jnp.ndarray, valid=None):
        """fg: bool over the spatial grid, True = foreground.

        Coordinate grids are *state leaves* (not regenerated per-round) so
        that tiled/sharded engines, which see local blocks, still compute
        distances in global coordinates.
        """
        if fg.ndim != self.ndim:
            raise ValueError(
                f"EdtOp(connectivity={self.connectivity!r}) is "
                f"{self.ndim}-D but fg has rank {fg.ndim}")
        coords = _grids(fg.shape)
        s = jnp.int32(SENTINEL)
        if valid is None:
            valid = jnp.ones(fg.shape, dtype=bool)
        # Invalid cells start (and stay — see round()) at the sentinel: a
        # non-valid background pixel must never offer distance 0.
        bg = ~fg & valid
        vr = jnp.stack([jnp.where(bg, g, s) for g in coords])
        state = {"vr": vr, "valid": valid}
        state.update(zip(self.coord_leaves, coords))
        return state

    def pad_value(self, state):
        pv = {"vr": jnp.int32(SENTINEL), "valid": False}
        pv.update((k, jnp.int32(SENTINEL)) for k in self.coord_leaves)
        return pv

    def init_frontier(self, state) -> jnp.ndarray:
        """Background pixels with >=1 foreground neighbor (Alg. 3 lines 4-5)."""
        vr = state["vr"]
        coords = [state[k] for k in self.coord_leaves]
        is_bg = jnp.ones(vr.shape[1:], dtype=bool)
        for i, g in enumerate(coords):
            is_bg = is_bg & (vr[i] == g)
        s = jnp.int32(SENTINEL)
        any_fg_nbr = jnp.zeros(vr.shape[1:], dtype=bool)
        for off in self.offsets:
            nbr_0 = shiftnd(vr[0], off, s)
            # out-of-image neighbors (fill==SENTINEL) look like fg; exclude
            # them by also requiring the neighbor be in-bounds via valid.
            nbr_valid = shiftnd(state["valid"], off, False)
            any_fg_nbr = any_fg_nbr | ((nbr_0 == s) & nbr_valid)
        return is_bg & any_fg_nbr & state["valid"]

    def _dist2(self, coords, ptrs):
        d = None
        for g, p in zip(coords, ptrs):
            dd = g - p
            d = dd * dd if d is None else d + dd * dd
        return d

    def round(self, state, frontier) -> Tuple[dict, jnp.ndarray]:
        vr = state["vr"]
        coords = [state[k] for k in self.coord_leaves]
        s = jnp.int32(SENTINEL)
        best = [vr[i] for i in range(self.ndim)]
        best_d = self._dist2(coords, best)
        src = [jnp.where(frontier, vr[i], s) for i in range(self.ndim)]
        for off in self.offsets:
            cand = [shiftnd(p, off, s) for p in src]
            cand_d = self._dist2(coords, cand)
            upd = cand_d < best_d
            best = [jnp.where(upd, cp, bp) for cp, bp in zip(cand, best)]
            best_d = jnp.where(upd, cand_d, best_d)
        changed = jnp.zeros(frontier.shape, dtype=bool)
        for i in range(self.ndim):
            changed = changed | (best[i] != vr[i])
        changed = changed & state["valid"]
        # Non-valid cells keep sentinel pointers so they can never propagate.
        best = [jnp.where(state["valid"], bp, s) for bp in best]
        new_state = dict(state)
        new_state["vr"] = jnp.stack(best)
        return new_state, changed


def edt(fg, *, connectivity: int = 8, engine: str = "auto", **solve_kw):
    """One-call squared EDT through the solve() dispatcher.

    ``fg``: bool (H, W), True = foreground; distances are to the nearest
    background pixel.  Returns (squared distance map, SolveStats); see
    repro.solve.ENGINES for the engine names.  Thin registry-backed
    wrapper over the ``"edt"`` :class:`~repro.ops.OpSpec`.
    """
    from repro.ops import run_op
    return run_op("edt", jnp.asarray(fg), connectivity=connectivity,
                  engine=engine, **solve_kw)


def distance_map(state) -> jnp.ndarray:
    """Squared distance map from the converged Voronoi pointers (Alg. 3 l.13)."""
    vr = state["vr"]
    leaves = COORD_LEAVES[vr.shape[0]]
    d2 = None
    for axis, leaf in enumerate(leaves):
        d = state[leaf] - vr[axis]
        d2 = d * d if d2 is None else d2 + d * d
    return d2
