"""gemma2-27b [dense] — [arXiv:2408.00118; hf].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.  Alternating
local(4096)/global attention, attn logit softcap 50, final softcap 30,
pre+post sandwich norms, GeGLU, embeddings scaled by sqrt(d), tied head,
query scale (d_model/n_heads)^-1/2 = 144^-1/2.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    mlp="geglu",
    rope_theta=1e4,
    tie_embeddings=True,
    embed_scale=True,
    post_norm=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    attn_scale=(4608 / 32) ** -0.5,
    window_pattern=(4096, -1),
    norm_eps=1e-6,
    train_microbatches=4,
    source="arXiv:2408.00118; hf:google/gemma-2-27b",
)
