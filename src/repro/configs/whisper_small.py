"""whisper-small [audio] — encoder-decoder [arXiv:2212.04356; unverified].

12L decoder (+12L encoder) d_model=768 12H d_ff=3072 vocab=51865.  The
conv frontend is a STUB per the assignment: `input_specs` provides 1500
precomputed frame embeddings (B, 1500, 768).  Absolute positions
(sinusoidal encoder / learned decoder), no RoPE.  # ASSUMED: RMSNorm
without bias in place of LayerNorm+bias; learned decoder positions
extended to 32768 for the synthetic decode_32k cell.
"""

from repro.configs.base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    mlp="gelu",
    tie_embeddings=True,
    encdec=EncDecConfig(n_enc_layers=12, n_frames=1500),
    embed_inputs="tokens",
    source="arXiv:2212.04356; hf:openai/whisper-small",
)
