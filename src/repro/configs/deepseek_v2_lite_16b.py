"""deepseek-v2-lite-16b [moe] — MLA + fine-grained MoE [arXiv:2405.04434; hf].

27L d_model=2048 16H vocab=102400.  MLA: kv_lora_rank=512, qk_nope=128,
qk_rope=64, v=128, no q-lora (lite).  MoE: 64 routed experts top-6 +
2 shared experts, expert d_ff=1408; layer 0 is dense (d_ff=10944, hf
config).  The assignment's bracket text mentions "160 routed" (full V2);
the inline spec "64e top-6" matches V2-Lite and is what we build.
"""

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=192,                     # qk_nope + qk_rope (MLA path)
    d_ff=10944,                       # dense layer 0 (hf config)
    vocab_size=102400,
    mlp="silu",
    rope_theta=1e4,
    mla=MLAConfig(q_lora_rank=None, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  capacity_factor=1.25),
    moe_layer_start=1,
    train_microbatches=4,
    source="arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2-Lite",
)
