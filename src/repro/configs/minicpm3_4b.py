"""minicpm3-4b [dense] — MLA attention [hf:openbmb/MiniCPM3-4B; hf].

62L d_model=2560 40H d_ff=6400 vocab=73448.  Multi-head Latent Attention:
q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head_dim=64
(hf config).  # ASSUMED: mup-style embedding/depth scaling factors of the
original are folded into init and omitted from layer math.
"""

from repro.configs.base import ArchConfig, MLAConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=96,                      # qk_nope + qk_rope (derived; MLA path)
    d_ff=6400,
    vocab_size=73448,
    mlp="silu",
    rope_theta=1e4,
    tie_embeddings=True,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64),
    train_microbatches=4,
    source="hf:openbmb/MiniCPM3-4B",
)
