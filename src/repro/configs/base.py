"""Architecture config system.

One `ArchConfig` describes every assigned architecture (10 archs from the
public pool, see configs/<id>.py) plus reduced smoke variants.  All fields
that alter layer math are explicit; anything uncertain in the public record
is marked `# ASSUMED` in the arch file.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (deepseek-v2 / minicpm3)."""
    q_lora_rank: Optional[int]   # None -> direct q projection
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # shared (always-on) experts, deepseek-style
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    normalize_topk: bool = True   # renormalize top-k router probs


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin recurrent block."""
    lru_width: int
    conv_width: int = 4
    c_exponent: float = 8.0


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    proj_factor_m: float = 2.0       # mLSTM block up-projection
    conv_width: int = 4
    ffn_factor_s: float = 4.0 / 3.0  # FFN after sLSTM blocks


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder."""
    n_enc_layers: int
    n_frames: int = 1500          # stub frontend output length (30 s clip)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    mlp: str = "silu"             # silu | gelu | geglu | sqrelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    embed_scale: bool = False     # gemma: multiply embeddings by sqrt(d)
    post_norm: bool = False       # gemma2 pre+post sandwich norms
    parallel_block: bool = False  # command-r style parallel attn+FFN

    rope_theta: float = 1e4
    mrope_sections: Optional[Tuple[int, int, int]] = None   # qwen2-vl
    attn_logit_softcap: Optional[float] = None               # gemma2
    final_logit_softcap: Optional[float] = None
    attn_scale: Optional[float] = None    # override head_dim**-0.5
    # per-layer sliding window; -1 = global.  None -> all global.
    window_pattern: Optional[Tuple[int, ...]] = None

    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    moe_layer_start: int = 0      # deepseek: first k layers dense
    dense_ff_residual: bool = False  # arctic: dense FFN in parallel with MoE

    # heterogeneous stacks: per-layer block kind, e.g. ("rec","rec","attn")
    block_pattern: Optional[Tuple[str, ...]] = None
    rglru: Optional[RGLRUConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    encdec: Optional[EncDecConfig] = None
    embed_inputs: str = "tokens"  # tokens | embeds (vlm stub) | frames (audio stub)

    # infra
    scan_layers: bool = True
    remat: str = "full"           # none | full | dots
    train_microbatches: int = 1   # grad-accum splits of the global batch
    fsdp: bool = False            # shard params (+opt) over the data axis
    seq_shard_residual: bool = False  # megatron-SP style residual sharding
    vocab_pad_multiple: int = 128
    dtype: str = "bfloat16"
    long_context_ok: bool = False  # sub-quadratic -> long_500k cell runs

    source: str = ""

    # ---- derived ----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def q_group(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def layer_kind(self, i: int) -> str:
        if self.block_pattern is None:
            return "attn"
        return self.block_pattern[i % len(self.block_pattern)]

    def window_for_layer(self, i: int) -> int:
        if self.window_pattern is None:
            return -1
        return self.window_pattern[i % len(self.window_pattern)]


# ---------------------------------------------------------------------------
# Assigned input shapes (same four for every LM arch; see DESIGN.md §5).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def runnable_shapes(cfg: ArchConfig):
    """Shapes that apply to this arch (long_500k gated on sub-quadratic)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.long_context_ok:
        out.append("long_500k")
    return out
