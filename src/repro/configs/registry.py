"""Arch registry: full configs, reduced smoke variants, and input specs.

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every model
input of a (arch x shape) cell — weak-type-correct, shardable, no device
allocation — exactly what `jax.jit(...).lower()` consumes in the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import (ArchConfig, EncDecConfig, MLAConfig, MoEConfig,
                                RGLRUConfig, ShapeSpec, SHAPES, XLSTMConfig,
                                runnable_shapes)

from repro.configs import (arctic_480b, command_r_plus_104b,
                           deepseek_v2_lite_16b, gemma2_27b, minicpm3_4b,
                           nemotron_4_340b, qwen2_vl_2b, recurrentgemma_2b,
                           whisper_small, xlstm_350m)

ARCHS: Dict[str, ArchConfig] = {
    c.name: c for c in [
        qwen2_vl_2b.CONFIG,
        nemotron_4_340b.CONFIG,
        minicpm3_4b.CONFIG,
        gemma2_27b.CONFIG,
        command_r_plus_104b.CONFIG,
        recurrentgemma_2b.CONFIG,
        xlstm_350m.CONFIG,
        deepseek_v2_lite_16b.CONFIG,
        arctic_480b.CONFIG,
        whisper_small.CONFIG,
    ]
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config: small layers/width/experts/vocab.

    Keeps every structural feature (MLA, MoE, block pattern, windows,
    softcaps, enc-dec) so the smoke test exercises the same code paths as
    the full config.
    """
    cfg = get_config(name)
    n_heads = 4
    n_kv = max(1, min(cfg.n_kv_heads * n_heads // max(cfg.n_heads, 1), n_heads))
    if cfg.n_kv_heads == cfg.n_heads:
        n_kv = n_heads
    head_dim = 16
    d_model = n_heads * head_dim * 2          # 128
    # keep >= 2 pattern periods for heterogeneous stacks
    if cfg.block_pattern is not None:
        n_layers = 2 * len(cfg.block_pattern)
    else:
        n_layers = 4
    repl = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=max(1, min(cfg.d_ff, 256)) if cfg.d_ff else 0,
        vocab_size=512,
        vocab_pad_multiple=64,
        remat="none",
        fsdp=False,
    )
    if cfg.attn_scale is not None:
        repl["attn_scale"] = (d_model / n_heads) ** -0.5
    if cfg.window_pattern is not None:
        repl["window_pattern"] = tuple(min(w, 32) if w > 0 else w
                                       for w in cfg.window_pattern)
    if cfg.mla is not None:
        repl["mla"] = MLAConfig(
            q_lora_rank=32 if cfg.mla.q_lora_rank else None,
            kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
            v_head_dim=16)
    if cfg.moe is not None:
        repl["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=min(cfg.moe.top_k, 2), d_expert=64,
            n_shared=min(cfg.moe.n_shared, 1))
    if cfg.rglru is not None:
        repl["rglru"] = RGLRUConfig(lru_width=d_model, conv_width=4)
    if cfg.encdec is not None:
        repl["encdec"] = EncDecConfig(n_enc_layers=2, n_frames=16)
    if cfg.mrope_sections is not None:
        repl["mrope_sections"] = (2, 3, 3)    # sum = head_dim/2 = 8
    return dataclasses.replace(cfg, **repl)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs) per (arch x shape)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeSpec, *, batch_override=None):
    """Model inputs for the cell, as ShapeDtypeStructs.

    train   -> {tokens/embeds..., labels}
    prefill -> {tokens/embeds...}
    decode  -> {tokens/embeds (one step)}; the KV cache spec comes from
               `jax.eval_shape(init_decode_cache, ...)` in the dry-run.
    """
    B = batch_override or shape.global_batch
    S = shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    specs = {}
    if shape.kind in ("train", "prefill"):
        if cfg.embed_inputs == "embeds":
            specs["embeds"] = _sds((B, S, cfg.d_model), dt)
            specs["positions"] = _sds((3, B, S), jnp.int32)
        else:
            specs["tokens"] = _sds((B, S), jnp.int32)
        if cfg.encdec is not None:
            specs["frames"] = _sds((B, cfg.encdec.n_frames, cfg.d_model), dt)
        if shape.kind == "train":
            specs["labels"] = _sds((B, S), jnp.int32)
    else:  # decode: one new token against a seq_len cache
        if cfg.embed_inputs == "embeds":
            specs["tokens"] = _sds((B, cfg.d_model), dt)
        else:
            specs["tokens"] = _sds((B,), jnp.int32)
    return specs


def all_cells():
    """Every (arch, shape) cell with its run/skip status."""
    cells = []
    for name, cfg in ARCHS.items():
        runnable = set(runnable_shapes(cfg))
        for sname, sh in SHAPES.items():
            status = "run" if sname in runnable else "skip:full-attention"
            cells.append((name, sname, status))
    return cells
