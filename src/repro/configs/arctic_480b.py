"""arctic-480b [moe] — dense-MoE hybrid [hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) vocab=32000.  128 experts top-2
(d_expert=4864) computed in parallel with a dense residual FFN
(d_ff=4864) on every layer — Arctic's dense+MoE architecture.  FSDP on
(480B total parameters).
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    mlp="silu",
    rope_theta=1e4,
    moe=MoEConfig(n_experts=128, top_k=2, d_expert=4864, n_shared=0,
                  capacity_factor=1.25),
    dense_ff_residual=True,
    fsdp=True,
    train_microbatches=8,
    source="hf:Snowflake/snowflake-arctic-base",
)
