"""nemotron-4-340b [dense] — [arXiv:2402.16819; unverified].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000; squared-ReLU MLP
(no gating).  # ASSUMED: full-dim RoPE (the paper reports rotary pct 50%;
partial-rope omitted), no bias terms.  FSDP on: 340B params do not fit
replicated on a 16-chip model axis.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    mlp="sqrelu",
    rope_theta=1e4,
    fsdp=True,
    train_microbatches=16,
    source="arXiv:2402.16819",
)
