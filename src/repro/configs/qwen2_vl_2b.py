"""qwen2-vl-2b [vlm] — Qwen2-VL 2B backbone [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  M-RoPE with
(t, h, w) position ids; dynamic-resolution vision frontend is a STUB —
`input_specs` feeds precomputed patch/text embeddings plus the (3, B, S)
M-RoPE position grid, per the assignment.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mlp="silu",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),      # sum = head_dim/2 = 64
    tie_embeddings=True,
    embed_inputs="embeds",
    norm_eps=1e-6,
    train_microbatches=2,
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B-Instruct",
)
