"""recurrentgemma-2b [hybrid] — Griffin 1:2 pattern [arXiv:2402.19427; hf].

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000.
Layer pattern (rec, rec, attn) cycling; attention layers use a 2048 local
window; recurrent block = dual up-projection (GeLU gate x conv1d+RG-LRU),
lru_width=2560.  Sub-quadratic -> the long_500k cell runs (state is O(1)
in context: RG-LRU hidden + 2048-window KV).
"""

from repro.configs.base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    mlp="geglu",
    rope_theta=1e4,
    tie_embeddings=True,
    embed_scale=True,
    block_pattern=("rec", "rec", "attn"),
    window_pattern=(2048,),           # applies to the attn layers
    rglru=RGLRUConfig(lru_width=2560, conv_width=4, c_exponent=8.0),
    long_context_ok=True,
    train_microbatches=2,
    source="arXiv:2402.19427; hf:google/recurrentgemma-2b",
)
