"""xlstm-350m [ssm] — xLSTM[7:1] [arXiv:2405.04517; unverified].

24L d_model=1024 4 heads vocab=50304, d_ff=0 (mLSTM blocks carry their own
2x up-projection; sLSTM blocks carry a 4/3 GeLU FFN).  Pattern: 7 mLSTM
then 1 sLSTM, repeated (3 sLSTM blocks total).  Matrix/scalar memory ->
O(1) decode state -> the long_500k cell runs.
"""

from repro.configs.base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    mlp="gelu",
    block_pattern=("mlstm",) * 7 + ("slstm",),
    xlstm=XLSTMConfig(proj_factor_m=2.0, conv_width=4, ffn_factor_s=4.0 / 3.0),
    long_context_ok=True,
    source="arXiv:2405.04517",
)
