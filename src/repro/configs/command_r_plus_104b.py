"""command-r-plus-104b [dense] — [hf:CohereForAI/c4ai-command-r-plus].

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.  Cohere parallel
block (attention and FFN both read the same pre-norm; one residual add),
no biases, rope_theta=75e6, tied embeddings with logit scaling
(# ASSUMED: logit_scale folded into the tied head).  FSDP on.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    mlp="silu",
    rope_theta=75e6,
    parallel_block=True,
    tie_embeddings=True,
    fsdp=True,
    train_microbatches=16,
    source="hf:CohereForAI/c4ai-command-r-plus",
)
