"""Mixture-of-Experts: top-k dropping router with sort-based dispatch and
expert parallelism.

Dispatch is production-grade (no one-hot einsum blowup): token->expert pairs
are sorted by expert id, packed into a dense (E_local, capacity, D) buffer
(drops beyond capacity, standard Switch semantics), run through stacked
expert FFNs with a single batched einsum, and scattered back weighted by the
(optionally renormalized) router probabilities.

Expert parallelism: `moe_apply` takes (e_start, e_count) — the slice of
experts this shard owns — and an optional `psum_axis`.  Tokens are replicated
across the model axis between TP ops (megatron convention), so each shard
routes all its local tokens, computes only its own experts, and the final
psum over the model axis combines expert outputs — EP without any all_to_all
(DESIGN.md §3.1).  deepseek-style shared experts and aux load-balance loss
included.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.layers import make_dense


def make_moe(key, d_model: int, cfg: MoEConfig, mlp_kind: str):
    ks = jax.random.split(key, 5)
    E, F = cfg.n_experts, cfg.d_expert
    gated = mlp_kind in ("silu", "geglu")
    scale = d_model ** -0.5
    p = {
        "router": {"w": jax.random.normal(ks[0], (d_model, E), jnp.float32) * scale},
        "up": jax.random.normal(ks[1], (E, d_model, F), jnp.float32) * scale,
        "down": jax.random.normal(ks[2], (E, F, d_model), jnp.float32) * (F ** -0.5),
    }
    if gated:
        p["gate"] = jax.random.normal(ks[3], (E, d_model, F), jnp.float32) * scale
    if cfg.n_shared:
        from repro.models.layers import make_mlp
        p["shared"] = make_mlp(ks[4], d_model, cfg.n_shared * F, mlp_kind)
    return p


def _expert_ffn(p, xe, mlp_kind, dtype):
    """xe: (E_local, C, D) -> (E_local, C, D), batched over experts."""
    up = jnp.einsum("ecd,edf->ecf", xe, p["up"].astype(dtype))
    if mlp_kind == "silu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["gate"].astype(dtype))) * up
    elif mlp_kind == "geglu":
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["gate"].astype(dtype))) * up
    elif mlp_kind == "gelu":
        h = jax.nn.gelu(up)
    elif mlp_kind == "sqrelu":
        h = jnp.square(jax.nn.relu(up))
    else:
        raise ValueError(mlp_kind)
    return jnp.einsum("ecf,efd->ecd", h, p["down"].astype(dtype))


def moe_apply(p, x, cfg: MoEConfig, mlp_kind: str, *, e_start=0, e_count=None,
              psum_axis=None, slice_params=None, dropless=False):
    """x: (..., D).  Returns (y, aux_loss).

    e_start/e_count select the local expert slice (expert parallelism);
    slice_params optionally maps full expert arrays -> local slices (used
    under shard_map where params arrive pre-sliced: pass identity).
    dropless=True sizes the capacity for the worst case (decode steps must
    not drop tokens — a dropped route changes logits).
    """
    E = cfg.n_experts
    e_count = E if e_count is None else e_count
    dtype = x.dtype
    lead = x.shape[:-1]
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    N = xf.shape[0]
    k = cfg.top_k

    # --- routing (fp32) ----------------------------------------------------
    logits = (xf.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (N, E)
    top_p, top_e = jax.lax.top_k(probs, k)                        # (N, k)
    if cfg.normalize_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (computed on the *global* assignment)
    me = probs.mean(axis=0)                                        # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (N * k)
    aux = cfg.aux_loss_weight * E * jnp.sum(me * ce)

    # --- local dispatch ------------------------------------------------------
    C = N if dropless else max(1, int(k * N * cfg.capacity_factor / E))
    ef = top_e.reshape(-1)                                         # (N*k,)
    tf = jnp.repeat(jnp.arange(N), k)
    wf = top_p.reshape(-1).astype(dtype)
    local = (ef >= e_start) & (ef < e_start + e_count)
    le = jnp.where(local, ef - e_start, e_count)                   # non-local -> bucket E_local
    order = jnp.argsort(le, stable=True)
    le_s, tok_s, w_s = le[order], tf[order], wf[order]
    starts = jnp.searchsorted(le_s, jnp.arange(e_count + 1))       # run starts
    pos = jnp.arange(N * k) - starts[le_s]
    keep = (le_s < e_count) & (pos < C)
    slot = jnp.where(keep, le_s * C + pos, e_count * C)            # dump slot at end

    buf = jnp.zeros((e_count * C + 1, D), dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], xf[tok_s], 0))
    xe = buf[:-1].reshape(e_count, C, D)

    if slice_params is None:
        # default: slice the local expert range out of full arrays
        slice_params = lambda a: jax.lax.dynamic_slice_in_dim(a, e_start, e_count, 0)
    pl = {kk: slice_params(p[kk]) for kk in ("up", "down", "gate") if kk in p}
    ye = _expert_ffn(pl, xe, mlp_kind, dtype).reshape(-1, D)       # (E_local*C, D)

    contrib = jnp.where(keep[:, None], ye[jnp.minimum(slot, e_count * C - 1)]
                        * w_s[:, None], 0)
    y = jnp.zeros((N, D), dtype).at[tok_s].add(contrib)

    if psum_axis is not None:
        y = jax.lax.psum(y, psum_axis)

    # shared experts run on every token (replicated across shards)
    if "shared" in p:
        from repro.models.layers import mlp as dense_mlp
        y = y + dense_mlp(p["shared"], xf, mlp_kind, dtype)

    return y.reshape(*lead, D), aux


def moe_apply_auto(p, x, cfg: MoEConfig, mlp_kind: str, *, dropless=False):
    """MoE with automatic expert parallelism.

    When a parallel context is active (launch/train, dry-run) and the expert
    count divides the TP axis, the dispatch runs as a `shard_map` island:
    each (data x model) shard routes its *local* tokens over its *local*
    expert slice and a psum over the model axis combines expert outputs.
    This keeps the sort-based dispatch local — GSPMD would otherwise turn
    the argsort into a distributed sort.  Outside a parallel context this
    is exactly `moe_apply`.
    """
    from repro.distributed.context import get_parallel

    ctx = get_parallel()
    E = cfg.n_experts
    if ctx is None:
        return moe_apply(p, x, cfg, mlp_kind, dropless=dropless)
    mesh = ctx.mesh
    tp = mesh.shape[ctx.tp_axis]
    dp = int(np.prod([mesh.shape[a] for a in ctx.dp_axes]))
    B = x.shape[0]
    if E % tp or B % dp:
        return moe_apply(p, x, cfg, mlp_kind, dropless=dropless)
    e_count = E // tp
    P_ = jax.sharding.PartitionSpec
    dp_axes = ctx.dp_axes
    tp_axis = ctx.tp_axis

    def pspec(path, leaf):
        ps = jax.tree_util.keystr(path)
        if "router" in ps or "shared" in ps:
            return P_(*([None] * leaf.ndim))
        return P_(tp_axis, *([None] * (leaf.ndim - 1)))   # expert-stacked

    param_specs = jax.tree_util.tree_map_with_path(pspec, p)

    def island(p_local, x_local):
        e_start = jax.lax.axis_index(tp_axis) * e_count
        y, aux = moe_apply(p_local, x_local, cfg, mlp_kind,
                           e_start=e_start, e_count=e_count,
                           psum_axis=tp_axis, slice_params=lambda a: a,
                           dropless=dropless)
        aux = jax.lax.pmean(jax.lax.pmean(aux, dp_axes), tp_axis)
        return y, aux

    from repro.core.distributed import shard_map_compat
    fn = shard_map_compat(
        island, mesh,
        (param_specs, P_(dp_axes, *([None] * (x.ndim - 1)))),
        (P_(dp_axes, *([None] * (x.ndim - 1))), P_()))
    return fn(p, x)


def moe_ref(p, x, cfg: MoEConfig, mlp_kind: str):
    """Reference: loop over experts, no capacity dropping.  Tests only."""
    dtype = x.dtype
    lead, D = x.shape[:-1], x.shape[-1]
    xf = x.reshape(-1, D)
    logits = xf.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    if cfg.normalize_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    y = jnp.zeros_like(xf)
    for e in range(cfg.n_experts):
        pe = {"up": p["up"][e:e+1], "down": p["down"][e:e+1]}
        if "gate" in p:
            pe["gate"] = p["gate"][e:e+1]
        he = _expert_ffn(pe, xf[None], mlp_kind, dtype)[0]
        w = jnp.where(top_e == e, top_p, 0).sum(-1).astype(dtype)
        y = y + he * w[:, None]
    if "shared" in p:
        from repro.models.layers import mlp as dense_mlp
        y = y + dense_mlp(p["shared"], xf, mlp_kind, dtype)
    return y.reshape(*lead, D)
