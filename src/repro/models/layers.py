"""Shared layer primitives: norms, MLPs, RoPE / M-RoPE, embeddings.

Everything is pure-functional: `init_*` builds param subtrees (plain dicts
of jnp arrays), `apply` functions take (params, x).  Params are created in
float32 and cast to the compute dtype at use (master-weight convention).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def make_dense(key, d_in, d_out, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}


def dense(p, x, dtype):
    return x @ p["w"].astype(dtype)


def make_norm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * p["scale"]).astype(dt)


def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def make_mlp(key, d_model, d_ff, kind):
    k1, k2, k3 = jax.random.split(key, 3)
    gated = kind in ("silu", "geglu")
    p = {"down": make_dense(k2, d_ff, d_model)}
    p["up"] = make_dense(k1, d_model, d_ff)
    if gated:
        p["gate"] = make_dense(k3, d_model, d_ff)
    return p


def mlp(p, x, kind, dtype):
    if kind == "silu":
        h = jax.nn.silu(dense(p["gate"], x, dtype)) * dense(p["up"], x, dtype)
    elif kind == "geglu":
        h = jax.nn.gelu(dense(p["gate"], x, dtype)) * dense(p["up"], x, dtype)
    elif kind == "gelu":
        h = jax.nn.gelu(dense(p["up"], x, dtype))
    elif kind == "sqrelu":   # nemotron-4 squared ReLU
        h = jnp.square(jax.nn.relu(dense(p["up"], x, dtype)))
    else:
        raise ValueError(kind)
    return dense(p["down"], h, dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta):
    """x: (..., S, H, D); positions: broadcastable to (..., S) int32."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                    # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    ang = ang[..., None, :]                          # (..., S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta, sections):
    """Qwen2-VL multimodal RoPE.

    positions3: (3, ..., S) — temporal / height / width position ids (the
    vision-frontend stub provides these; text tokens have t=h=w).
    sections: per-axis number of frequency pairs, sum == D/2.
    """
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                     # (D/2,)
    # ang per axis then stitch sections: (3, ..., S, D/2)
    ang_all = positions3[..., None].astype(jnp.float32) * freqs
    parts = []
    start = 0
    for axis, sec in enumerate(sections):
        parts.append(ang_all[axis, ..., start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)[..., None, :]   # (..., S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos, d_model):
    """Whisper encoder positional embedding (fixed)."""
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d_model)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
