"""Parameter counting for MFU/roofline bookkeeping.

Counts come from `jax.eval_shape(init_params, ...)` — exact by construction,
no analytic formula to drift out of sync with the model code.

Conventions (EXPERIMENTS.md §Roofline):
  * N excludes the input embedding gather (not a matmul) but includes the
    LM head; a tied table is counted once, on the head side.
  * N_active (MoE): routed-expert params scaled by top_k / n_experts,
    shared experts and everything else at 1x.
"""

from __future__ import annotations

from functools import partial

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.transformer import init_params


def _leaf_sizes(cfg: ArchConfig):
    key_s = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    tree = jax.eval_shape(partial(init_params, cfg), key_s)
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out.append((jax.tree_util.keystr(path), int(np.prod(leaf.shape))))
    return out


def param_count(cfg: ArchConfig) -> int:
    """Total parameters (including embedding table)."""
    return sum(n for _, n in _leaf_sizes(cfg))


def matmul_param_count(cfg: ArchConfig) -> int:
    """N for the 2ND/6ND flop model: excludes the embedding gather unless
    the table is tied (then it acts as the head matmul and counts once)."""
    total = 0
    for path, n in _leaf_sizes(cfg):
        if "embed'" in path and not cfg.tie_embeddings:
            continue
        if "dec_pos" in path:
            continue
        total += n
    return total


def active_matmul_param_count(cfg: ArchConfig) -> int:
    """MoE-aware: routed experts contribute top_k / n_experts of their size."""
    if cfg.moe is None:
        return matmul_param_count(cfg)
    frac = cfg.moe.top_k / cfg.moe.n_experts
    total = 0
    for path, n in _leaf_sizes(cfg):
        if "embed'" in path and not cfg.tie_embeddings:
            continue
        if "dec_pos" in path:
            continue
        if "moe']['up" in path or "moe']['gate" in path or "moe']['down" in path:
            total += int(n * frac)
        else:
            total += n
    return total
