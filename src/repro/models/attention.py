"""Attention: GQA with RoPE / windows / softcap, chunked (flash-style)
softmax, MLA (latent attention), and sequence-sharded flash decoding.

Memory discipline: `chunked_attention` never materializes the (Sq, Skv)
score matrix — it scans KV in blocks with an online-softmax carry (running
max m, normalizer l, weighted accumulator acc), optionally also blocking the
query axis.  This is the pure-JAX flash formulation; XLA fuses each block's
QK^T / softmax / PV into an MXU-friendly pipeline on TPU.

`flash_decode_sharded` merges per-shard partial attention (m, l, acc) across
a KV cache sharded along *sequence* on the `model` mesh axis — the
flash-decoding trick, needed for archs whose KV-head count does not divide
the TP width (DESIGN.md §3.2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap is not None else x


def _mask(qpos, kpos, causal, window):
    """(..., Sq, Sk) boolean validity mask from position vectors."""
    m = jnp.ones((qpos.shape[-1], kpos.shape[-1]), dtype=bool)
    if causal:
        m = m & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        m = m & (kpos[None, :] > qpos[:, None] - window)
    return m


def full_attention(q, k, v, *, causal=True, window=None, softcap=None,
                   scale=None, q_offset=0, kv_offset=0):
    """Naive reference: materializes scores.  Oracle for tests only.

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, Dk/Dv).  window: int or traced
    scalar; <= 0 means global.
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = _softcap(s, softcap)
    qpos = q_offset + jnp.arange(Sq)
    kpos = kv_offset + jnp.arange(k.shape[1])
    valid = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        valid = valid & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        w = jnp.asarray(window)
        valid = valid & ((kpos[None, :] > qpos[:, None] - w) | (w <= 0))
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, v.shape[-1]).astype(q.dtype)


def chunked_attention(q, k, v, *, causal=True, window=None, softcap=None,
                      scale=None, q_offset=0, kv_offset=0,
                      chunk_q=2048, chunk_kv=1024):
    """Flash-style attention; O(Sq * chunk_kv) live memory.

    window may be a traced scalar (per-layer value under scan-over-layers);
    <= 0 disables the window.
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    Dv = v.shape[-1]
    scale = D ** -0.5 if scale is None else scale
    cq = min(chunk_q, Sq)
    ck = min(chunk_kv, Sk)
    if Sq % cq:                      # non-divisible (e.g. whisper's 1500
        cq = Sq                      # frames): fall back to one block
    if Sk % ck:
        ck = Sk
    nq, nk = Sq // cq, Sk // ck
    w = jnp.asarray(window) if window is not None else jnp.asarray(0)

    # Inputs stay in the compute dtype (bf16 in production): QK^T and PV
    # accumulate in fp32 via preferred_element_type, probs are cast back to
    # the compute dtype for the PV matmul (flash-attention convention).
    # Keeping the blocks bf16 halves attention HBM/collective bytes vs the
    # previous all-fp32 formulation (§Perf cell A).
    qg = q.reshape(B, nq, cq, Hkv, G, D)
    kc = k.reshape(B, nk, ck, Hkv, D)
    vc = v.reshape(B, nk, ck, Hkv, Dv)

    def q_block(_, qi):
        qb = qg[:, qi]                                  # (B, cq, Hkv, G, D)
        qpos = q_offset + qi * cq + jnp.arange(cq)

        def kv_block(carry, ki):
            m, l, acc = carry
            kb = kc[:, ki]
            vb = vc[:, ki]
            kpos = kv_offset + ki * ck + jnp.arange(ck)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            valid = jnp.ones((cq, ck), bool)
            if causal:
                valid = valid & (kpos[None, :] <= qpos[:, None])
            valid = valid & ((kpos[None, :] > qpos[:, None] - w) | (w <= 0))
            s = jnp.where(valid, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(q.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, cq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), jnp.arange(nk))
        o = acc / jnp.maximum(l, 1e-30)[..., None]      # (B, Hkv, G, cq, Dv)
        return None, o.transpose(0, 3, 1, 2, 4)          # (B, cq, Hkv, G, Dv)

    _, blocks = jax.lax.scan(q_block, None, jnp.arange(nq))
    # blocks: (nq, B, cq, Hkv, G, Dv)
    o = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, Dv)
    return o.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None,
                     softcap=None, scale=None):
    """Single-token decode against a (B, Smax, Hkv, D) cache.

    cache_len: current valid length (the new token is at cache_len - 1).
    """
    B, _, Hq, D = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    Dv = v_cache.shape[-1]
    scale = D ** -0.5 if scale is None else scale
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32)) * scale
    s = _softcap(s, softcap)
    kpos = jnp.arange(Smax)
    clen = jnp.broadcast_to(jnp.asarray(cache_len), (B,))
    valid = kpos[None, :] < clen[:, None]                       # (B, Smax)
    if window is not None:
        w = jnp.asarray(window)
        valid = valid & ((kpos[None, :] > clen[:, None] - 1 - w) | (w <= 0))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, Hq, Dv).astype(q.dtype)


def flash_decode_partial(q, k_shard, v_shard, valid_mask, *, softcap=None, scale=None):
    """Per-shard partial attention for sequence-sharded KV caches.

    Returns (m, l, acc) to be merged across shards with `flash_decode_merge`.
    q: (B, 1, Hq, D); k_shard/v_shard: (B, Ss, Hkv, D); valid_mask: (B, Ss).
    """
    B, _, Hq, D = q.shape
    Hkv = k_shard.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_shard.astype(jnp.float32)) * scale
    s = _softcap(s, softcap)
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)                                   # (B, Hkv, G)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhgk,bkhd->bhgd", p, v_shard.astype(jnp.float32))
    return m, l, acc


def flash_decode_merge(m, l, acc, axis_name):
    """Merge per-shard (m, l, acc) over `axis_name` (log-sum-exp algebra)."""
    m_glob = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * corr, axis_name)
    acc_glob = jax.lax.psum(acc * corr[..., None], axis_name)
    return acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]
