"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin) and xLSTM (mLSTM/sLSTM).

Every cell has two forms that tests prove equivalent:
  * a *parallel* training/prefill form over (B, S, ...) built on
    `jax.lax.associative_scan` (linear and max-plus recurrences are both
    associative, so the VPU computes them in O(log S) depth), or a chunked
    state-passing form for the matrix-memory mLSTM;
  * a *step* form carrying an O(1) state for decode (this is what makes the
    `long_500k` cell runnable for these archs: state size is independent of
    context length).

Conventions: params are plain dicts of fp32 arrays cast to compute dtype at
use; activations (B, S, D).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import make_dense, dense


# ---------------------------------------------------------------------------
# Shared: causal temporal conv1d (width K, depthwise), parallel + step forms.
# ---------------------------------------------------------------------------

def make_conv1d(key, d: int, width: int):
    return {"w": jax.random.normal(key, (width, d), jnp.float32) * (width * d) ** -0.25,
            "b": jnp.zeros((d,), jnp.float32)}


def conv1d_causal(p, x):
    """x: (B, S, D) -> (B, S, D); causal depthwise conv of width K."""
    K = p["w"].shape[0]
    w = p["w"].astype(x.dtype)
    out = x * w[K - 1]
    for i in range(K - 1):
        shifted = jnp.pad(x, ((0, 0), (K - 1 - i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[i]
    return out + p["b"].astype(x.dtype)


def conv1d_step(p, window, x_t):
    """window: (B, K-1, D) previous inputs; x_t: (B, D). Returns (y_t, window')."""
    K = p["w"].shape[0]
    w = p["w"].astype(x_t.dtype)
    full = jnp.concatenate([window, x_t[:, None]], axis=1)       # (B, K, D)
    y = jnp.einsum("bkd,kd->bd", full, w) + p["b"].astype(x_t.dtype)
    return y, full[:, 1:]


# ---------------------------------------------------------------------------
# RG-LRU (Griffin eq. 1-4): h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t),
# log a_t = -c * r_t * softplus(-Lambda).
# ---------------------------------------------------------------------------

def make_rglru(key, d: int):
    k1, k2, k3 = jax.random.split(key, 3)
    # Lambda init so that a^c spans ~(0.9, 0.999) (Griffin appendix).
    u = jax.random.uniform(k3, (d,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (-1.0 / 8.0) - 1.0)  # sigmoid(-lam)^8 ~ u... inverse below
    return {
        "wr": make_dense(k1, d, d), "br": jnp.zeros((d,), jnp.float32),
        "wi": make_dense(k2, d, d), "bi": jnp.zeros((d,), jnp.float32),
        "lam": lam,
    }


def _rglru_coeffs(p, x, c: float):
    dt = x.dtype
    r = jax.nn.sigmoid(dense(p["wr"], x, dt) + p["br"].astype(dt)).astype(jnp.float32)
    i = jax.nn.sigmoid(dense(p["wi"], x, dt) + p["bi"].astype(dt)).astype(jnp.float32)
    log_a = -c * r * jax.nn.softplus(-p["lam"])          # (B, S, D) fp32, <= 0
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12))
    b = mult * (i * x.astype(jnp.float32))
    return a, b


def _linear_scan(a, b, axis: int):
    """h_t = a_t h_{t-1} + b_t via associative scan ((a,b) composition)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return ar * al, ar * bl + br
    _, h = jax.lax.associative_scan(combine, (a, b), axis=axis)
    return h


def rglru_apply(p, x, c: float = 8.0):
    """Parallel form. x: (B, S, D) -> (B, S, D)."""
    a, b = _rglru_coeffs(p, x, c)
    h = _linear_scan(a, b, axis=1)
    return h.astype(x.dtype)


def rglru_step(p, h_prev, x_t, c: float = 8.0):
    """h_prev: (B, D) fp32; x_t: (B, D). Returns (y_t, h_new)."""
    a, b = _rglru_coeffs(p, x_t[:, None], c)
    h = a[:, 0] * h_prev + b[:, 0]
    return h.astype(x_t.dtype), h


# ---------------------------------------------------------------------------
# Griffin recurrent block: two up-branches (gate: GeLU; main: conv1d+RG-LRU),
# elementwise merge, down-projection.
# ---------------------------------------------------------------------------

def make_rec_block(key, d_model: int, lru_width: int, conv_width: int):
    ks = jax.random.split(key, 5)
    return {
        "w_gate": make_dense(ks[0], d_model, lru_width),
        "w_main": make_dense(ks[1], d_model, lru_width),
        "conv": make_conv1d(ks[2], lru_width, conv_width),
        "lru": make_rglru(ks[3], lru_width),
        "w_out": make_dense(ks[4], lru_width, d_model),
    }


def rec_block_apply(p, x, c_exp: float = 8.0, return_state: bool = False):
    gate = jax.nn.gelu(dense(p["w_gate"], x, x.dtype))
    pre = dense(p["w_main"], x, x.dtype)
    main = conv1d_causal(p["conv"], pre)
    a, b = _rglru_coeffs(p["lru"], main, c_exp)
    h = _linear_scan(a, b, axis=1)
    out = dense(p["w_out"], h.astype(x.dtype) * gate, x.dtype)
    if return_state:
        K = p["conv"]["w"].shape[0]
        return out, {"h": h[:, -1], "conv": pre[:, -(K - 1):]}
    return out


def rec_block_init_state(batch: int, lru_width: int, conv_width: int,
                         dtype=jnp.bfloat16):
    return {"h": jnp.zeros((batch, lru_width), jnp.float32),
            "conv": jnp.zeros((batch, conv_width - 1, lru_width), dtype)}


def rec_block_step(p, state, x_t, c_exp: float = 8.0):
    gate = jax.nn.gelu(dense(p["w_gate"], x_t, x_t.dtype))
    main = dense(p["w_main"], x_t, x_t.dtype)
    main, conv_w = conv1d_step(p["conv"], state["conv"].astype(x_t.dtype), main)
    y, h = rglru_step(p["lru"], state["h"], main, c_exp)
    out = dense(p["w_out"], y * gate, x_t.dtype)
    return out, {"h": h, "conv": conv_w.astype(state["conv"].dtype)}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM): matrix memory C_t = f_t C_{t-1} + i_t v_t k_t^T, read
# h_t = C_t q_t / max(|n_t . q_t|, exp(-m_t)); log-space stabilized.
# Chunked parallel form (intra-chunk quadratic, inter-chunk state passing).
# ---------------------------------------------------------------------------

def mlstm_chunked(q, k, v, i_gate, f_gate, chunk: int = 256,
                  return_state: bool = False):
    """q,k,v: (B, S, H, D); i_gate/f_gate: (B, S, H) raw (pre-activation).

    Returns h: (B, S, H, D), or (h, (C, n, m) final state) with
    return_state=True — the prefill path MUST take the state from this
    pass's carry; replaying the sequence step-by-step costs an S-trip
    sequential loop (a 229k-collective bug caught in §Perf iteration C2).
    """
    B, S, H, D = q.shape
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    N = S // C
    scale = D ** -0.5
    # Per-chunk work (fp32 casts, cumulative gates, the (C, C) decay matrix)
    # happens INSIDE the scan body: materializing the (B, N, C, C, H) decay
    # tensor up front costs O(S*C) fp32 HBM — at 32k prefill that was 268
    # GB/device, the dominant roofline term of the whole cell (§Perf).
    qc = q.reshape(B, N, C, H, D)
    kc = k.reshape(B, N, C, H, D)
    vc = v.reshape(B, N, C, H, D)
    fgc = f_gate.reshape(B, N, C, H)
    igc = i_gate.reshape(B, N, C, H)
    tri = jnp.tril(jnp.ones((C, C), bool))

    f32 = jnp.float32

    def scan_fn(carry, blk):
        Cm, n, m = carry                              # (B,H,D,D), (B,H,D), (B,H)
        qb, kb, vb, fgb, igb = blk
        # q/k/v stay in the compute dtype (bf16 in production) — the chunk
        # gathers/partial-sum reduces then move half the bytes; accumulation
        # is forced to fp32 via preferred_element_type.  Gate/stabilizer
        # math is fp32 throughout.
        qb = qb * jnp.asarray(scale, qb.dtype)        # (B,C,H,D)
        logfb = jax.nn.log_sigmoid(fgb.astype(f32))   # (B,C,H)
        logib = igb.astype(f32)
        Fb = jnp.cumsum(logfb, axis=1)                # within-chunk cum log-f
        Ftotb = Fb[:, -1]                             # (B,H)
        # Intra-chunk decay: Db[t, s] = F_t - F_s + logi_s for s <= t.
        Db = Fb[:, :, None, :] - Fb[:, None, :, :] + logib[:, None, :, :]
        Db = jnp.where(tri[None, :, :, None], Db, -jnp.inf)
        # inter-chunk: decayed query contribution
        m_intra = jnp.max(Db, axis=2)                 # (B,C,H): max over s
        m_inter = Fb + m[:, None, :]                  # (B,C,H)
        m_new = jnp.maximum(m_intra, m_inter)         # per-position stabilizer
        dt = qb.dtype
        s = jnp.einsum("bthd,bshd->btsh", qb, kb,
                       preferred_element_type=f32)    # (B,C,C,H) fp32
        s = s * jnp.exp(Db - m_new[:, :, None, :])
        # "probs" in compute dtype for the PV-style matmuls (flash-attention
        # convention), fp32 accumulation via preferred_element_type
        sp = s.astype(dt)
        h_intra = jnp.einsum("btsh,bshd->bthd", sp, vb,
                             preferred_element_type=f32)
        l_intra = s.sum(axis=2)                       # (B,C,H)
        w_inter = jnp.exp(m_inter - m_new)            # (B,C,H)
        h_inter = jnp.einsum("bthd,bhde->bthe", qb, Cm.astype(dt),
                             preferred_element_type=f32) * w_inter[..., None]
        l_inter = jnp.einsum("bthd,bhd->bth", qb, n.astype(dt),
                             preferred_element_type=f32) * w_inter
        denom = jnp.maximum(jnp.abs(l_intra + l_inter), jnp.exp(-m_new))
        h = (h_intra + h_inter) / denom[..., None]
        # state update to end of chunk
        m_next = jnp.maximum(Ftotb + m, jnp.max(Db[:, -1], axis=1))
        w_old = jnp.exp(Ftotb + m - m_next)           # (B,H)
        wk = jnp.exp(Ftotb[:, None, :] - Fb + logib - m_next[:, None, :])  # (B,C,H)
        C_new = Cm * w_old[:, :, None, None] + jnp.einsum(
            "bshd,bsh,bshe->bhde", kb, wk.astype(dt), vb,
            preferred_element_type=f32)
        n_new = n * w_old[:, :, None] + jnp.einsum(
            "bshd,bsh->bhd", kb, wk.astype(dt), preferred_element_type=f32)
        return (C_new, n_new, m_next), h

    C0 = jnp.zeros((B, H, D, D), jnp.float32)
    n0 = jnp.zeros((B, H, D), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    blks = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
            vc.transpose(1, 0, 2, 3, 4), fgc.transpose(1, 0, 2, 3),
            igc.transpose(1, 0, 2, 3))
    state, hs = jax.lax.scan(scan_fn, (C0, n0, m0), blks)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    h = h.astype(q.dtype)
    if return_state:
        return h, state
    return h


def mlstm_ref(q, k, v, i_gate, f_gate):
    """Sequential stabilized reference (tests only)."""
    B, S, H, D = q.shape
    scale = D ** -0.5

    def step(carry, t):
        Cm, n, m = carry
        qt = q[:, t].astype(jnp.float32) * scale
        kt = k[:, t].astype(jnp.float32)
        vt = v[:, t].astype(jnp.float32)
        logf = jax.nn.log_sigmoid(f_gate[:, t].astype(jnp.float32))
        logi = i_gate[:, t].astype(jnp.float32)
        m_new = jnp.maximum(logf + m, logi)
        fw = jnp.exp(logf + m - m_new)
        iw = jnp.exp(logi - m_new)
        Cm = Cm * fw[:, :, None, None] + iw[:, :, None, None] * (
            kt[:, :, :, None] * vt[:, :, None, :])
        n = n * fw[:, :, None] + iw[:, :, None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, Cm)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)), jnp.exp(-m_new))
        return (Cm, n, m_new), num / den[..., None]

    C0 = jnp.zeros((B, H, D, D), jnp.float32)
    n0 = jnp.zeros((B, H, D), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, hs = jax.lax.scan(step, (C0, n0, m0), jnp.arange(S))
    return hs.transpose(1, 0, 2, 3).astype(q.dtype)


def mlstm_step(state, q_t, k_t, v_t, i_t, f_t):
    """One decode step.  state: {"C": (B,H,D,D), "n": (B,H,D), "m": (B,H)}."""
    D = q_t.shape[-1]
    qt = q_t.astype(jnp.float32) * D ** -0.5
    kt = k_t.astype(jnp.float32)
    vt = v_t.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_t.astype(jnp.float32))
    logi = i_t.astype(jnp.float32)
    m_new = jnp.maximum(logf + state["m"], logi)
    fw = jnp.exp(logf + state["m"] - m_new)
    iw = jnp.exp(logi - m_new)
    Cm = state["C"] * fw[:, :, None, None] + iw[:, :, None, None] * (
        kt[:, :, :, None] * vt[:, :, None, :])
    n = state["n"] * fw[:, :, None] + iw[:, :, None] * kt
    num = jnp.einsum("bhd,bhde->bhe", qt, Cm)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)), jnp.exp(-m_new))
    h = (num / den[..., None]).astype(q_t.dtype)
    return h, {"C": Cm, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM: scalar memory with exponential gating; stabilizer m_t is a max-plus
# linear recurrence -> associative scan, then (c, n) are gated linear scans.
# ---------------------------------------------------------------------------

def slstm_apply(z, i_gate, f_gate, o_gate, return_state: bool = False):
    """z (cell input, tanh'd), gates: (B, S, H, D) raw pre-activations.

    Returns h: (B, S, H, D), optionally with the final (c, n, m) state.
    (No hidden-to-hidden recurrence in this simplified head-parallel form —
    ASSUMED simplification recorded in DESIGN.md; the gating recurrence is
    the xLSTM sLSTM one.)
    """
    zf = jnp.tanh(z.astype(jnp.float32))
    logi = i_gate.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))

    # m_t = max(logf_t + m_{t-1}, logi_t): max-plus scan over functions
    # x -> max(x + a, b), composed as (a1+a2, max(b1 + a2, b2)).
    def mp_combine(l, r):
        al, bl = l
        ar, br = r
        return al + ar, jnp.maximum(bl + ar, br)
    _, m = jax.lax.associative_scan(mp_combine, (logf, logi), axis=1)

    m_prev = jnp.concatenate([jnp.full_like(m[:, :1], -1e30), m[:, :-1]], axis=1)
    fw = jnp.exp(logf + m_prev - m)        # stabilized forget weight
    iw = jnp.exp(logi - m)                 # stabilized input weight

    c = _linear_scan(fw, iw * zf, axis=1)
    n = _linear_scan(fw, iw, axis=1)
    h = jnp.tanh(c / jnp.maximum(n, 1e-6))  # ASSUMED: tanh readout stabilizer
    out = (jax.nn.sigmoid(o_gate.astype(jnp.float32)) * h).astype(z.dtype)
    if return_state:
        return out, {"c": c[:, -1], "n": n[:, -1], "m": m[:, -1]}
    return out


def slstm_step(state, z_t, i_t, f_t, o_t):
    """state: {"c": (B,H,D), "n": (B,H,D), "m": (B,H,D)} fp32."""
    zf = jnp.tanh(z_t.astype(jnp.float32))
    logi = i_t.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_t.astype(jnp.float32))
    m_new = jnp.maximum(logf + state["m"], logi)
    fw = jnp.exp(logf + state["m"] - m_new)
    iw = jnp.exp(logi - m_new)
    c = state["c"] * fw + iw * zf
    n = state["n"] * fw + iw
    h = jnp.tanh(c / jnp.maximum(n, 1e-6))
    out = (jax.nn.sigmoid(o_t.astype(jnp.float32)) * h).astype(z_t.dtype)
    return out, {"c": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# xLSTM blocks.
# ---------------------------------------------------------------------------

def make_mlstm_block(key, d_model: int, n_heads: int, proj_factor: float,
                     conv_width: int):
    d_in = int(d_model * proj_factor)
    ks = jax.random.split(key, 8)
    return {
        "w_up": make_dense(ks[0], d_model, d_in),
        "w_gate": make_dense(ks[1], d_model, d_in),
        "conv": make_conv1d(ks[2], d_in, conv_width),
        "wq": make_dense(ks[3], d_in, d_in),
        "wk": make_dense(ks[4], d_in, d_in),
        "wv": make_dense(ks[5], d_in, d_in),
        "w_if": make_dense(ks[6], d_in, 2 * n_heads),
        "w_down": make_dense(ks[7], d_in, d_model),
        "gn_scale": jnp.ones((d_in,), jnp.float32),
    }


def _heads(x, h):
    B, S, D = x.shape
    return x.reshape(B, S, h, D // h)


def _groupnorm_heads(x, scale, eps=1e-5):
    """Per-head group norm over the head dim. x: (B, S, H, Dh)."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    xn = (xf - mu) * jax.lax.rsqrt(var + eps)
    B, S, H, Dh = x.shape
    return (xn.reshape(B, S, H * Dh) * scale).astype(x.dtype)


def mlstm_block_apply(p, x, n_heads: int, chunk: int = 256,
                      return_state: bool = False):
    dt = x.dtype
    up = dense(p["w_up"], x, dt)
    gate = dense(p["w_gate"], x, dt)
    c = jax.nn.silu(conv1d_causal(p["conv"], up))
    q = _heads(dense(p["wq"], c, dt), n_heads)
    k = _heads(dense(p["wk"], c, dt), n_heads)
    v = _heads(dense(p["wv"], up, dt), n_heads)
    if_g = dense(p["w_if"], up, dt)
    i_g, f_g = jnp.split(if_g, 2, axis=-1)              # (B, S, H)
    hs = mlstm_chunked(q, k, v, i_g, f_g, chunk=chunk,
                       return_state=return_state)
    if return_state:
        hs, (Cm, n, m) = hs
    h = _groupnorm_heads(hs, p["gn_scale"])
    out = dense(p["w_down"], h * jax.nn.silu(gate), dt)
    if return_state:
        K = p["conv"]["w"].shape[0]
        return out, {"conv": up[:, -(K - 1):], "C": Cm, "n": n, "m": m}
    return out


def mlstm_block_init_state(batch, d_model, n_heads, proj_factor, conv_width,
                           dtype=jnp.bfloat16):
    d_in = int(d_model * proj_factor)
    dh = d_in // n_heads
    return {"conv": jnp.zeros((batch, conv_width - 1, d_in), dtype),
            "C": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
            "m": jnp.full((batch, n_heads), -1e30, jnp.float32)}


def mlstm_block_step(p, state, x_t, n_heads: int):
    dt = x_t.dtype
    up = dense(p["w_up"], x_t, dt)                      # (B, d_in)
    gate = dense(p["w_gate"], x_t, dt)
    c, conv_w = conv1d_step(p["conv"], state["conv"].astype(dt), up)
    c = jax.nn.silu(c)
    B, d_in = up.shape
    hd = d_in // n_heads
    q = dense(p["wq"], c, dt).reshape(B, n_heads, hd)
    k = dense(p["wk"], c, dt).reshape(B, n_heads, hd)
    v = dense(p["wv"], up, dt).reshape(B, n_heads, hd)
    i_g, f_g = jnp.split(dense(p["w_if"], up, dt), 2, axis=-1)
    h, cell = mlstm_step({"C": state["C"], "n": state["n"], "m": state["m"]},
                         q, k, v, i_g, f_g)
    h = _groupnorm_heads(h[:, None], p["gn_scale"])[:, 0]
    out = dense(p["w_down"], h * jax.nn.silu(gate), dt)
    return out, {"conv": conv_w.astype(state["conv"].dtype), **cell}


def make_slstm_block(key, d_model: int, n_heads: int, conv_width: int,
                     ffn_factor: float):
    ks = jax.random.split(key, 7)
    d_ff = int(d_model * ffn_factor)
    return {
        "conv": make_conv1d(ks[0], d_model, conv_width),
        "w_z": make_dense(ks[1], d_model, d_model),
        "w_i": make_dense(ks[2], d_model, d_model),
        "w_f": make_dense(ks[3], d_model, d_model),
        "w_o": make_dense(ks[4], d_model, d_model),
        "gn_scale": jnp.ones((d_model,), jnp.float32),
        "ffn_up": make_dense(ks[5], d_model, d_ff),
        "ffn_down": make_dense(ks[6], d_ff, d_model),
    }


def slstm_block_apply(p, x, n_heads: int, return_state: bool = False):
    dt = x.dtype
    c = jax.nn.silu(conv1d_causal(p["conv"], x))
    z = _heads(dense(p["w_z"], c, dt), n_heads)
    i = _heads(dense(p["w_i"], c, dt), n_heads)
    f = _heads(dense(p["w_f"], c, dt), n_heads)
    o = _heads(dense(p["w_o"], x, dt), n_heads)
    hs = slstm_apply(z, i, f, o, return_state=return_state)
    if return_state:
        hs, state = hs
    h = _groupnorm_heads(hs, p["gn_scale"])
    h = dense(p["ffn_down"], jax.nn.gelu(dense(p["ffn_up"], h, dt)), dt)
    if return_state:
        K = p["conv"]["w"].shape[0]
        return h, {"conv": x[:, -(K - 1):], **state}
    return h


def slstm_block_init_state(batch, d_model, n_heads, conv_width,
                           dtype=jnp.bfloat16):
    dh = d_model // n_heads
    z = jnp.zeros((batch, n_heads, dh), jnp.float32)
    return {"conv": jnp.zeros((batch, conv_width - 1, d_model), dtype),
            "c": z, "n": z, "m": jnp.full((batch, n_heads, dh), -1e30, jnp.float32)}


def slstm_block_step(p, state, x_t, n_heads: int):
    dt = x_t.dtype
    c_in, conv_w = conv1d_step(p["conv"], state["conv"].astype(dt), x_t)
    c_in = jax.nn.silu(c_in)
    B, D = x_t.shape
    hd = D // n_heads
    z = dense(p["w_z"], c_in, dt).reshape(B, n_heads, hd)
    i = dense(p["w_i"], c_in, dt).reshape(B, n_heads, hd)
    f = dense(p["w_f"], c_in, dt).reshape(B, n_heads, hd)
    o = dense(p["w_o"], x_t, dt).reshape(B, n_heads, hd)
    h, cell = slstm_step({"c": state["c"], "n": state["n"], "m": state["m"]},
                         z, i, f, o)
    h = _groupnorm_heads(h[:, None], p["gn_scale"])[:, 0]
    h = dense(p["ffn_down"], jax.nn.gelu(dense(p["ffn_up"], h, dt)), dt)
    return h, {"conv": conv_w.astype(state["conv"].dtype), **cell}
