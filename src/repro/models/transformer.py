"""Unified model: one parameterized block family covers all 10 assigned archs.

Layer kinds: "attn" (GQA + RoPE/M-RoPE/window/softcap), "mla" (DeepSeek-style
latent attention, absorbed-matrix decode), "rec" (Griffin RG-LRU block),
"mlstm"/"slstm" (xLSTM), "xattn" (whisper decoder: self + cross attention).
MLP-ness per layer: dense MLP, MoE, or MoE + dense residual (arctic).

Three entry points (all pure functions of (params, cfg, batch)):
  * ``forward``      — teacher-forced training forward -> final hidden (B,S,D)
  * ``prefill``      — forward + KV/recurrent cache construction
  * ``decode_step``  — one token against the cache

Layer stacking: consecutive layers with identical structure are grouped and
scanned (`lax.scan` over stacked params; per-layer window sizes ride along as
scanned data), so a 96-layer uniform stack compiles as one body.  Groups of
size < 2 are unrolled.  `jax.checkpoint` (remat) wraps the per-layer body
according to cfg.remat.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import recurrent as rec_mod
from repro.models.layers import (apply_mrope, apply_rope, dense, make_dense,
                                 make_mlp, make_norm, mlp, rmsnorm,
                                 sinusoidal_positions, softcap)
from repro.models.moe import make_moe, moe_apply_auto as moe_apply


# ---------------------------------------------------------------------------
# Layer plan / grouping
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSig:
    kind: str          # attn | mla | rec | mlstm | slstm | xattn
    mlp: str           # dense | moe | moe+dense | none


def layer_plan(cfg: ArchConfig) -> List[Tuple[LayerSig, int]]:
    """Per-layer (signature, window)."""
    plan = []
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn" and cfg.mla is not None:
            kind = "mla"
        if kind in ("rec", "mlstm", "slstm"):
            m = "none"
        elif cfg.moe is not None and i >= cfg.moe_layer_start:
            m = "moe+dense" if cfg.dense_ff_residual else "moe"
        else:
            m = "dense"
        plan.append((LayerSig(kind, m), cfg.window_for_layer(i)))
    return plan


def layer_groups(cfg: ArchConfig) -> List[Tuple[LayerSig, List[int], List[int]]]:
    """Consecutive runs of identical structure: (sig, layer_ids, windows)."""
    groups = []
    for i, (sig, w) in enumerate(layer_plan(cfg)):
        if groups and groups[-1][0] == sig:
            groups[-1][1].append(i)
            groups[-1][2].append(w)
        else:
            groups.append((sig, [i], [w]))
    return groups


# ---------------------------------------------------------------------------
# Per-layer param init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ArchConfig):
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {"wq": make_dense(ks[0], D, H * Dh),
            "wk": make_dense(ks[1], D, Hkv * Dh),
            "wv": make_dense(ks[2], D, Hkv * Dh),
            "wo": make_dense(ks[3], H * Dh, D)}


def _init_mla(key, cfg: ArchConfig):
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    dq = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {"wkv_a": make_dense(ks[0], D, m.kv_lora_rank + m.qk_rope_head_dim),
         "kv_norm": make_norm(m.kv_lora_rank),
         "wkv_b": make_dense(ks[1], m.kv_lora_rank,
                             H * (m.qk_nope_head_dim + m.v_head_dim)),
         "wo": make_dense(ks[2], H * m.v_head_dim, D)}
    if m.q_lora_rank:
        p["wq_a"] = make_dense(ks[3], D, m.q_lora_rank)
        p["q_norm"] = make_norm(m.q_lora_rank)
        p["wq_b"] = make_dense(ks[4], m.q_lora_rank, H * dq)
    else:
        p["wq"] = make_dense(ks[5], D, H * dq)
    return p


def _init_xattn(key, cfg: ArchConfig):
    D, H, Dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    return {"xnorm": make_norm(D),
            "xwq": make_dense(ks[0], D, H * Dh),
            "xwk": make_dense(ks[1], D, H * Dh),
            "xwv": make_dense(ks[2], D, H * Dh),
            "xwo": make_dense(ks[3], H * Dh, D)}


def _init_layer(key, cfg: ArchConfig, sig: LayerSig):
    ks = jax.random.split(key, 6)
    D = cfg.d_model
    p: Dict[str, Any] = {"norm": make_norm(D)}
    if sig.kind in ("attn", "xattn"):
        p["attn"] = _init_attn(ks[0], cfg)
        if sig.kind == "xattn":
            p.update(_init_xattn(ks[4], cfg))
    elif sig.kind == "mla":
        p["attn"] = _init_mla(ks[0], cfg)
    elif sig.kind == "rec":
        p["rec"] = rec_mod.make_rec_block(ks[0], D, cfg.rglru.lru_width,
                                          cfg.rglru.conv_width)
    elif sig.kind == "mlstm":
        p["mlstm"] = rec_mod.make_mlstm_block(ks[0], D, cfg.n_heads,
                                              cfg.xlstm.proj_factor_m,
                                              cfg.xlstm.conv_width)
    elif sig.kind == "slstm":
        p["slstm"] = rec_mod.make_slstm_block(ks[0], D, cfg.n_heads,
                                              cfg.xlstm.conv_width,
                                              cfg.xlstm.ffn_factor_s)
    else:
        raise ValueError(sig.kind)
    if cfg.post_norm:
        p["post_norm"] = make_norm(D)
    if sig.mlp != "none" and not cfg.parallel_block:
        p["norm2"] = make_norm(D)
    if sig.mlp in ("dense",) or (sig.mlp == "moe+dense"):
        p["mlp"] = make_mlp(ks[1], D, cfg.d_ff, cfg.mlp)
    if sig.mlp in ("moe", "moe+dense"):
        p["moe"] = make_moe(ks[2], D, cfg.moe, cfg.mlp)
    if cfg.post_norm and sig.mlp != "none":
        p["post_norm2"] = make_norm(D)
    return p


def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.padded_vocab
    params: Dict[str, Any] = {
        "embed": {"w": jax.random.normal(ks[0], (V, D), jnp.float32) * 0.01},
        "final_norm": make_norm(D),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = make_dense(ks[1], D, V)

    def stacked_group(key, sig, n):
        keys = jax.random.split(key, n)
        return jax.vmap(lambda k: _init_layer(k, cfg, sig))(keys)

    groups = {}
    gkeys = jax.random.split(ks[2], max(len(layer_groups(cfg)), 1))
    for gi, (sig, ids, _) in enumerate(layer_groups(cfg)):
        if len(ids) >= 2:
            groups[f"g{gi}"] = stacked_group(gkeys[gi], sig, len(ids))
        else:
            groups[f"g{gi}"] = _init_layer(gkeys[gi], cfg, sig)
    params["groups"] = groups

    if cfg.encdec is not None:
        ne = cfg.encdec.n_enc_layers
        ekeys = jax.random.split(ks[3], 2)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_layer(k, cfg, LayerSig("attn", "dense")))(
                jax.random.split(ekeys[0], ne)),
            "final_norm": make_norm(D),
        }
        params["dec_pos"] = {"w": jax.random.normal(ks[4], (32768, D), jnp.float32) * 0.01}
    return params


# ---------------------------------------------------------------------------
# Attention sub-layers (train/prefill path)
# ---------------------------------------------------------------------------

def _rope_qk(cfg, q, k, positions):
    if cfg.mrope_sections is not None:
        # positions: (3, B, S)
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _attn_apply(p, cfg: ArchConfig, x, window, positions, *, causal=True,
                use_rope=True, return_kv=False):
    B, S, D = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = dense(p["wq"], x, dt).reshape(B, S, H, Dh)
    k = dense(p["wk"], x, dt).reshape(B, S, Hkv, Dh)
    v = dense(p["wv"], x, dt).reshape(B, S, Hkv, Dh)
    if use_rope:
        q, k = _rope_qk(cfg, q, k, positions)
    o = attn_mod.chunked_attention(
        q, k, v, causal=causal, window=window, softcap=cfg.attn_logit_softcap,
        scale=cfg.attn_scale)
    out = dense(p["wo"], o.reshape(B, S, H * Dh), dt)
    if return_kv:
        return out, (k, v)
    return out


def _mla_expand_qkv(p, cfg: ArchConfig, x, positions):
    """Expanded (training/prefill) MLA: returns q, k, v and the latent cache."""
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    dt = x.dtype
    if m.q_lora_rank:
        cq = rmsnorm(p["q_norm"], dense(p["wq_a"], x, dt), cfg.norm_eps)
        q = dense(p["wq_b"], cq, dt).reshape(B, S, H, dn + dr)
    else:
        q = dense(p["wq"], x, dt).reshape(B, S, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    kv_a = dense(p["wkv_a"], x, dt)
    c_kv = rmsnorm(p["kv_norm"], kv_a[..., : m.kv_lora_rank], cfg.norm_eps)
    k_pe = kv_a[..., m.kv_lora_rank:].reshape(B, S, 1, dr)
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    k_pe = apply_rope(k_pe, positions, cfg.rope_theta)
    kv = dense(p["wkv_b"], c_kv, dt).reshape(B, S, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, (B, S, H, dr))], axis=-1)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    return q, k, v, (c_kv, k_pe[:, :, 0])


def _mla_apply(p, cfg: ArchConfig, x, window, positions, *, return_kv=False):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    dt = x.dtype
    q, k, v, cache = _mla_expand_qkv(p, cfg, x, positions)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    o = attn_mod.chunked_attention(q, k, v, causal=True, window=window,
                                   softcap=cfg.attn_logit_softcap, scale=scale)
    out = dense(p["wo"], o.reshape(B, S, H * m.v_head_dim), dt)
    if return_kv:
        return out, cache
    return out


def _mlp_apply(p, cfg: ArchConfig, sig: LayerSig, h, *, dropless=False):
    aux = jnp.float32(0.0)
    if sig.mlp == "dense":
        y = mlp(p["mlp"], h, cfg.mlp, h.dtype)
    elif sig.mlp == "moe":
        y, aux = moe_apply(p["moe"], h, cfg.moe, cfg.mlp, dropless=dropless)
    elif sig.mlp == "moe+dense":
        y, aux = moe_apply(p["moe"], h, cfg.moe, cfg.mlp, dropless=dropless)
        y = y + mlp(p["mlp"], h, cfg.mlp, h.dtype)
    else:
        y = jnp.zeros_like(h)
    return y, aux


# ---------------------------------------------------------------------------
# One layer (train/prefill path).  Returns (x, aux_loss, cache_entry).
# ---------------------------------------------------------------------------

def layer_apply(p, cfg: ArchConfig, sig: LayerSig, x, window, positions,
                *, enc_out=None, want_cache=False):
    dt = x.dtype
    aux = jnp.float32(0.0)
    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    cache_entry = None
    if sig.kind in ("attn", "xattn"):
        use_rope = cfg.encdec is None   # whisper: absolute positions, no rope
        if want_cache:
            a, (k, v) = _attn_apply(p["attn"], cfg, h, window, positions,
                                    use_rope=use_rope, return_kv=True)
            cache_entry = {"k": k, "v": v}
        else:
            a = _attn_apply(p["attn"], cfg, h, window, positions, use_rope=use_rope)
    elif sig.kind == "mla":
        if want_cache:
            a, (c_kv, k_pe) = _mla_apply(p["attn"], cfg, h, window, positions,
                                         return_kv=True)
            cache_entry = {"c_kv": c_kv, "k_pe": k_pe}
        else:
            a = _mla_apply(p["attn"], cfg, h, window, positions)
    elif sig.kind == "rec":
        a = rec_mod.rec_block_apply(p["rec"], h, cfg.rglru.c_exponent,
                                    return_state=want_cache)
        if want_cache:
            a, cache_entry = a
    elif sig.kind == "mlstm":
        a = rec_mod.mlstm_block_apply(p["mlstm"], h, cfg.n_heads,
                                      return_state=want_cache)
        if want_cache:
            a, cache_entry = a
    elif sig.kind == "slstm":
        a = rec_mod.slstm_block_apply(p["slstm"], h, cfg.n_heads,
                                      return_state=want_cache)
        if want_cache:
            a, cache_entry = a
    else:
        raise ValueError(sig.kind)

    if cfg.post_norm:
        a = rmsnorm(p["post_norm"], a, cfg.norm_eps)

    if cfg.parallel_block and sig.mlp != "none":
        y, aux = _mlp_apply(p, cfg, sig, h)
        x = x + a + y
    else:
        x = x + a
        if sig.kind == "xattn":
            hx = rmsnorm(p["xnorm"], x, cfg.norm_eps)
            B, S, D = hx.shape
            H, Dh = cfg.n_heads, cfg.head_dim
            q = dense(p["xwq"], hx, dt).reshape(B, S, H, Dh)
            k = dense(p["xwk"], enc_out, dt).reshape(B, enc_out.shape[1], H, Dh)
            v = dense(p["xwv"], enc_out, dt).reshape(B, enc_out.shape[1], H, Dh)
            o = attn_mod.chunked_attention(q, k, v, causal=False, window=None)
            x = x + dense(p["xwo"], o.reshape(B, S, H * Dh), dt)
        if sig.mlp != "none":
            h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
            y, aux = _mlp_apply(p, cfg, sig, h2)
            if cfg.post_norm:
                y = rmsnorm(p["post_norm2"], y, cfg.norm_eps)
            x = x + y
    return x, aux, cache_entry


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _pin_batch_sharding(x):
    """Pin the residual stream to batch-over-data sharding.

    Without this, GSPMD may redistribute activations inside the FSDP layer
    loop (observed on nemotron-340b: fp32 all-reduces of batch-REPLICATED
    activation tensors, 21 TiB of wire per step — §Perf cell B).  A
    constraint at every layer boundary makes batch sharding a fixed point
    of the propagation.
    """
    from repro.distributed.context import get_parallel
    ctx = get_parallel()
    if ctx is None or x.shape[0] % ctx.mesh.shape[ctx.dp_axes[0]]:
        return x
    spec = jax.sharding.PartitionSpec(ctx.dp_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ctx.mesh, spec))


def run_stack(params, cfg: ArchConfig, x, positions, *, enc_out=None,
              want_cache=False):
    """Run all layer groups.  Returns (x, total_aux, cache dict)."""
    total_aux = jnp.float32(0.0)
    cache: Dict[str, Any] = {}
    for gi, (sig, ids, windows) in enumerate(layer_groups(cfg)):
        gp = params["groups"][f"g{gi}"]
        warr = jnp.array(windows, jnp.int32)
        if len(ids) >= 2:
            def body(xc, scanned, sig=sig):
                lp, w = scanned
                xo, aux, ce = layer_apply(lp, cfg, sig, xc, w, positions,
                                          enc_out=enc_out, want_cache=want_cache)
                return _pin_batch_sharding(xo), (aux, ce)
            body = _maybe_remat(body, cfg)
            x, (auxs, ces) = jax.lax.scan(body, x, (gp, warr))
            total_aux = total_aux + auxs.sum()
            if want_cache and ces is not None:
                cache[f"g{gi}"] = ces
        else:
            def body1(xc, lp, sig=sig, w=windows[0]):
                return layer_apply(lp, cfg, sig, xc, jnp.int32(w), positions,
                                   enc_out=enc_out, want_cache=want_cache)
            body1 = _maybe_remat(body1, cfg)
            x, aux, ce = body1(x, gp)
            total_aux = total_aux + aux
            if want_cache and ce is not None:
                cache[f"g{gi}"] = ce
    return x, total_aux, cache


def _embed_in(params, cfg: ArchConfig, batch):
    dt = jnp.dtype(cfg.dtype)
    if cfg.embed_inputs == "embeds":
        x = batch["embeds"].astype(dt)
        positions = batch["positions"]          # (3, B, S) for M-RoPE
    else:
        tokens = batch["tokens"]
        x = params["embed"]["w"].astype(dt)[tokens]
        S = tokens.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    return x, positions


def _encoder_out(params, cfg: ArchConfig, frames):
    """Whisper encoder over stub frame embeddings (B, T, D)."""
    dt = jnp.dtype(cfg.dtype)
    x = frames.astype(dt) + sinusoidal_positions(frames.shape[1],
                                                 cfg.d_model).astype(dt)[None]
    sig = LayerSig("attn", "dense")

    def body(xc, lp):
        h = rmsnorm(lp["norm"], xc, cfg.norm_eps)
        a = _attn_apply(lp["attn"], cfg, h, None, None, causal=False,
                        use_rope=False)
        xc = xc + a
        h2 = rmsnorm(lp["norm2"], xc, cfg.norm_eps)
        y = mlp(lp["mlp"], h2, cfg.mlp, dt)
        return xc + y, None
    body = _maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def forward(params, cfg: ArchConfig, batch):
    """Training forward: final hidden states (B, S, D) + aux loss."""
    x, positions = _embed_in(params, cfg, batch)
    enc_out = None
    if cfg.encdec is not None:
        enc_out = _encoder_out(params, cfg, batch["frames"])
        S = batch["tokens"].shape[1]
        x = x + params["dec_pos"]["w"].astype(x.dtype)[:S][None]
        positions = None
    x, aux, _ = run_stack(params, cfg, x, positions, enc_out=enc_out)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def logits_from_hidden(params, cfg: ArchConfig, x):
    if cfg.tie_embeddings:
        w = params["embed"]["w"].astype(x.dtype).T
        logits = x @ w
    else:
        logits = dense(params["lm_head"], x, x.dtype)
    return softcap(logits, cfg.final_logit_softcap)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def prefill(params, cfg: ArchConfig, batch):
    """Returns (cache, last-token logits).

    The cache holds per-group KV (padded to max_len via decode-side concat —
    here exact-length; the serve engine pre-pads) or recurrent state.
    """
    x, positions = _embed_in(params, cfg, batch)
    enc_out = None
    if cfg.encdec is not None:
        enc_out = _encoder_out(params, cfg, batch["frames"])
        S = batch["tokens"].shape[1]
        x = x + params["dec_pos"]["w"].astype(x.dtype)[:S][None]
        positions = None
    x, _, cache = run_stack(params, cfg, x, positions, enc_out=enc_out,
                            want_cache=True)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x[:, -1:])
    if cfg.encdec is not None:
        cache["enc_out"] = enc_out
    return cache, logits


def init_decode_cache(cfg: ArchConfig, batch_size: int, max_len: int,
                      dtype=jnp.bfloat16):
    """Shape-only cache initializer (used by serve_step dry-runs and engine)."""
    cache: Dict[str, Any] = {}
    for gi, (sig, ids, _) in enumerate(layer_groups(cfg)):
        n = len(ids)

        def stack(tree):
            if n >= 2:
                return jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), tree)
            return tree
        if sig.kind in ("attn", "xattn"):
            ent = {"k": jnp.zeros((batch_size, max_len, cfg.n_kv_heads,
                                   cfg.head_dim), dtype),
                   "v": jnp.zeros((batch_size, max_len, cfg.n_kv_heads,
                                   cfg.head_dim), dtype)}
        elif sig.kind == "mla":
            m = cfg.mla
            ent = {"c_kv": jnp.zeros((batch_size, max_len, m.kv_lora_rank), dtype),
                   "k_pe": jnp.zeros((batch_size, max_len, m.qk_rope_head_dim),
                                     dtype)}
        elif sig.kind == "rec":
            ent = rec_mod.rec_block_init_state(batch_size, cfg.rglru.lru_width,
                                               cfg.rglru.conv_width, dtype)
        elif sig.kind == "mlstm":
            ent = rec_mod.mlstm_block_init_state(
                batch_size, cfg.d_model, cfg.n_heads,
                cfg.xlstm.proj_factor_m, cfg.xlstm.conv_width, dtype)
        elif sig.kind == "slstm":
            ent = rec_mod.slstm_block_init_state(batch_size, cfg.d_model,
                                                 cfg.n_heads, cfg.xlstm.conv_width,
                                                 dtype)
        cache[f"g{gi}"] = stack(ent)
    if cfg.encdec is not None:
        cache["enc_out"] = jnp.zeros(
            (batch_size, cfg.encdec.n_frames, cfg.d_model), dtype)
    return cache


def _decode_attn(p, cfg: ArchConfig, h, ce, cache_len, window, position):
    """One-token GQA attention against the cache; updates cache in place."""
    B = h.shape[0]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = h.dtype
    q = dense(p["wq"], h, dt).reshape(B, 1, H, Dh)
    k = dense(p["wk"], h, dt).reshape(B, 1, Hkv, Dh)
    v = dense(p["wv"], h, dt).reshape(B, 1, Hkv, Dh)
    if cfg.encdec is None:
        pos = jnp.broadcast_to(jnp.asarray(position), (B,))[:, None]
        if cfg.mrope_sections is not None:
            pos3 = jnp.broadcast_to(pos[None], (3, B, 1))
            q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
    bidx = jnp.arange(B)
    lens = jnp.broadcast_to(jnp.asarray(cache_len), (B,))
    k_cache = ce["k"].at[bidx, lens].set(k[:, 0].astype(ce["k"].dtype))
    v_cache = ce["v"].at[bidx, lens].set(v[:, 0].astype(ce["v"].dtype))
    o = attn_mod.decode_attention(q, k_cache, v_cache, lens + 1,
                                  window=window, softcap=cfg.attn_logit_softcap,
                                  scale=cfg.attn_scale)
    out = dense(p["wo"], o.reshape(B, 1, H * Dh)[:, 0], dt)
    return out, {"k": k_cache, "v": v_cache}


def _decode_mla(p, cfg: ArchConfig, h, ce, cache_len, position):
    """Absorbed-matrix MLA decode: scores and context in the latent space.

    Never expands the per-head K/V for cached positions — the cache stays
    (B, S, r) + (B, S, dr), the MLA serving advantage.
    """
    m = cfg.mla
    B = h.shape[0]
    H = cfg.n_heads
    dn, dr, dv, r = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim, m.kv_lora_rank
    dt = h.dtype
    if m.q_lora_rank:
        cq = rmsnorm(p["q_norm"], dense(p["wq_a"], h, dt), cfg.norm_eps)
        q = dense(p["wq_b"], cq, dt).reshape(B, H, dn + dr)
    else:
        q = dense(p["wq"], h, dt).reshape(B, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    pos = jnp.broadcast_to(jnp.asarray(position), (B,))[:, None]
    q_pe = apply_rope(q_pe[:, None], pos, cfg.rope_theta)[:, 0]      # (B,H,dr)

    kv_a = dense(p["wkv_a"], h, dt)
    c_kv_new = rmsnorm(p["kv_norm"], kv_a[..., :r], cfg.norm_eps)
    k_pe_new = apply_rope(kv_a[..., r:][:, None, None], pos,
                          cfg.rope_theta)[:, 0, 0]                    # (B,dr)
    bidx = jnp.arange(B)
    lens = jnp.broadcast_to(jnp.asarray(cache_len), (B,))
    c_cache = ce["c_kv"].at[bidx, lens].set(c_kv_new.astype(ce["c_kv"].dtype))
    pe_cache = ce["k_pe"].at[bidx, lens].set(k_pe_new.astype(ce["k_pe"].dtype))

    # Absorb W_UK into the query: q_lat[b,h,r] = sum_dn q_nope * W_uk[r,h,dn]
    wkv_b = p["wkv_b"]["w"].astype(jnp.float32).reshape(r, H, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32), w_uk)
    scale = (dn + dr) ** -0.5
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, c_cache.astype(jnp.float32))
         + jnp.einsum("bhd,bsd->bhs", q_pe.astype(jnp.float32),
                      pe_cache.astype(jnp.float32))) * scale
    s = softcap(s, cfg.attn_logit_softcap)
    Smax = c_cache.shape[1]
    valid = jnp.arange(Smax)[None, :] < (lens + 1)[:, None]
    s = jnp.where(valid[:, None, :], s, attn_mod.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", pr, c_cache.astype(jnp.float32))
    o = jnp.einsum("bhr,rhd->bhd", ctx_lat, w_uv)                    # (B,H,dv)
    out = dense(p["wo"], o.reshape(B, H * dv).astype(dt), dt)
    return out, {"c_kv": c_cache, "k_pe": pe_cache}


def decode_layer(p, cfg: ArchConfig, sig: LayerSig, x, ce, cache_len, window,
                 *, enc_cache=None):
    """x: (B, D) one token.  Returns (x', cache_entry')."""
    dt = x.dtype
    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    if sig.kind in ("attn", "xattn"):
        a, ce_new = _decode_attn(p["attn"], cfg, h[:, None], ce, cache_len,
                                 window, cache_len)
    elif sig.kind == "mla":
        a, ce_new = _decode_mla(p["attn"], cfg, h, ce, cache_len, cache_len)
    elif sig.kind == "rec":
        a, ce_new = rec_mod.rec_block_step(p["rec"], ce, h, cfg.rglru.c_exponent)
    elif sig.kind == "mlstm":
        a, ce_new = rec_mod.mlstm_block_step(p["mlstm"], ce, h, cfg.n_heads)
    elif sig.kind == "slstm":
        a, ce_new = rec_mod.slstm_block_step(p["slstm"], ce, h, cfg.n_heads)
    else:
        raise ValueError(sig.kind)
    if cfg.post_norm:
        a = rmsnorm(p["post_norm"], a, cfg.norm_eps)

    if cfg.parallel_block and sig.mlp != "none":
        y, _ = _mlp_apply(p, cfg, sig, h, dropless=True)
        x = x + a + y
    else:
        x = x + a
        if sig.kind == "xattn":
            hx = rmsnorm(p["xnorm"], x, cfg.norm_eps)
            B = hx.shape[0]
            H, Dh = cfg.n_heads, cfg.head_dim
            T = enc_cache.shape[1]
            q = dense(p["xwq"], hx, dt).reshape(B, 1, H, Dh)
            k = dense(p["xwk"], enc_cache, dt).reshape(B, T, H, Dh)
            v = dense(p["xwv"], enc_cache, dt).reshape(B, T, H, Dh)
            o = attn_mod.decode_attention(q, k, v, jnp.full((B,), T))
            x = x + dense(p["xwo"], o.reshape(B, H * Dh), dt)
        if sig.mlp != "none":
            h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
            y, _ = _mlp_apply(p, cfg, sig, h2, dropless=True)
            if cfg.post_norm:
                y = rmsnorm(p["post_norm2"], y, cfg.norm_eps)
            x = x + y
    return x, ce_new


def decode_step(params, cfg: ArchConfig, cache, tokens, cache_len):
    """One decode step.  tokens: (B,) int32 (or embeds (B, D) for vlm stub).

    Returns (new_cache, logits (B, V)).
    """
    dt = jnp.dtype(cfg.dtype)
    if cfg.embed_inputs == "embeds":
        x = tokens.astype(dt)
    else:
        x = params["embed"]["w"].astype(dt)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.encdec is not None:
        pos = jnp.broadcast_to(jnp.asarray(cache_len), (x.shape[0],))
        x = x + params["dec_pos"]["w"].astype(dt)[pos]
    enc_cache = cache.get("enc_out")
    new_cache = dict(cache)
    for gi, (sig, ids, windows) in enumerate(layer_groups(cfg)):
        gp = params["groups"][f"g{gi}"]
        ce = cache[f"g{gi}"]
        if len(ids) >= 2:
            warr = jnp.array(windows, jnp.int32)

            def body(xc, scanned, sig=sig):
                lp, ce_l, w = scanned
                xo, ce_new = decode_layer(lp, cfg, sig, xc, ce_l, cache_len, w,
                                          enc_cache=enc_cache)
                return xo, ce_new
            x, ce_out = jax.lax.scan(body, x, (gp, ce, warr))
        else:
            x, ce_out = decode_layer(gp, cfg, sig, x, ce, cache_len,
                                     jnp.int32(windows[0]), enc_cache=enc_cache)
        new_cache[f"g{gi}"] = ce_out
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x)
    return new_cache, logits
