"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step, shard) — a restarted run
replays the exact same stream (the checkpoint/restart fault-tolerance
story depends on this), and each data-parallel host shard draws a disjoint
slice.  Token streams are Zipf-ish synthetic text; vision/audio stubs draw
Gaussian embeddings (the assignment supplies frontends as stubs).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.2


def _rng(cfg: DataConfig, step: int, shard: int):
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))


def synth_tokens(rng, batch, seq, vocab, zipf_a=1.2):
    """Zipf-distributed token ids (shape (batch, seq)) in [2, vocab)."""
    raw = rng.zipf(zipf_a, size=(batch, seq)).astype(np.int64)
    return (2 + (raw - 1) % max(vocab - 2, 1)).astype(np.int32)


def batch_for_step(cfg: ArchConfig, shape: ShapeSpec, step: int,
                   data_cfg: Optional[DataConfig] = None,
                   shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
    """The training/prefill batch for `step` (this shard's slice)."""
    dc = data_cfg or DataConfig()
    rng = _rng(dc, step, shard)
    B = shape.global_batch // n_shards
    S = shape.seq_len
    out: Dict[str, np.ndarray] = {}
    if cfg.embed_inputs == "embeds":
        out["embeds"] = rng.standard_normal((B, S, cfg.d_model), np.float32)
        # M-RoPE grid: text tokens have t=h=w=index (the vision stub would
        # supply patch (t, h, w) triplets)
        pos = np.broadcast_to(np.arange(S, dtype=np.int32)[None, None],
                              (3, B, S)).copy()
        out["positions"] = pos
    else:
        out["tokens"] = synth_tokens(rng, B, S, cfg.vocab_size, dc.zipf_a)
    if cfg.encdec is not None:
        out["frames"] = rng.standard_normal(
            (B, cfg.encdec.n_frames, cfg.d_model), np.float32)
    if shape.kind == "train":
        src = out.get("tokens")
        if src is None:
            out["labels"] = synth_tokens(rng, B, S, cfg.vocab_size, dc.zipf_a)
        else:
            out["labels"] = np.concatenate(
                [src[:, 1:], np.full((B, 1), 2, np.int32)], axis=1)
    return out


class DataIterator:
    """Stateful wrapper; `state` is just the step counter (checkpointable)."""

    def __init__(self, cfg: ArchConfig, shape: ShapeSpec,
                 data_cfg: Optional[DataConfig] = None,
                 shard: int = 0, n_shards: int = 1, start_step: int = 0):
        self.cfg, self.shape = cfg, shape
        self.data_cfg = data_cfg or DataConfig()
        self.shard, self.n_shards = shard, n_shards
        self.step = start_step

    def __next__(self):
        b = batch_for_step(self.cfg, self.shape, self.step, self.data_cfg,
                           self.shard, self.n_shards)
        self.step += 1
        return b

    def state(self):
        return {"step": self.step}

    def restore(self, state):
        self.step = int(state["step"])
