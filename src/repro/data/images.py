"""Synthetic "tissue" image generator for IWPP benchmarks and tests.

The paper evaluates on whole-slide tissue images with varying tissue
coverage (Fig. 12: 25/50/75/100%).  We reproduce the workload shape with
blob images: smoothed thresholded noise gives connected tissue-like regions;
``coverage`` controls the foreground fraction; the marker is the standard
``I - h`` marker (mask minus a constant, clipped), which makes morphological
reconstruction fill regional maxima domes — the paper's segmentation use.
"""

from __future__ import annotations

import numpy as np


def _smooth(x: np.ndarray, iters: int = 3) -> np.ndarray:
    """Cheap separable box smoothing (no scipy)."""
    for _ in range(iters):
        x = (x + np.roll(x, 1, 0) + np.roll(x, -1, 0)) / 3.0
        x = (x + np.roll(x, 1, 1) + np.roll(x, -1, 1)) / 3.0
    return x


def tissue_image(h: int, w: int, coverage: float = 1.0, seed: int = 0,
                 dtype=np.uint8):
    """Returns (marker, mask) uint8 images with ~`coverage` foreground."""
    rng = np.random.default_rng(seed)
    noise = _smooth(rng.random((h, w)), iters=4)
    thresh = np.quantile(noise, 1.0 - coverage) if coverage < 1.0 else -np.inf
    fg = noise >= thresh
    lo, hi = noise.min(), noise.max()
    gray = ((noise - lo) / max(hi - lo, 1e-9) * 200 + 30).astype(dtype)
    mask = np.where(fg, gray, 0).astype(dtype)
    h_drop = 40
    marker = np.clip(mask.astype(np.int32) - h_drop, 0, None).astype(dtype)
    return marker, mask


def binary_blobs(h: int, w: int, coverage: float = 0.5, seed: int = 0,
                 scale: int = 4):
    """Boolean foreground image for the EDT benchmarks.  ``scale`` sets the
    blob feature size (smoothing depth): larger scale -> larger connected
    regions -> deeper distance propagation (the whole-slide-tissue regime)."""
    rng = np.random.default_rng(seed)
    noise = _smooth(rng.random((h, w)), iters=scale)
    thresh = np.quantile(noise, 1.0 - coverage)
    return noise >= thresh


def bg_disks(h: int, w: int, coverage: float = 0.9, n_disks: int = 6,
             seed: int = 0):
    """Foreground image whose background is a few concentrated disks
    (total area ~ (1 - coverage) of the image).  Distances inside the
    foreground then reach O(image size) — the whole-slide regime the paper
    evaluates EDT on (their Fig. 14: speedups GROW with tissue coverage
    because distances get longer)."""
    rng = np.random.default_rng(seed)
    fg = np.ones((h, w), bool)
    r = int(np.sqrt((1.0 - coverage) * h * w / (max(n_disks, 1) * np.pi)))
    yy, xx = np.mgrid[0:h, 0:w]
    for _ in range(n_disks):
        cy, cx = rng.integers(0, h), rng.integers(0, w)
        fg &= ((yy - cy) ** 2 + (xx - cx) ** 2) > r * r
    return fg


def seeded_marker(mask: np.ndarray, n_seeds: int = 32, patch: int = 3,
                  seed: int = 0):
    """Sparse-seed marker: the paper's reconstruction-from-markers workload
    (Fig. 1: small marker patches inside objects).  The wavefront is a thin
    expanding ring — the regime where queue/tile tracking beats full sweeps
    hardest (in contrast to the dense ``mask - h`` marker, whose initial
    wavefront covers the whole image)."""
    rng = np.random.default_rng(seed)
    marker = np.zeros_like(mask)
    fg = np.argwhere(mask > 0)
    if len(fg) == 0:
        return marker
    for idx in rng.choice(len(fg), size=min(n_seeds, len(fg)), replace=False):
        r, c = fg[idx]
        r0, c0 = max(0, r - patch), max(0, c - patch)
        marker[r0:r + patch, c0:c + patch] = mask[r0:r + patch, c0:c + patch]
    return marker
