"""Training launcher: mesh + sharded params/opt + checkpoint/restart loop.

CPU-scale entry point (the production mesh path is exercised by dryrun.py):

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Restart-from-latest is automatic: if --ckpt-dir holds a checkpoint, params,
optimizer and the data-iterator step are restored and the run continues
deterministically (the data pipeline is a pure function of the step).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config, smoke_config
from repro.ckpt.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.data.pipeline import DataIterator
from repro.models.transformer import init_params
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps)

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start, state, extra = restore(
            args.ckpt_dir, like={"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        print(f"[train] restored step {start} from {args.ckpt_dir}")

    it = DataIterator(cfg, shape, start_step=start)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, args.microbatches))
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None

    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % args.log_every == 0 or step == start:
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            print(f"[train] step {step + 1} loss={m['loss']:.4f} "
                  f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e} ({dt:.1f}s)")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state})
        ckpt.wait()
        print(f"[train] final checkpoint at step {args.steps}")
    return params, opt_state


if __name__ == "__main__":
    main()
