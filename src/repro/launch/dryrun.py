import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede every other import: jax locks the device count on first init.
# (An explicit device-count in XLA_FLAGS — e.g. the 8-device test harness —
# takes precedence; the production dry-run default is 512.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds ShapeDtypeStruct inputs (no allocation), jits the
train/prefill/serve step with production in_shardings, runs
``.lower().compile()``, and records:

  * ``compiled.memory_analysis()``   — proves the per-device footprint fits;
  * ``compiled.cost_analysis()``     — HLO FLOPs / bytes for the roofline;
  * collective statistics parsed from the post-SPMD HLO text — per-op-kind
    wire-byte estimates (ring all-reduce counts 2x payload, all-gather /
    reduce-scatter / all-to-all / collective-permute 1x), the roofline's
    collective term;
  * wall times for lowering and compile.

Results land in results/dryrun/<mesh>/<arch>__<shape>.json, consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all            # every cell, both meshes
    python -m repro.launch.dryrun --all --mesh multi
"""

import argparse
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, runnable_shapes
from repro.configs.registry import ARCHS, get_config, input_specs
from repro.distributed.context import ParallelCtx, parallel_ctx
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import init_decode_cache, init_params
from repro.train.optim import init_opt_state
from repro.train.step import make_prefill_step, make_serve_step, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Total bytes of all typed shapes in an HLO result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str):
    """Per-kind (count, result bytes, wire-byte estimate) from HLO text."""
    stats = {k: {"count": 0, "bytes": 0, "wire_bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^=]*?)\s*"
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start)?\(", s)
        if not m:
            continue
        kind = m.group(2)
        if m.group(3) and f"{kind}-done" in hlo_text:
            pass  # async pair: count the -start only
        if re.match(r"%?[\w.\-]+\s*=\s*[^=]*" + kind + r"-done\(", s):
            continue
        result_bytes = _shape_bytes(m.group(1))
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += result_bytes
        factor = 2.0 if kind == "all-reduce" else 1.0
        stats[kind]["wire_bytes"] += int(result_bytes * factor)
    stats["total_wire_bytes"] = sum(
        v["wire_bytes"] for k, v in stats.items() if isinstance(v, dict))
    return stats


def _mem_analysis(compiled):
    out = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "host_argument_size_in_bytes",
                  "host_output_size_in_bytes", "host_temp_size_in_bytes",
                  "serialized_size_in_bytes"):
            if hasattr(ma, f):
                out[f] = int(getattr(ma, f))
    except Exception as e:  # noqa: BLE001
        out["error"] = repr(e)
    return out


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and not k.startswith("utilization")}
    except Exception as e:  # noqa: BLE001
        return {"error": repr(e)}


def build_cell(arch: str, shape_name: str, mesh, *, microbatches: int = 0):
    """Returns (fn, args, in_shardings) ready for jit().lower().

    microbatches=0 -> the arch's production default (cfg.train_microbatches).
    """
    cfg = get_config(arch)
    if microbatches <= 0:
        microbatches = cfg.train_microbatches
    shape = SHAPES[shape_name]
    baxes = shd.batch_axes(mesh)
    ctx = ParallelCtx(mesh=mesh, dp_axes=baxes)

    key_s = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    params_s = jax.eval_shape(partial(init_params, cfg), key_s)
    pspecs = shd.param_specs(cfg, params_s, mesh)
    pshard = shd.named(mesh, pspecs)

    bspecs_in = input_specs(cfg, shape)
    bshard = shd.named(mesh, shd.batch_specs(cfg, bspecs_in, mesh))

    if shape.kind == "train":
        opt_s = jax.eval_shape(init_opt_state, params_s)
        # m/v shaped like params; step replicated
        oshard = {"m": shd.named(mesh, shd.param_specs(cfg, params_s, mesh)),
                  "v": shd.named(mesh, shd.param_specs(cfg, params_s, mesh)),
                  "step": shd.named(mesh, jax.sharding.PartitionSpec())}
        fn = make_train_step(cfg, microbatches=microbatches)
        args = (params_s, opt_s, bspecs_in)
        in_sh = (pshard, oshard, bshard)
        out_sh = (pshard, oshard, None)
        donate = (0, 1)
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        args = (params_s, bspecs_in)
        in_sh = (pshard, bshard)
        out_sh = None
        donate = ()
    else:  # decode
        cache_s = jax.eval_shape(
            partial(init_decode_cache, cfg, shape.global_batch, shape.seq_len))
        cshard = shd.named(mesh, shd.cache_specs(cfg, cache_s, mesh))
        tok_s = bspecs_in["tokens"]
        tshard = shd.named(mesh, shd.batch_specs(cfg, {"tokens": tok_s}, mesh))["tokens"]
        len_s = jax.ShapeDtypeStruct((), jnp.int32)
        fn = make_serve_step(cfg)
        args = (params_s, cache_s, tok_s, len_s)
        in_sh = (pshard, cshard, tshard,
                 shd.named(mesh, jax.sharding.PartitionSpec()))
        out_sh = (cshard, None)
        donate = (1,)
    return cfg, ctx, fn, args, in_sh, out_sh, donate


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             *, save_hlo: bool = False, tag: str = "",
             microbatches: int = 0) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rec = {"arch": arch, "shape": shape_name, "mesh": list(mesh.shape.values()),
           "mesh_axes": list(mesh.axis_names), "status": "ok", "tag": tag}
    cfg = get_config(arch)
    if shape_name not in runnable_shapes(cfg):
        rec["status"] = "skip:full-attention-500k"
        return _save(rec, out_dir, mesh_kind, arch, shape_name, tag)
    try:
        cfg, ctx, fn, args, in_sh, out_sh, donate = build_cell(
            arch, shape_name, mesh, microbatches=microbatches)
        with parallel_ctx(ctx), mesh:
            jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate)
            t0 = time.perf_counter()
            lowered = jfn.lower(*args)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        rec["memory_analysis"] = _mem_analysis(compiled)
        rec["cost_analysis"] = _cost_analysis(compiled)
        hlo = compiled.as_text()
        rec["collectives"] = collective_stats(hlo)
        rec["hlo_lines"] = hlo.count("\n")
        # loop-aware cost model (cost_analysis counts scan bodies once)
        try:
            from repro.launch import hlocost
            hc = hlocost.analyze(hlo)
            rec["hlo_cost"] = {"flops": hc["flops"], "bytes": hc["bytes"],
                               "collectives": hc["coll"],
                               "n_warnings": hc["n_warnings"],
                               "warnings": hc["warnings"]}
        except Exception as e:  # noqa: BLE001
            rec["hlo_cost"] = {"error": repr(e)}
        # persist the HLO (gzip) so analyses never need a recompile
        import gzip
        hdir = os.path.join(out_dir, mesh_kind)
        os.makedirs(hdir, exist_ok=True)
        with gzip.open(os.path.join(
                hdir, f"{arch}__{shape_name}{tag}.hlo.txt.gz"), "wt") as f:
            f.write(hlo)
        if save_hlo:
            with open(os.path.join(hdir, f"{arch}__{shape_name}{tag}.hlo.txt"),
                      "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = repr(e)
        rec["traceback"] = traceback.format_exc()[-4000:]
    return _save(rec, out_dir, mesh_kind, arch, shape_name, tag)


def _save(rec, out_dir, mesh_kind, arch, shape_name, tag=""):
    d = os.path.join(out_dir, mesh_kind)
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{arch}__{shape_name}{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    mem = rec.get("memory_analysis", {})
    coll = rec.get("collectives", {})
    print(f"[dryrun] {mesh_kind:6s} {arch:24s} {shape_name:12s} "
          f"{rec['status']:8s} compile={rec.get('compile_s', 0):.1f}s "
          f"temp={mem.get('temp_size_in_bytes', 0) / 2**30:.2f}GiB "
          f"coll={coll.get('total_wire_bytes', 0) / 2**30:.3f}GiB",
          flush=True)
    return rec


def reanalyze(out_dir: str):
    """Recompute hlo_cost for every saved .hlo.txt.gz (no recompiles)."""
    import glob
    import gzip
    from repro.launch import hlocost
    for hpath in sorted(glob.glob(os.path.join(out_dir, "*", "*.hlo.txt.gz"))):
        jpath = hpath.replace(".hlo.txt.gz", ".json")
        if not os.path.exists(jpath):
            continue
        rec = json.load(open(jpath))
        hc = hlocost.analyze(gzip.open(hpath, "rt").read())
        rec["hlo_cost"] = {"flops": hc["flops"], "bytes": hc["bytes"],
                           "collectives": hc["coll"],
                           "n_warnings": hc["n_warnings"],
                           "warnings": hc["warnings"]}
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[reanalyze] {jpath}", flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for perf-iteration runs")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = per-arch production default")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute hlo_cost from saved HLOs, no compiles")
    args = ap.parse_args(argv)
    if args.reanalyze:
        reanalyze(args.out)
        return 0

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list(ARCHS) if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]

    n_err = 0
    for mk in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, mk, args.out, save_hlo=args.save_hlo,
                               tag=args.tag, microbatches=args.microbatches)
                n_err += rec["status"] == "error"
    print(f"[dryrun] done, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
