"""Production meshes.  Functions, not module constants: importing this
module never touches jax device state (required by the dry-run protocol)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2):
    """Small mesh over host CPU devices (tests / examples).

    Requires XLA_FLAGS=--xla_force_host_platform_device_count>=data*model
    to have been set before jax initialized.
    """
    return jax.make_mesh((data, model), ("data", "model"))
