"""Loop-aware cost model over post-SPMD HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE — under
scan-over-layers / scan-over-microbatches that undercounts FLOPs, bytes and
collectives by orders of magnitude (verified: a 7-trip scanned matmul
reports 1x the body flops).  This module re-derives the three roofline
inputs by parsing the HLO and weighting every computation by its loop trip
count:

  * flops       — exact for `dot` (2 x out_elems x contraction size, batch
                  dims included); elementwise/fusion ops nominally
                  1 flop / output element; dots inside fusions are counted
                  by descending into the called computation.
  * bytes       — per-op output + operand bytes (fusions as single ops:
                  their internals live in registers), the HBM-traffic model;
  * collectives — per-kind counts / payload / wire bytes (ring all-reduce
                  2x payload, others 1x), weighted by loop multiplicity.

Trip counts come from the loop condition's scalar s32 `constant(N)` feeding
the LT compare (the canonical lax.scan/fori lowering).  Loops whose bound
cannot be parsed get multiplicity 1 and are reported in ``warnings``.
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_OP_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")


def _parse_op_line(line: str):
    """(name, result_type, opcode) or None.  Handles tuple result types with
    embedded /*index=N*/ comments via balanced-paren scanning."""
    m = _OP_HEAD_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    if i >= len(line):
        return None
    if line[i] == "(":                       # tuple type
        depth = 0
        j = i
        while j < len(line):
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        rtype = line[i:j + 1]
        i = j + 1
    else:
        j = line.find(" ", i)
        if j < 0:
            return None
        rtype = line[i:j]
        i = j
    mo = re.match(r"\s*([\w\-]+)\(", line[i:])
    if not mo:
        return None
    return name, rtype, mo.group(1)

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "partition-id", "replica-id", "iota", "broadcast"}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems(type_str: str) -> int:
    total = 0
    for _, dims in _dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def _operands(line: str, opcode: str) -> List[str]:
    """%refs inside the opcode's balanced paren group."""
    k = line.find(opcode + "(", line.index("=") + 1)
    if k < 0:
        return []
    i = k + len(opcode)
    depth = 0
    end = len(line)
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                end = j
                break
    return re.findall(r"%([\w.\-]+)", line[i:end])


class Op:
    __slots__ = ("name", "rtype", "opcode", "line", "operands")

    def __init__(self, name, rtype, opcode, line):
        self.name, self.rtype, self.opcode, self.line = name, rtype, opcode, line
        self.operands = _operands(line, opcode)


def _split_top(s: str) -> List[str]:
    """Split on commas at zero bracket/paren/brace depth."""
    out, depth, start = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(s[start:i])
            start = i + 1
    tail = s[start:].strip()
    if tail:
        out.append(tail)
    return out


class Computation:
    def __init__(self, name, params_str):
        self.name = name
        self.ops: List[Op] = []
        self.symbols: Dict[str, str] = {}
        self.params: List[str] = []
        for part in _split_top(params_str):
            m = re.match(r"\s*(?:/\*[^*]*\*/)?\s*%?([\w.\-]+)\s*:\s*(.+)",
                         part.strip())
            if m:
                self.symbols[m.group(1)] = m.group(2)
                self.params.append(m.group(1))


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = Computation(mc.group(1), mc.group(2))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        parsed = _parse_op_line(line)
        if parsed:
            op = Op(parsed[0], parsed[1], parsed[2], line.rstrip())
            cur.ops.append(op)
            cur.symbols[op.name] = op.rtype
    return comps


def _attr(line: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _elems(op.rtype)
    lhs = comp.symbols.get(op.operands[0], "")
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if not lhs or not m:
        return 2.0 * out_elems                      # degenerate fallback
    dims = _dims(lhs)
    if not dims:
        return 2.0 * out_elems
    shape = dims[0][1]
    contract = 1
    for d in (m.group(1).split(",") if m.group(1) else []):
        contract *= shape[int(d)]
    return 2.0 * out_elems * contract


def _fusion_bytes(op: Op, c: "Computation", comps) -> int:
    """HBM-traffic model for a fusion: output + per-operand effective bytes.

    A fusion that only ever *dynamic-slices* one of its parameters (the
    scan-over-layers pattern: the stacked params/saves buffer is a fusion
    operand, sliced inside) touches just the slice, not the buffer.
    Likewise a parameter consumed solely as the in-place target of a
    dynamic-update-slice contributes the update's bytes, and the fusion's
    full-buffer output is aliased to it.  Everything else counts full size.
    """
    callee = _attr(op.line, "calls")
    cc = comps.get(callee) if callee else None
    out_b = _bytes(op.rtype)
    if cc is None:
        return out_b + sum(_bytes(c.symbols.get(o, "")) for o in op.operands)
    # parameter name -> order (header params are in order)
    pnames = cc.params[:len(op.operands)]
    # follow single-step bitcast/reshape chains from params
    alias = {}
    for o in cc.ops:
        if o.opcode in ("bitcast", "reshape", "copy") and len(o.operands) == 1:
            alias[o.name] = o.operands[0]

    def root(n):
        seen = 0
        while n in alias and seen < 10:
            n = alias[n]
            seen += 1
        return n

    uses: Dict[str, List[Tuple[str, "Op", int]]] = {p: [] for p in pnames}
    for o in cc.ops:
        if o.opcode in ("bitcast", "reshape"):
            continue
        for idx, ref in enumerate(o.operands):
            r = root(ref)
            if r in uses:
                uses[r].append((o.opcode, o, idx))

    total = 0
    aliased_out = False
    for pi, pname in enumerate(pnames):
        full = _bytes(cc.symbols.get(pname, "")) or \
            _bytes(c.symbols.get(op.operands[pi], ""))
        us = uses.get(pname, [])
        if us and all(u[0] == "dynamic-slice" for u in us):
            total += sum(_bytes(u[1].rtype) for u in us)
        elif us and all(u[0] == "dynamic-update-slice" and u[2] == 0
                        for u in us) and full == out_b:
            upd = sum(_bytes(cc.symbols.get(u[1].operands[1], ""))
                      for u in us if len(u[1].operands) > 1)
            total += upd
            aliased_out = True
        else:
            total += full
    if aliased_out:
        # in-place: the output "write" is just the updated window(s),
        # already accounted on the parameter side.
        return total
    return total + out_b


class CostResult(dict):
    pass


def analyze(hlo: str, entry: Optional[str] = None) -> CostResult:
    comps = parse_module(hlo)
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))
    warnings: List[str] = []
    memo: Dict[str, dict] = {}

    def trip_count(cond_name: str) -> Optional[int]:
        cond = comps.get(cond_name)
        if cond is None:
            return None
        const_vals = {}
        root = None
        for op in cond.ops:
            if op.opcode == "constant" and op.rtype.strip().startswith("s32[]"):
                m = re.search(r"constant\((-?\d+)\)", op.line)
                if m:
                    const_vals[op.name] = int(m.group(1))
            if "ROOT" in op.line:
                root = op
        # prefer the constant feeding the ROOT compare (directly or as a
        # wrapped-fusion operand) — other s32 constants in the cond (e.g.
        # sequence-length scalars) must not be mistaken for the bound.
        if root is not None:
            for o in root.operands:
                if o in const_vals:
                    return const_vals[o]
        if not const_vals:
            return None
        return max(const_vals.values())

    def fused_dot_flops(comp_name: str) -> float:
        c = comps.get(comp_name)
        if c is None:
            return 0.0
        total = 0.0
        for op in c.ops:
            if op.opcode == "dot":
                total += _dot_flops(op, c)
            sub = _attr(op.line, "calls")
            if sub:
                total += fused_dot_flops(sub)
        return total

    def cost(comp_name: str) -> dict:
        if comp_name in memo:
            return memo[comp_name]
        c = comps.get(comp_name)
        res = {"flops": 0.0, "bytes": 0.0,
               "coll": {k: {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0}
                        for k in _COLLECTIVES}}
        memo[comp_name] = res
        if c is None:
            return res
        for op in c.ops:
            base = op.opcode.removesuffix("-start").removesuffix("-done")
            if op.opcode.endswith("-done"):
                continue                              # async pair: count start
            if base == "while":
                body = _attr(op.line, "body")
                cond = _attr(op.line, "condition")
                trips = trip_count(cond) if cond else None
                if trips is None:
                    trips = 1
                    warnings.append(f"unparsed trip count for {op.name}")
                for sub, mult in ((body, trips), (cond, trips + 1)):
                    if not sub:
                        continue
                    sc = cost(sub)
                    res["flops"] += mult * sc["flops"]
                    res["bytes"] += mult * sc["bytes"]
                    for k in _COLLECTIVES:
                        for f in ("count", "bytes", "wire_bytes"):
                            res["coll"][k][f] += mult * sc["coll"][k][f]
                continue
            if base == "conditional":
                branches = re.findall(r"(?:true_computation|false_computation|"
                                      r"branch_computations=\{)([^},]+)", op.line)
                for b in branches:
                    for sub in re.findall(r"%?([\w.\-]+)", b):
                        sc = cost(sub)
                        res["flops"] += sc["flops"]
                        res["bytes"] += sc["bytes"]
                continue
            # ---- flops ----
            if base == "dot":
                res["flops"] += _dot_flops(op, c)
            elif base == "fusion":
                sub = _attr(op.line, "calls")
                res["flops"] += _elems(op.rtype)      # nominal elementwise
                if sub:
                    res["flops"] += fused_dot_flops(sub)
            elif base == "convolution":
                res["flops"] += 2.0 * _elems(op.rtype)  # lower bound; flagged
                warnings.append(f"convolution flops lower-bounded: {op.name}")
            elif base not in _SKIP_BYTES:
                res["flops"] += _elems(op.rtype)
            # ---- bytes ----
            if base not in _SKIP_BYTES:
                if base == "dynamic-update-slice":
                    # in-place window write: traffic = the updated slice
                    b = 2 * _bytes(c.symbols.get(op.operands[1], "")) \
                        if len(op.operands) > 1 else _bytes(op.rtype)
                elif base == "dynamic-slice":
                    b = 2 * _bytes(op.rtype)   # read slice + write result
                elif base == "fusion":
                    b = _fusion_bytes(op, c, comps)
                else:
                    b = _bytes(op.rtype)
                    for o in op.operands:
                        b += _bytes(c.symbols.get(o, ""))
                res["bytes"] += b
            # ---- collectives ----
            if base in _COLLECTIVES:
                payload = _bytes(op.rtype)
                if op.opcode.endswith("-start") and base == "all-gather":
                    # result of all-gather-start is (operand, result) tuple
                    payload = payload / 2
                factor = 2.0 if base == "all-reduce" else 1.0
                res["coll"][base]["count"] += 1
                res["coll"][base]["bytes"] += payload
                res["coll"][base]["wire_bytes"] += payload * factor
        return res

    out = CostResult(cost(entry))
    out["warnings"] = warnings[:20]
    out["n_warnings"] = len(warnings)
    total = sum(v["wire_bytes"] for v in out["coll"].values())
    out["coll"]["total_wire_bytes"] = total
    return out
