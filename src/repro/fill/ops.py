"""Binary fill-holes as a *derived* IWPP op (paper §2's third instance).

Hole filling is border-seeded morphological reconstruction of the
complement: reconstruct, inside the background (``~image``), from seeds on
the image border; background the reconstruction never reaches has no path
to the border — i.e. it is a hole.  ``FillHolesOp`` therefore **derives
from** :class:`~repro.morph.ops.MorphReconstructOp` and adds no propagation
code at all: it only swaps in a state builder (complement mask + border
marker) and a result extractor (``J == 0``).  Its registry spec
(`repro/ops/builtin.py`) reuses the morph Pallas tile solvers *through the
registry* (``get_op("morph").pallas_solver``) — the spec-level composition
the plugin API exists for (DESIGN.md §2.4, docs/OPS.md).

``connectivity`` is the connectivity of the **background flood** (the
complement), matching scipy's structure-element convention: scipy's default
cross structure == ``connectivity=4``.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.morph.ops import MorphReconstructOp


@dataclasses.dataclass(frozen=True)
class FillHolesOp(MorphReconstructOp):
    """Border-seeded reconstruction of the complement (binary fill-holes)."""

    connectivity: int = 4

    def make_state(self, image, valid=None):
        """State from a boolean image (True = foreground).

        The morph state leaves get: ``I`` = the full complement as int32
        (1 on background — the reconstruction mask; invalid cells keep
        their complement value so :meth:`filled` can report the *input*
        there, while the valid mask keeps them out of the flood), ``J`` =
        1 only on *valid border* background pixels (the seeds).  ``J <= I``
        holds by construction, so the inherited round/frontier/pad
        machinery applies unchanged.
        """
        img = jnp.asarray(image, bool)
        H, W = img.shape
        if valid is None:
            valid = jnp.ones((H, W), dtype=bool)
        border = jnp.zeros((H, W), dtype=bool)
        border = border.at[0, :].set(True).at[-1, :].set(True)
        border = border.at[:, 0].set(True).at[:, -1].set(True)
        I = (~img).astype(jnp.int32)
        J = ((~img) & valid & border).astype(jnp.int32)
        return {"J": J, "I": I, "valid": valid}

    def filled(self, state) -> jnp.ndarray:
        """Extract the filled image from a converged state: foreground
        (``I == 0``) plus every *valid* background pixel the border flood
        never reached (``J == 0`` — a hole).  Invalid cells report the
        input image value (foreground as-is, background never filled),
        honoring the engines' invalid-restore contract at the user-facing
        surface too."""
        return (state["I"] == 0) | ((state["J"] == 0) & state["valid"])


def fill_holes(image, *, connectivity: int = 4, engine: str = "auto",
               **solve_kw):
    """One-call binary hole filling through the solve() dispatcher.

    ``image``: bool (H, W), True = foreground.  ``connectivity`` is the
    background-flood connectivity (4 == scipy's default structure).
    Returns (filled bool image, SolveStats).  Thin registry-backed wrapper:
    equivalent to ``solve("fill_holes", image, ...)`` plus the spec's
    ``finalize``.
    """
    from repro.ops import run_op
    return run_op("fill_holes", image, connectivity=connectivity,
                  engine=engine, **solve_kw)
