"""Sequential reference for binary hole filling (paper §2: fill-holes is
named as a further IWPP instance alongside reconstruction and EDT).

``fill_holes_bfs`` — the definitional algorithm: flood-fill the background
from the image border (a FIFO wavefront over the complement), then mark
every background pixel the flood never reached as a hole.  This is exactly
``scipy.ndimage.binary_fill_holes`` (same structure-element convention:
``connectivity`` is the connectivity of the *background* flood — scipy's
default cross structure corresponds to ``connectivity=4``), kept here
scipy-free so examples and the conformance suite run on the bare runtime
deps.
"""

from __future__ import annotations

from collections import deque

import numpy as np

from repro.morph.ref import N4, N8


def fill_holes_bfs(image: np.ndarray, connectivity: int = 4) -> np.ndarray:
    """Fill holes of a boolean image; returns the filled boolean image.

    A *hole* is a background component with no path (through background,
    under ``connectivity``) to the image border.
    """
    img = np.asarray(image, bool)
    nbrs = N4 if connectivity == 4 else N8
    H, W = img.shape
    reached = np.zeros((H, W), bool)
    q: deque = deque()
    for r in range(H):
        for c in (0, W - 1):
            if not img[r, c] and not reached[r, c]:
                reached[r, c] = True
                q.append((r, c))
    for c in range(W):
        for r in (0, H - 1):
            if not img[r, c] and not reached[r, c]:
                reached[r, c] = True
                q.append((r, c))
    while q:
        r, c = q.popleft()
        for dr, dc in nbrs:
            rr, cc = r + dr, c + dc
            if 0 <= rr < H and 0 <= cc < W and not img[rr, cc] and not reached[rr, cc]:
                reached[rr, cc] = True
                q.append((rr, cc))
    return img | ~reached
