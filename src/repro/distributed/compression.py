"""Gradient compression for cross-pod data parallelism: int8 quantization
with error feedback (1-bit-Adam-style residual correction).

Used by the explicit-DP (shard_map) training variant: gradients are
quantized to int8 with a per-tensor scale before the cross-replica
all-reduce, cutting DP all-reduce bytes 4x (fp32) / 2x (bf16); the
quantization residual is kept locally and added back into the next step's
gradient (error feedback makes the scheme unbiased over time).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress(g: jnp.ndarray, ef: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """g + ef -> (int8 q, fp32 scale, new ef)."""
    gf = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_ef = gf - q.astype(jnp.float32) * scale
    return q, scale, new_ef


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(g, ef, axis_name: str):
    """All-reduce `g` over `axis_name` in int8 (mean), with error feedback.

    Returns (g_mean, new_ef).  Must run inside shard_map/pmap.  The int8
    payloads are summed as int32 (no overflow for <= 2^23 replicas) and the
    per-replica scales are averaged — an unbiased mean because each
    replica's quantization error stays in its local ef buffer.
    """
    n = jax.lax.psum(1, axis_name)

    def one(gl, efl):
        q, scale, new_ef = compress(gl, efl)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        # sum_i q_i * scale_i ~ sum_i q_i * mean(scale): exact when scales
        # are equal; the deviation lands in the next step's error feedback.
        mean_scale = jax.lax.psum(scale, axis_name) / n
        g_mean = qsum.astype(jnp.float32) * mean_scale / n
        # account the approximation into ef so nothing is lost
        new_ef = new_ef + (decompress(q, scale) - q.astype(jnp.float32) * mean_scale)
        return g_mean.astype(gl.dtype), new_ef

    flat_g, tdef = jax.tree_util.tree_flatten(g)
    flat_ef = tdef.flatten_up_to(ef)
    out = [one(a, b) for a, b in zip(flat_g, flat_ef)]
    g_out = tdef.unflatten([o[0] for o in out])
    ef_out = tdef.unflatten([o[1] for o in out])
    return g_out, ef_out
