"""Logical-axis sharding rules -> PartitionSpecs for params, batches, caches.

Path-based rules (t5x-style): each param leaf's pytree path is matched
against name patterns; the rule gives the spec of the *trailing* dims, and
leading dims (stacked-layer axes from scan-over-layers) are padded with
None.  FSDP (cfg.fsdp) additionally shards one replicated param dim over
the data axis (ZeRO-3 style; GSPMD inserts the per-layer all-gathers).

Axis conventions (DESIGN.md §3.2):
  batch   -> ("pod", "data")  [multi-pod]  or ("data",)
  heads / ffn / vocab / experts -> "model"
  sequence (decode KV cache when heads don't divide the TP width) -> "model"
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _pad(spec_dims, ndim):
    """Left-pad a trailing-dims spec with None up to ndim."""
    dims = list(spec_dims)
    assert len(dims) <= ndim, (dims, ndim)
    return P(*([None] * (ndim - len(dims)) + dims))


# (substring patterns, trailing-dims spec, fsdp trailing-dims spec)
_PARAM_RULES = [
    # embeddings / head
    (("embed'", ), ("model", None), ("model", "data")),
    (("lm_head",), (None, "model"), ("data", "model")),
    (("dec_pos",), (None, None), (None, "data")),
    # MLA
    (("wq_a",), (None, None), ("data", None)),
    (("wq_b",), (None, "model"), (None, "model")),
    (("wkv_a",), (None, None), ("data", None)),
    (("wkv_b",), (None, "model"), (None, "model")),
    # attention
    (("'wq'", "'wk'", "'wv'", "xwq", "xwk", "xwv"),
     (None, "model"), ("data", "model")),
    (("'wo'", "xwo"), ("model", None), ("model", "data")),
    # MoE expert stacks (E, D, F) / (E, F, D): experts over model
    (("moe']['up", "moe']['gate", "moe']['down"),
     ("model", None, None), ("model", "data", None)),
    (("router",), (None, None), (None, None)),
    # dense MLP
    (("mlp']['up", "mlp']['gate", "ffn_up"), (None, "model"), ("data", "model")),
    (("mlp']['down", "ffn_down"), ("model", None), ("model", "data")),
    # Griffin recurrent block: lru channels over model
    (("w_gate", "w_main"), (None, "model"), ("data", "model")),
    (("w_out",), ("model", None), ("model", "data")),
    (("'wr'", "'wi'"), (None, "model"), (None, "model")),
    (("lru']['br", "lru']['bi", "lam",), ("model",), ("model",)),
    (("rec']['conv",), (None, "model"), (None, "model")),
    # xLSTM
    (("w_up",), (None, "model"), ("data", "model")),
    (("w_if",), (None, None), (None, None)),
    (("w_down",), ("model", None), ("model", "data")),
    (("mlstm']['conv", "gn_scale"), (None,), (None,)),
    (("w_z", "w_i", "w_f", "w_o"), (None, None), (None, None)),
]


def _match_param(path_str: str):
    for pats, spec, fspec in _PARAM_RULES:
        if any(p in path_str for p in pats):
            return spec, fspec
    return (), ()          # replicate (norm scales, small biases, conv)


def param_specs(cfg: ArchConfig, params_tree, mesh: Mesh):
    """PartitionSpec pytree for a param tree (arrays or ShapeDtypeStructs)."""
    fsdp = cfg.fsdp

    def one(path, leaf):
        pstr = jax.tree_util.keystr(path)
        spec, fspec = _match_param(pstr)
        dims = fspec if fsdp else spec
        # special-case: xLSTM wq/wk/wv act on d_inner; patterns above for
        # attention already cover them (same layout).
        nd = len(leaf.shape)
        dims = tuple(dims[:nd])
        # drop axes that don't divide the dim size
        fixed = []
        for size, ax in zip(leaf.shape[nd - len(dims):], dims):
            if ax is None:
                fixed.append(None)
            else:
                axes = (ax,) if isinstance(ax, str) else ax
                n = int(np.prod([mesh.shape[a] for a in axes]))
                fixed.append(ax if size % n == 0 else None)
        return _pad(tuple(fixed), nd)

    return jax.tree_util.tree_map_with_path(one, params_tree)


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), spec_tree)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ArchConfig, specs: Dict[str, Any], mesh: Mesh):
    """PartitionSpecs for the input batch dict (train/prefill/decode)."""
    b = batch_axes(mesh)
    bsz = int(np.prod([mesh.shape[a] for a in b]))
    out = {}
    for k, v in specs.items():
        shp = v.shape
        if k == "positions":                    # (3, B, S)
            out[k] = P(None, b, None) if shp[1] % bsz == 0 else P()
            continue
        if len(shp) == 0:
            out[k] = P()
            continue
        if shp[0] % bsz != 0:                   # tiny batch (long_500k B=1)
            out[k] = P(*([None] * len(shp)))
            continue
        out[k] = P(b, *([None] * (len(shp) - 1)))
    return out


def cache_specs(cfg: ArchConfig, cache_tree, mesh: Mesh):
    """Decode-cache specs.  KV heads over model when divisible, else the
    sequence axis (flash-decoding style); batch over the data axes."""
    b = batch_axes(mesh)
    bsz = int(np.prod([mesh.shape[a] for a in b]))
    tp = mesh.shape["model"]

    def one(path, leaf):
        pstr = jax.tree_util.keystr(path)
        shp = leaf.shape
        nd = len(shp)
        # strip a possible stacked-layer leading dim for rule purposes
        def bspec(i):           # batch dim at index i
            return b if shp[i] % bsz == 0 else None
        if "enc_out" in pstr:   # (B, T, D)
            return P(bspec(0), None, None)
        if "'k'" in pstr or "'v'" in pstr:      # (..., B, S, Hkv, Dh)
            off = nd - 4
            lead = [None] * off
            hs = "model" if shp[off + 2] % tp == 0 else None
            ss = None if hs else ("model" if shp[off + 1] % tp == 0 else None)
            return P(*lead, bspec(off), ss, hs, None)
        if "c_kv" in pstr or "k_pe" in pstr:    # (..., B, S, r)
            off = nd - 3
            lead = [None] * off
            ss = "model" if shp[off + 1] % tp == 0 else None
            return P(*lead, bspec(off), ss, None)
        if "conv" in pstr:                      # (..., B, K-1, D)
            off = nd - 3
            ds = "model" if shp[off + 2] % tp == 0 else None
            return P(*([None] * off), bspec(off), None, ds)
        if "'h'" in pstr:                       # (..., B, lru)
            off = nd - 2
            ds = "model" if shp[off + 1] % tp == 0 else None
            return P(*([None] * off), bspec(off), ds)
        # mlstm/slstm states (..., B, H, ...) — batch only
        off = 0
        for i, s in enumerate(shp):
            if s % bsz == 0:
                off = i
                break
        else:
            return P(*([None] * nd))
        return P(*([None] * off), b, *([None] * (nd - off - 1)))

    return jax.tree_util.tree_map_with_path(one, cache_tree)
