"""Trace-time parallel context.

pjit/GSPMD propagates most shardings from the in_shardings annotations, but
the MoE dispatch is deliberately implemented as a `shard_map` island (local
token routing per data shard + expert-parallel slice per model shard — the
sort-based dispatch must not be partitioned by GSPMD, which would turn the
argsort into a distributed sort).  The island needs the mesh and axis names
at *trace* time; this module carries them.  `set_parallel(None)` restores
single-device behaviour (tests, CPU examples).
"""

from __future__ import annotations

import dataclasses
from contextlib import contextmanager
from typing import Optional, Tuple

from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Mesh
    dp_axes: Tuple[str, ...]       # batch axes, e.g. ("pod", "data")
    tp_axis: str = "model"         # tensor/expert-parallel axis


_CTX: Optional[ParallelCtx] = None


def set_parallel(ctx: Optional[ParallelCtx]):
    global _CTX
    _CTX = ctx


def get_parallel() -> Optional[ParallelCtx]:
    return _CTX


@contextmanager
def parallel_ctx(ctx: Optional[ParallelCtx]):
    prev = get_parallel()
    set_parallel(ctx)
    try:
        yield
    finally:
        set_parallel(prev)
