"""Sequential reference for connected-component labeling as IWPP.

``label_wavefront`` — queue-based flood fill that assigns every foreground
component the **maximum linear index** (``r * W + c + 1``) among its
pixels.  That is exactly the fixed point of
:class:`repro.label.ops.LabelPropagationOp`'s monotone max-label
propagation, so engines must match it *bit-for-bit* (unlike scipy's
``ndimage.label``, whose label values depend on scan order — compare to
scipy with :func:`same_components`).

``relabel_sequential`` — compact arbitrary positive labels to 1..K in
first-appearance order (presentation helper; the IWPP fixed point itself
keeps the max-index labels).
"""

from __future__ import annotations

from collections import deque

import numpy as np

from repro.morph.ref import N4, N8


def label_wavefront(fg: np.ndarray, connectivity: int = 8) -> np.ndarray:
    """Max-linear-index component labels; background = 0."""
    img = np.asarray(fg, bool)
    nbrs = N8 if connectivity == 8 else N4
    H, W = img.shape
    out = np.zeros((H, W), dtype=np.int32)
    seen = np.zeros((H, W), bool)
    for r in range(H):
        for c in range(W):
            if img[r, c] and not seen[r, c]:
                comp = [(r, c)]
                seen[r, c] = True
                q: deque = deque(comp)
                while q:
                    cr, cc = q.popleft()
                    for dr, dc in nbrs:
                        rr, cc2 = cr + dr, cc + dc
                        if (0 <= rr < H and 0 <= cc2 < W
                                and img[rr, cc2] and not seen[rr, cc2]):
                            seen[rr, cc2] = True
                            comp.append((rr, cc2))
                            q.append((rr, cc2))
                lab = max(rr * W + cc2 + 1 for rr, cc2 in comp)
                for rr, cc2 in comp:
                    out[rr, cc2] = lab
    return out


def relabel_sequential(labels: np.ndarray) -> np.ndarray:
    """Map positive labels to 1..K in first-appearance (raster) order."""
    lab = np.asarray(labels)
    out = np.zeros_like(lab, dtype=np.int32)
    mapping: dict = {}
    flat, oflat = lab.ravel(), out.ravel()
    for i, v in enumerate(flat):
        if v > 0:
            oflat[i] = mapping.setdefault(int(v), len(mapping) + 1)
    return out


def same_components(a: np.ndarray, b: np.ndarray) -> bool:
    """Component-membership equality up to relabeling: both labelings have
    the same support and induce the same partition of it (the equivalence
    scipy comparison needs — scipy's label *values* are scan-order
    artifacts)."""
    a, b = np.asarray(a), np.asarray(b)
    if a.shape != b.shape or not np.array_equal(a > 0, b > 0):
        return False
    return np.array_equal(relabel_sequential(a), relabel_sequential(b))
