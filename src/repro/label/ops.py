"""Connected-component labeling as an IWPP `PropagationOp`.

The paper's point (§2, and the MIC follow-up, Gomes & Teodoro 2016) is that
IWPP instances differ only in the propagation condition.  Labeling is the
max-label flood fill: seed every foreground pixel with a unique label (its
linear index + 1) and propagate the **maximum** label within each
foreground-connected region:

    lab'(q) = max(lab(q), max_{p in N(q) & frontier & fg} lab(p))   if fg(q)

Updates only ever increase ``lab`` and max is commutative — the IWPP
contract — so every engine converges to the same fixed point: each
component uniformly holds the max linear index among its pixels
(bit-comparable to ``repro.label.ref.label_wavefront``; compare to scipy
up to relabeling with ``repro.label.ref.same_components``).

State pytree: {"lab": int32 (H, W) labels (mutable), "fg": bool (static
foreground mask), "valid": bool (static)}.  Background keeps ``lab == 0``
(the neutral value: 0 can never beat a real label, so the `pad_value`
halo/padding fill can never source propagation).

The Pallas tile solver for this op is the **morph kernel, parametrized**
(`kernels/ops.py: tile_solver_label`): with mask ``I = fg ? LABEL_CAP : 0``
the morph update ``min(I, max(J, max_nbr J))`` *is* the masked-max label
update — the registry-level kernel reuse DESIGN.md §2.4 describes.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.pattern import PropagationOp, shift2d


# Upper bound on any label value.  The Pallas label solver runs the morph
# kernel with mask plane `fg ? LABEL_CAP : 0`, so a seed above the cap
# would be silently clamped there (collapsing distinct components) while
# the dense engines would not — hence the hard guard in label_seeds.
LABEL_CAP = 1 << 30


def label_seeds(fg: jnp.ndarray) -> jnp.ndarray:
    """Unique int32 seed labels: linear index + 1 on fg, 0 elsewhere."""
    H, W = fg.shape
    if H * W + 1 > LABEL_CAP:
        raise ValueError(
            f"grid {H}x{W} needs labels up to {H * W + 1}, above "
            f"LABEL_CAP={LABEL_CAP} (the Pallas label solver's mask value); "
            "label propagation is limited to grids below 2^30 pixels")
    r = jax.lax.broadcasted_iota(jnp.int32, (H, W), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (H, W), 1)
    return jnp.where(fg, r * jnp.int32(W) + c + 1, 0)


@dataclasses.dataclass(frozen=True)
class LabelPropagationOp(PropagationOp):
    """Monotone max-label flood fill (connected-component labeling)."""

    @property
    def static_leaves(self):
        return ("fg", "valid")

    def make_state(self, fg, valid=None):
        """fg: bool (H, W), True = foreground to be labeled.

        Labels are *global* linear indices assigned here once, so tiled and
        sharded engines — which see local blocks — propagate globally
        meaningful values (the same reason EDT carries coordinate leaves).
        """
        fg = jnp.asarray(fg, bool)
        if valid is None:
            valid = jnp.ones(fg.shape, dtype=bool)
        return {"lab": label_seeds(fg & valid), "fg": fg, "valid": valid}

    def pad_value(self, state):
        return {"lab": jnp.int32(0), "fg": False, "valid": False}

    def init_frontier(self, state) -> jnp.ndarray:
        """p is queued iff it can still improve some neighbor: a foreground
        neighbor q with lab(q) < lab(p) (the FH queue condition with the
        morph propagation test swapped for the label one)."""
        lab, fg = state["lab"], state["fg"]
        can = jnp.zeros(lab.shape, dtype=bool)
        for dr, dc in self.offsets:
            lq = shift2d(lab, dr, dc, jnp.int32(0))
            fq = shift2d(fg & state["valid"], dr, dc, False)
            can = can | (fq & (lq < lab))
        return can & fg & state["valid"]

    def round(self, state, frontier) -> Tuple[dict, jnp.ndarray]:
        lab, fg = state["lab"], state["fg"]
        src = jnp.where(frontier, lab, 0)
        cand = jnp.zeros_like(lab)
        for dr, dc in self.offsets:
            cand = jnp.maximum(cand, shift2d(src, dr, dc, jnp.int32(0)))
        new = jnp.where(fg, jnp.maximum(lab, cand), lab)
        changed = (new > lab) & state["valid"]
        return {"lab": new, "fg": fg, "valid": state["valid"]}, changed


def label(fg, *, connectivity: int = 8, engine: str = "auto", **solve_kw):
    """One-call connected-component labeling through solve().

    ``fg``: bool (H, W), True = foreground.  Returns (int32 label map with
    per-component max-linear-index labels, SolveStats); compact to 1..K
    with ``repro.label.ref.relabel_sequential`` if sequential ids are
    wanted.  Thin registry-backed wrapper over ``solve("label", fg, ...)``.
    """
    from repro.ops import run_op
    return run_op("label", fg, connectivity=connectivity, engine=engine,
                  **solve_kw)
