"""Pallas TPU kernel: drain one morphological-reconstruction tile in VMEM.

This is the hot spot the paper optimizes with its BQ/TQ queues: repeated
neighbor propagation over one tile.  The TPU formulation keeps the whole
halo block (``(T+2, T+2)`` in 2D, ``(T+2, T+2, T+2)`` in 3D — DESIGN.md
§2.7) resident in VMEM and iterates the neighbor max-propagate + min-clamp
to local stability *inside the kernel* — zero HBM traffic between
iterations (the BQ analogue; DESIGN.md §2).  The neighbor combine is one
statically-shifted VREG plane per offset in the op's
:class:`~repro.core.geometry.Neighborhood` (TQ analogue).

Two entry points:

* :func:`morph_tile_solve`          — one halo block;
* :func:`morph_tile_solve_batched`  — a (K, T+2, ...) batch of blocks,
  drained concurrently with a ``pl.pallas_call`` grid over the batch
  dimension (the paper's parallel consumption of the global queue,
  DESIGN.md §2 "batched queue drain"); each grid step iterates its own
  block to stability independently.

Block shapes should keep the (8, 128) vector layout: T in {64, 128, 256} and
int32/float32 payloads (wrappers upcast uint8 — TPU-native dtype policy).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.geometry import ravel_index, unravel_index
from repro.core.pattern import offsets_for
from repro.kernels.queue import fit_seed as _fit_seed
from repro.kernels.queue import queued_fixed_point


def _neutral(dtype):
    return jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer) else -jnp.inf


def _full(shape):
    """BlockSpec for a whole-array block of any rank."""
    shape = tuple(shape)
    return pl.BlockSpec(shape, lambda: (0,) * len(shape))


def _batch_blk(spatial):
    """BlockSpec for one (1, *spatial) slab of a batched array under grid=(K,)."""
    spatial = tuple(spatial)
    return pl.BlockSpec((1,) + spatial, lambda k: (k,) + (0,) * len(spatial))


def _shifted_slice(xp, off, shape):
    """The neighbor plane at `off` of a halo-padded block (rank-generic)."""
    return jax.lax.slice(xp, tuple(1 + d for d in off),
                         tuple(1 + d + s for d, s in zip(off, shape)))


def _make_kernel(connectivity, max_iters: int, batched: bool = False):
    offsets = offsets_for(connectivity)

    def kernel(j_ref, i_ref, valid_ref, o_ref, iters_ref):
        if batched:  # refs carry a leading (1,)-block batch dim under the grid
            J = j_ref[0]
            I = i_ref[0]
            valid = valid_ref[0]
        else:
            J = j_ref[...]
            I = i_ref[...]
            valid = valid_ref[...]
        shp = J.shape  # halo block: (T+2, ...) over the spatial rank
        neut = _neutral(J.dtype)
        # Invalid in-block pixels (non-rectangular masks) must neither source
        # nor hold propagation: pin them to the neutral value — the morph
        # analogue of the EDT kernel's sentinel clamp.
        J = jnp.where(valid, J, neut)

        def cond(carry):
            _, changed, it = carry
            return changed & (it < max_iters)

        def body(carry):
            J, _, it = carry
            # Full-block update (halo ring evolves too): keeps pass-through
            # propagation paths identical to the dense-round oracle.
            Jp = jnp.pad(J, 1, constant_values=neut)
            cand = jnp.full_like(J, neut)
            for off in offsets:
                cand = jnp.maximum(cand, _shifted_slice(Jp, off, shp))
            new = jnp.minimum(I, jnp.maximum(J, cand))
            new = jnp.where(valid, new, neut)
            changed = jnp.any(new != J)
            return new, changed, it + 1

        J, _, iters = jax.lax.while_loop(cond, body, (J, jnp.bool_(True), jnp.int32(0)))
        if batched:
            o_ref[0] = J
            iters_ref[0, 0, 0] = iters
        else:
            o_ref[...] = J
            iters_ref[0, 0] = iters

    return kernel


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters", "interpret"))
def morph_tile_solve(J, I, valid, *, connectivity=8, max_iters: int = 1024,
                     interpret: bool = True):
    """Drain one (T+2, ...) halo block to local stability.

    Returns (J_out, iters).  Halo faces are read as propagation sources
    but their output values are unspecified (callers write back interiors
    only, as the tiled engine does).  Invalid cells come back neutral.
    """
    kernel = _make_kernel(connectivity, max_iters)
    out_shape = (
        jax.ShapeDtypeStruct(J.shape, J.dtype),
        jax.ShapeDtypeStruct((1, 1), jnp.int32),
    )
    J_out, iters = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        in_specs=[_full(J.shape), _full(I.shape), _full(valid.shape)],
        out_specs=(_full(J.shape), _full((1, 1))),
        interpret=interpret,
    )(J, I, valid)
    return J_out, iters[0, 0]


def _make_queued_kernel(connectivity, max_iters: int, capacity: int,
                        batched: bool = False, seeded: bool = False):
    """Queued variant (DESIGN.md §2.5), push formulation: the queue holds
    last round's *improved* pixels, and each round gathers only those and
    pushes ``min(I[t], J[s])`` to every neighbor ``t`` — O(capacity) work
    per round instead of O(block).  Queue overflow spills to one dense
    full-block round.  Accepted updates coincide exactly with the dense
    kernel's (a non-improved neighbor's offer was already max-merged when
    it last improved), so outputs and iteration counts are bit-identical
    to :func:`_make_kernel` — only the work per round shrinks.

    ``seeded`` adds two input refs (resident queue indices + live count,
    DESIGN.md §2.6) and starts the drain from them, skipping the O(block)
    seeding sweep — the re-entry path when the caller already knows the
    frontier."""
    offsets = offsets_for(connectivity)

    def kernel(j_ref, i_ref, valid_ref, *refs):
        if seeded:
            seed_ref, cnt_ref = refs[0], refs[1]
            o_ref, iters_ref, spills_ref = refs[2], refs[3], refs[4]
        else:
            o_ref, iters_ref, spills_ref = refs[0], refs[1], refs[2]
        if batched:  # refs carry a leading (1,)-block batch dim under the grid
            J = j_ref[0]
            I = i_ref[0]
            valid = valid_ref[0]
        else:
            J = j_ref[...]
            I = i_ref[...]
            valid = valid_ref[...]
        shp = J.shape  # halo block: (T+2, ...) over the spatial rank
        n = math.prod(shp)
        neut = _neutral(J.dtype)
        J = jnp.where(valid, J, neut)

        def dense_round(J):
            # Same body as the dense kernel's while-loop step.
            Jp = jnp.pad(J, 1, constant_values=neut)
            cand = jnp.full_like(J, neut)
            for off in offsets:
                cand = jnp.maximum(cand, _shifted_slice(Jp, off, shp))
            new = jnp.minimum(I, jnp.maximum(J, cand))
            new = jnp.where(valid, new, neut)
            return new, new != J

        I_flat = I.reshape(-1)
        valid_flat = valid.reshape(-1)

        def queued_round(J, queue):
            # Push formulation: gather the queued (improved) pixels' values
            # once, offer min(I[t], J[s]) to each neighbor t, and scatter-max
            # the improving offers back.  Duplicate targets (several sources
            # improving one pixel) are safe: max is order-free and duplicate
            # enqueues are idempotent (DESIGN.md §2.5).
            Jf = J.reshape(-1)
            live = queue >= 0
            src = jnp.where(live, queue, 0)
            vs = Jf[src]                    # pre-round source values
            sco = unravel_index(src, shp)   # per-axis source coords
            tgts = []                       # offsets unrolled in Python:
            for off in offsets:             # Pallas forbids captured arrays
                tco = tuple(c + d for c, d in zip(sco, off))
                inb = live
                for c, s in zip(tco, shp):
                    inb = inb & (c >= 0) & (c < s)
                tgts.append(jnp.where(inb, ravel_index(tco, shp), n))  # n -> dropped
            tgt = jnp.concatenate(tgts)
            offer = jnp.minimum(
                jnp.take(I_flat, tgt, mode="fill", fill_value=neut),
                jnp.concatenate([vs] * len(offsets)))
            old = jnp.take(Jf, tgt, mode="fill", fill_value=neut)
            imp = (offer > old) & jnp.take(valid_flat, tgt, mode="fill",
                                           fill_value=False)
            Jf = Jf.at[jnp.where(imp, tgt, n)].max(offer, mode="drop")
            return Jf.reshape(shp), tgt, imp

        initial_queue = None
        if seeded:
            if batched:
                initial_queue = (seed_ref[0], cnt_ref[0, 0, 0])
            else:
                initial_queue = (seed_ref[0], cnt_ref[0, 0])
        J, iters, spills = queued_fixed_point(
            dense_round, queued_round, J,
            max_iters=max_iters, capacity=capacity,
            initial_queue=initial_queue)
        if batched:
            o_ref[0] = J
            iters_ref[0, 0, 0] = iters
            spills_ref[0, 0, 0] = spills
        else:
            o_ref[...] = J
            iters_ref[0, 0] = iters
            spills_ref[0, 0] = spills

    return kernel


def _clip_capacity(queue_capacity: int, n: int, n_offsets: int) -> int:
    # The queue counts per-contribution (duplicates included), so up to
    # n_offsets*n slots are meaningful — that capacity can never overflow.
    return max(1, min(int(queue_capacity), n_offsets * n))


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters",
                                             "queue_capacity", "interpret"))
def morph_tile_solve_queued(J, I, valid, seed=None, *, connectivity=8,
                            max_iters: int = 1024, queue_capacity: int = 64,
                            interpret: bool = True):
    """Queued drain of one (T+2, ...) halo block (DESIGN.md §2.5).

    Returns (J_out, iters, spills): bit-identical J_out and iters to
    :func:`morph_tile_solve`; ``spills`` counts the rounds whose candidate
    set overflowed ``queue_capacity`` and fell back to a dense sweep.

    ``seed`` — optional resident queue ``(indices, count)`` (DESIGN.md
    §2.6): flat int32 block indices of the pixels whose values have not yet
    been offered to their neighbors (dead slots ``-1``), plus the live
    count.  The drain then starts from this frontier instead of paying the
    O(block) seeding sweep; a count above the (clipped) capacity safely
    spills to a dense first round.
    """
    n_off = len(offsets_for(connectivity))
    cap = _clip_capacity(queue_capacity, math.prod(J.shape), n_off)
    kernel = _make_queued_kernel(connectivity, max_iters, cap,
                                 seeded=seed is not None)
    out_shape = (
        jax.ShapeDtypeStruct(J.shape, J.dtype),
        jax.ShapeDtypeStruct((1, 1), jnp.int32),
        jax.ShapeDtypeStruct((1, 1), jnp.int32),
    )
    scalar = _full((1, 1))
    in_specs = [_full(J.shape), _full(I.shape), _full(valid.shape)]
    args = (J, I, valid)
    if seed is not None:
        sq, cnt = seed
        sq = _fit_seed(sq, cap)[None, :]            # (1, cap)
        cnt = jnp.asarray(cnt, jnp.int32).reshape(1, 1)
        in_specs += [_full(sq.shape), scalar]
        args += (sq, cnt)
    J_out, iters, spills = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        in_specs=in_specs,
        out_specs=(_full(J.shape), scalar, scalar),
        interpret=interpret,
    )(*args)
    return J_out, iters[0, 0], spills[0, 0]


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters",
                                             "queue_capacity", "interpret"))
def morph_tile_solve_queued_batched(J, I, valid, seed=None, *,
                                    connectivity=8,
                                    max_iters: int = 1024,
                                    queue_capacity: int = 64,
                                    interpret: bool = True):
    """Queued drain of a (K, T+2, ...) batch; each grid step owns one block
    and one local queue.  Returns (J_out, iters, spills), both (K,).

    ``seed`` — optional per-block resident queues ``(indices, counts)``
    with shapes (K, n) / (K,) (same contract as
    :func:`morph_tile_solve_queued`)."""
    K = J.shape[0]
    spatial = J.shape[1:]
    n_off = len(offsets_for(connectivity))
    cap = _clip_capacity(queue_capacity, math.prod(spatial), n_off)
    kernel = _make_queued_kernel(connectivity, max_iters, cap, batched=True,
                                 seeded=seed is not None)
    out_shape = (
        jax.ShapeDtypeStruct(J.shape, J.dtype),
        jax.ShapeDtypeStruct((K, 1, 1), jnp.int32),
        jax.ShapeDtypeStruct((K, 1, 1), jnp.int32),
    )
    blk = _batch_blk(spatial)
    scalar = pl.BlockSpec((1, 1, 1), lambda k: (k, 0, 0))
    in_specs = [blk, blk, blk]
    args = (J, I, valid)
    if seed is not None:
        sq, cnt = seed
        sq = jax.vmap(lambda s: _fit_seed(s, cap))(sq)        # (K, cap)
        cnt = jnp.asarray(cnt, jnp.int32).reshape(K, 1, 1)
        in_specs += [pl.BlockSpec((1, cap), lambda k: (k, 0)), scalar]
        args += (sq, cnt)
    J_out, iters, spills = pl.pallas_call(
        kernel,
        grid=(K,),
        out_shape=out_shape,
        in_specs=in_specs,
        out_specs=(blk, scalar, scalar),
        interpret=interpret,
    )(*args)
    return J_out, iters[:, 0, 0], spills[:, 0, 0]


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters", "interpret"))
def morph_tile_solve_batched(J, I, valid, *, connectivity=8,
                             max_iters: int = 1024, interpret: bool = True):
    """Drain a (K, T+2, ...) batch of halo blocks concurrently.

    One ``pallas_call`` with ``grid=(K,)``: each grid step owns one block and
    iterates it to *its own* local stability (no cross-block sync, unlike a
    vmapped while_loop which runs every block for the batch max).  Returns
    (J_out, iters) with iters shaped (K,).
    """
    K = J.shape[0]
    spatial = J.shape[1:]
    kernel = _make_kernel(connectivity, max_iters, batched=True)
    out_shape = (
        jax.ShapeDtypeStruct(J.shape, J.dtype),
        jax.ShapeDtypeStruct((K, 1, 1), jnp.int32),
    )
    blk = _batch_blk(spatial)
    J_out, iters = pl.pallas_call(
        kernel,
        grid=(K,),
        out_shape=out_shape,
        in_specs=[blk, blk, blk],
        out_specs=(blk, pl.BlockSpec((1, 1, 1), lambda k: (k, 0, 0))),
        interpret=interpret,
    )(J, I, valid)
    return J_out, iters[:, 0, 0]
