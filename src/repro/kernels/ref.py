"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tiles import _tile_local_solve
from repro.edt.ops import EdtOp
from repro.morph.ops import MorphReconstructOp


def morph_tile_ref(J, I, valid, connectivity: int = 8):
    """Oracle for kernels.morph_tile: dense rounds to stability (interior)."""
    op = MorphReconstructOp(connectivity=connectivity)
    blk, _ = _tile_local_solve(op, {"J": J, "I": I, "valid": valid},
                               max_iters=4 * max(J.shape))
    return blk["J"]


def edt_tile_ref(vr_r, vr_c, valid, row, col, connectivity: int = 8):
    """Oracle for kernels.edt_tile."""
    op = EdtOp(connectivity=connectivity)
    state = {"vr": jnp.stack([vr_r, vr_c]), "valid": valid, "row": row, "col": col}
    blk, _ = _tile_local_solve(op, state, max_iters=4 * max(vr_r.shape))
    return blk["vr"][0], blk["vr"][1]


def raster_down_ref(J, I):
    """Oracle for kernels.raster_scan.raster_down: explicit row recurrence."""
    def step(prev, rows):
        j, i = rows
        v = jnp.minimum(i, jnp.maximum(j, prev))
        return v, v
    neut = (jnp.iinfo(J.dtype).min if jnp.issubdtype(J.dtype, jnp.integer) else -jnp.inf)
    init = jnp.full((J.shape[1],), neut, dtype=J.dtype)
    _, out = jax.lax.scan(step, init, (J, I))
    return out
