"""Pallas TPU kernel: drain one EDT (Voronoi-pointer) tile in VMEM.

Same structure as morph_tile: the (T+2, T+2) halo block iterates the
8-neighbor candidate min-reduction to local stability without leaving VMEM.
Distances are int32 (exact for grids < 8192 with the far sentinel; see
repro.edt.ref.SENTINEL).  This kernel replaces Algorithm 6's atomicCAS retry
loop with a race-free vector reduction — the TPU-native adaptation.

:func:`edt_tile_solve_batched` drains a (K, T+2, T+2) batch with a
``pallas_call`` grid over the batch dimension (DESIGN.md §2 "batched queue
drain"); each grid step converges independently.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pattern import offsets_for
from repro.edt.ref import SENTINEL


def _make_kernel(connectivity: int, max_iters: int, batched: bool = False):
    offsets = offsets_for(connectivity)

    def kernel(vr_r_ref, vr_c_ref, valid_ref, row_ref, col_ref, or_ref, oc_ref, iters_ref):
        if batched:  # refs carry a leading (1,)-block batch dim under the grid
            vr_r, vr_c = vr_r_ref[0], vr_c_ref[0]
            valid = valid_ref[0]
            row, col = row_ref[0], col_ref[0]
        else:
            vr_r, vr_c = vr_r_ref[...], vr_c_ref[...]
            valid = valid_ref[...]
            row, col = row_ref[...], col_ref[...]
        Hp, Wp = vr_r.shape
        s = jnp.int32(SENTINEL)
        # Invalid in-block pixels must never source propagation: pin them to
        # the sentinel before the first iteration reads them as neighbors.
        vr_r = jnp.where(valid, vr_r, s)
        vr_c = jnp.where(valid, vr_c, s)

        def shifted(x, dr, dc):
            xp = jnp.pad(x, 1, constant_values=s)
            return jax.lax.slice(xp, (1 + dr, 1 + dc), (1 + dr + Hp, 1 + dc + Wp))

        def dist2(rr, cc, pr, pc):
            dr_ = rr - pr
            dc_ = cc - pc
            return dr_ * dr_ + dc_ * dc_

        def cond(carry):
            _, _, changed, it = carry
            return changed & (it < max_iters)

        def body(carry):
            vr_r, vr_c, _, it = carry
            br, bc = vr_r, vr_c
            bd = dist2(row, col, br, bc)
            for dr, dc in offsets:
                cr, cc_ = shifted(vr_r, dr, dc), shifted(vr_c, dr, dc)
                cd = dist2(row, col, cr, cc_)
                upd = cd < bd
                br = jnp.where(upd, cr, br)
                bc = jnp.where(upd, cc_, bc)
                bd = jnp.where(upd, cd, bd)
            br = jnp.where(valid, br, s)
            bc = jnp.where(valid, bc, s)
            changed = jnp.any((br != vr_r) | (bc != vr_c))
            return br, bc, changed, it + 1

        vr_r, vr_c, _, iters = jax.lax.while_loop(
            cond, body, (vr_r, vr_c, jnp.bool_(True), jnp.int32(0)))
        if batched:
            or_ref[0] = vr_r
            oc_ref[0] = vr_c
            iters_ref[0, 0, 0] = iters
        else:
            or_ref[...] = vr_r
            oc_ref[...] = vr_c
            iters_ref[0, 0] = iters

    return kernel


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters", "interpret"))
def edt_tile_solve(vr_r, vr_c, valid, row, col, *, connectivity: int = 8,
                   max_iters: int = 1024, interpret: bool = True):
    """Drain one (T+2, T+2) EDT halo block.  Returns (vr_r, vr_c, iters)."""
    kernel = _make_kernel(connectivity, max_iters)
    shp = vr_r.shape
    out_shape = (
        jax.ShapeDtypeStruct(shp, vr_r.dtype),
        jax.ShapeDtypeStruct(shp, vr_c.dtype),
        jax.ShapeDtypeStruct((1, 1), jnp.int32),
    )
    full = lambda s: pl.BlockSpec(s, lambda: (0, 0))
    o_r, o_c, iters = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        in_specs=[full(shp)] * 5,
        out_specs=(full(shp), full(shp), full((1, 1))),
        interpret=interpret,
    )(vr_r, vr_c, valid, row, col)
    return o_r, o_c, iters[0, 0]


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters", "interpret"))
def edt_tile_solve_batched(vr_r, vr_c, valid, row, col, *, connectivity: int = 8,
                           max_iters: int = 1024, interpret: bool = True):
    """Drain a (K, T+2, T+2) batch of EDT halo blocks concurrently.

    Returns (vr_r, vr_c, iters) with iters shaped (K,); each grid step
    iterates its own block to stability independently.
    """
    K, Hp, Wp = vr_r.shape
    kernel = _make_kernel(connectivity, max_iters, batched=True)
    out_shape = (
        jax.ShapeDtypeStruct((K, Hp, Wp), vr_r.dtype),
        jax.ShapeDtypeStruct((K, Hp, Wp), vr_c.dtype),
        jax.ShapeDtypeStruct((K, 1, 1), jnp.int32),
    )
    blk = pl.BlockSpec((1, Hp, Wp), lambda k: (k, 0, 0))
    o_r, o_c, iters = pl.pallas_call(
        kernel,
        grid=(K,),
        out_shape=out_shape,
        in_specs=[blk] * 5,
        out_specs=(blk, blk, pl.BlockSpec((1, 1, 1), lambda k: (k, 0, 0))),
        interpret=interpret,
    )(vr_r, vr_c, valid, row, col)
    return o_r, o_c, iters[:, 0, 0]
