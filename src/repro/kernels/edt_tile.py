"""Pallas TPU kernel: drain one EDT (Voronoi-pointer) tile in VMEM.

Same structure as morph_tile: the halo block (``(T+2, T+2)`` in 2D,
``(T+2, T+2, T+2)`` in 3D — DESIGN.md §2.7) iterates the neighbor
candidate min-reduction to local stability without leaving VMEM.
Distances are int32 (exact for grids < 8192 with the far sentinel; see
repro.edt.ref.SENTINEL).  This kernel replaces Algorithm 6's atomicCAS retry
loop with a race-free vector reduction — the TPU-native adaptation.

Entry points come in two spellings:

* rank-generic ``*_nd`` — stacked ``(ndim, *spatial)`` pointer/coordinate
  arrays, one plane per spatial axis (what the engine adapters call);
* the historical 2D ``(vr_r, vr_c, valid, row, col)`` signatures, kept as
  thin wrappers over the ``*_nd`` forms.

:func:`edt_tile_solve_batched` drains a (K, T+2, ...) batch with a
``pallas_call`` grid over the batch dimension (DESIGN.md §2 "batched queue
drain"); each grid step converges independently.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.geometry import ravel_index, unravel_index
from repro.core.pattern import offsets_for
from repro.edt.ref import SENTINEL
from repro.kernels.queue import fit_seed as _fit_seed
from repro.kernels.queue import queued_fixed_point


def _full(shape):
    shape = tuple(shape)
    return pl.BlockSpec(shape, lambda: (0,) * len(shape))


def _batch_blk(spatial):
    spatial = tuple(spatial)
    return pl.BlockSpec((1,) + spatial, lambda k: (k,) + (0,) * len(spatial))


def _dist2(coords, ptrs):
    d = None
    for g, p in zip(coords, ptrs):
        dd = g - p
        d = dd * dd if d is None else d + dd * dd
    return d


def _make_kernel(connectivity, max_iters: int, batched: bool = False):
    offsets = offsets_for(connectivity)
    ndim = len(offsets[0])

    def kernel(*refs):
        ins = refs[:2 * ndim + 1]
        outs = refs[2 * ndim + 1:]
        if batched:  # refs carry a leading (1,)-block batch dim under the grid
            vr = [r[0] for r in ins[:ndim]]
            valid = ins[ndim][0]
            coords = [r[0] for r in ins[ndim + 1:]]
        else:
            vr = [r[...] for r in ins[:ndim]]
            valid = ins[ndim][...]
            coords = [r[...] for r in ins[ndim + 1:]]
        shp = valid.shape
        s = jnp.int32(SENTINEL)
        # Invalid in-block pixels must never source propagation: pin them to
        # the sentinel before the first iteration reads them as neighbors.
        vr = [jnp.where(valid, p, s) for p in vr]

        def shifted(x, off):
            xp = jnp.pad(x, 1, constant_values=s)
            return jax.lax.slice(xp, tuple(1 + d for d in off),
                                 tuple(1 + d + n for d, n in zip(off, shp)))

        def cond(carry):
            _, changed, it = carry
            return changed & (it < max_iters)

        def body(carry):
            vr, _, it = carry
            best = list(vr)
            bd = _dist2(coords, best)
            for off in offsets:
                cand = [shifted(p, off) for p in vr]
                cd = _dist2(coords, cand)
                upd = cd < bd
                best = [jnp.where(upd, cp, bp) for cp, bp in zip(cand, best)]
                bd = jnp.where(upd, cd, bd)
            best = [jnp.where(valid, bp, s) for bp in best]
            changed = jnp.bool_(False)
            for bp, p in zip(best, vr):
                changed = changed | jnp.any(bp != p)
            return tuple(best), changed, it + 1

        vr, _, iters = jax.lax.while_loop(
            cond, body, (tuple(vr), jnp.bool_(True), jnp.int32(0)))
        if batched:
            for o_ref, p in zip(outs[:ndim], vr):
                o_ref[0] = p
            outs[ndim][0, 0, 0] = iters
        else:
            for o_ref, p in zip(outs[:ndim], vr):
                o_ref[...] = p
            outs[ndim][0, 0] = iters

    return kernel


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters", "interpret"))
def edt_tile_solve_nd(vr, valid, coords, *, connectivity=8,
                      max_iters: int = 1024, interpret: bool = True):
    """Drain one EDT halo block, any spatial rank.

    ``vr``/``coords``: (ndim, *spatial) stacked pointer/coordinate planes;
    ``valid``: (*spatial,) bool.  Returns (vr_out, iters).
    """
    ndim = vr.shape[0]
    shp = valid.shape
    kernel = _make_kernel(connectivity, max_iters)
    out_shape = tuple(jax.ShapeDtypeStruct(shp, vr.dtype) for _ in range(ndim))
    out_shape += (jax.ShapeDtypeStruct((1, 1), jnp.int32),)
    outs = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        in_specs=[_full(shp)] * (2 * ndim + 1),
        out_specs=tuple([_full(shp)] * ndim) + (_full((1, 1)),),
        interpret=interpret,
    )(*[vr[i] for i in range(ndim)], valid, *[coords[i] for i in range(ndim)])
    return jnp.stack(outs[:ndim]), outs[ndim][0, 0]


def edt_tile_solve(vr_r, vr_c, valid, row, col, *, connectivity=8,
                   max_iters: int = 1024, interpret: bool = True):
    """Drain one (T+2, T+2) EDT halo block.  Returns (vr_r, vr_c, iters) —
    the historical 2D spelling of :func:`edt_tile_solve_nd`."""
    o, iters = edt_tile_solve_nd(
        jnp.stack([vr_r, vr_c]), valid, jnp.stack([row, col]),
        connectivity=connectivity, max_iters=max_iters, interpret=interpret)
    return o[0], o[1], iters


def _make_queued_kernel(connectivity, max_iters: int, capacity: int,
                        batched: bool = False, seeded: bool = False):
    """Queued EDT variant (DESIGN.md §2.5), push formulation: the queue
    holds last round's improved pixels; each round gathers only their
    pre-round pointers and pushes them to neighbors with one sequential
    scatter pass per offset, in the dense kernel's offset order.  Each pass
    compares against the target's *current* (partially updated) pointer —
    the dense round's evolving per-pixel best accumulator — so even Voronoi
    *tie* resolution, not just distances, is bit-identical to
    :func:`_make_kernel`, as is the iteration count.  Queue overflow spills
    to one dense full-block round.

    ``seeded`` adds two input refs (resident queue indices + live count,
    DESIGN.md §2.6) and starts the drain from them, skipping the O(block)
    seeding sweep."""
    offsets = offsets_for(connectivity)
    ndim = len(offsets[0])

    def kernel(*refs):
        ins = refs[:2 * ndim + 1]
        rest = refs[2 * ndim + 1:]
        if seeded:
            seed_ref, cnt_ref = rest[0], rest[1]
            out_refs = rest[2:2 + ndim]
            iters_ref, spills_ref = rest[2 + ndim], rest[3 + ndim]
        else:
            out_refs = rest[0:ndim]
            iters_ref, spills_ref = rest[ndim], rest[ndim + 1]
        if batched:  # refs carry a leading (1,)-block batch dim under the grid
            vr = [r[0] for r in ins[:ndim]]
            valid = ins[ndim][0]
            coords = [r[0] for r in ins[ndim + 1:]]
        else:
            vr = [r[...] for r in ins[:ndim]]
            valid = ins[ndim][...]
            coords = [r[...] for r in ins[ndim + 1:]]
        shp = valid.shape
        n = math.prod(shp)
        s = jnp.int32(SENTINEL)
        vr = [jnp.where(valid, p, s) for p in vr]

        def shifted(x, off):
            xp = jnp.pad(x, 1, constant_values=s)
            return jax.lax.slice(xp, tuple(1 + d for d in off),
                                 tuple(1 + d + m for d, m in zip(off, shp)))

        def dense_round(carry):
            # Same body as the dense kernel's while-loop step.
            vr = carry
            best = list(vr)
            bd = _dist2(coords, best)
            for off in offsets:
                cand = [shifted(p, off) for p in vr]
                cd = _dist2(coords, cand)
                upd = cd < bd
                best = [jnp.where(upd, cp, bp) for cp, bp in zip(cand, best)]
                bd = jnp.where(upd, cd, bd)
            best = [jnp.where(valid, bp, s) for bp in best]
            changed = jnp.zeros(shp, dtype=bool)
            for bp, p in zip(best, vr):
                changed = changed | (bp != p)
            return tuple(best), changed

        coord_flat = [g.reshape(-1) for g in coords]
        valid_flat = valid.reshape(-1)

        def queued_round(carry, queue):
            # Push formulation: gather the queued sources' pre-round pointers
            # once, then one sequential scatter pass per offset in the dense
            # kernel's order.  Each pass reads the target's current pointer —
            # the dense round's evolving best accumulator — and targets are
            # unique within a pass (distinct sources, one common shift), so
            # every scatter is race-free and deterministic.
            pf = [p.reshape(-1) for p in carry]
            live = queue >= 0
            src = jnp.where(live, queue, 0)
            ptr = [f[src] for f in pf]     # pre-round source pointers (offers)
            sglob = [g[src] for g in coord_flat]  # global coords are affine in
            sco = unravel_index(src, shp)         # the local index, so target
            tgts, flags = [], []                  # coords are arithmetic
            for off in offsets:
                # The pixel that reads source s under offset d is t = s - d:
                # dense's shifted() hands p the neighbor at p + d.
                tco = tuple(c - d for c, d in zip(sco, off))
                inb = live
                for c, m in zip(tco, shp):
                    inb = inb & (c >= 0) & (c < m)
                tg = jnp.where(inb, ravel_index(tco, shp), n)  # n -> dropped
                tglob = [g - d for g, d in zip(sglob, off)]
                cd = _dist2(tglob, ptr)
                od = _dist2(tglob, [jnp.take(f, tg, mode="fill",
                                             fill_value=SENTINEL) for f in pf])
                upd = (inb & (cd < od)
                       & jnp.take(valid_flat, tg, mode="fill", fill_value=False))
                tdrop = jnp.where(upd, tg, n)
                pf = [f.at[tdrop].set(p, mode="drop") for f, p in zip(pf, ptr)]
                tgts.append(tg)
                flags.append(upd)
            return (tuple(f.reshape(shp) for f in pf),
                    jnp.concatenate(tgts), jnp.concatenate(flags))

        initial_queue = None
        if seeded:
            if batched:
                initial_queue = (seed_ref[0], cnt_ref[0, 0, 0])
            else:
                initial_queue = (seed_ref[0], cnt_ref[0, 0])
        vr, iters, spills = queued_fixed_point(
            dense_round, queued_round, tuple(vr),
            max_iters=max_iters, capacity=capacity,
            initial_queue=initial_queue)
        if batched:
            for o_ref, p in zip(out_refs, vr):
                o_ref[0] = p
            iters_ref[0, 0, 0] = iters
            spills_ref[0, 0, 0] = spills
        else:
            for o_ref, p in zip(out_refs, vr):
                o_ref[...] = p
            iters_ref[0, 0] = iters
            spills_ref[0, 0] = spills

    return kernel


def _clip_capacity(queue_capacity: int, n: int, n_offsets: int) -> int:
    # The queue counts per-contribution (duplicates included), so up to
    # n_offsets*n slots are meaningful — that capacity can never overflow.
    return max(1, min(int(queue_capacity), n_offsets * n))


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters",
                                             "queue_capacity", "interpret"))
def edt_tile_solve_queued_nd(vr, valid, coords, seed=None, *, connectivity=8,
                             max_iters: int = 1024, queue_capacity: int = 64,
                             interpret: bool = True):
    """Queued drain of one EDT halo block, any rank (DESIGN.md §2.5).

    ``vr``/``coords``: (ndim, *spatial).  Returns (vr_out, iters, spills) —
    pointer planes and iters bit-identical to :func:`edt_tile_solve_nd`;
    ``spills`` counts overflow rounds that fell back to a dense sweep.

    ``seed`` — optional resident queue ``(indices, count)`` (DESIGN.md
    §2.6; see :func:`repro.kernels.morph_tile.morph_tile_solve_queued` for
    the contract): start the drain from a known frontier instead of the
    O(block) seeding sweep.
    """
    ndim = vr.shape[0]
    shp = valid.shape
    n_off = len(offsets_for(connectivity))
    cap = _clip_capacity(queue_capacity, math.prod(shp), n_off)
    kernel = _make_queued_kernel(connectivity, max_iters, cap,
                                 seeded=seed is not None)
    out_shape = tuple(jax.ShapeDtypeStruct(shp, vr.dtype) for _ in range(ndim))
    out_shape += (jax.ShapeDtypeStruct((1, 1), jnp.int32),
                  jax.ShapeDtypeStruct((1, 1), jnp.int32))
    in_specs = [_full(shp)] * (2 * ndim + 1)
    args = tuple(vr[i] for i in range(ndim)) + (valid,)
    args += tuple(coords[i] for i in range(ndim))
    if seed is not None:
        sq, cnt = seed
        sq = _fit_seed(sq, cap)[None, :]            # (1, cap)
        cnt = jnp.asarray(cnt, jnp.int32).reshape(1, 1)
        in_specs += [_full(sq.shape), _full((1, 1))]
        args += (sq, cnt)
    outs = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        in_specs=in_specs,
        out_specs=tuple([_full(shp)] * ndim) + (_full((1, 1)), _full((1, 1))),
        interpret=interpret,
    )(*args)
    return jnp.stack(outs[:ndim]), outs[ndim][0, 0], outs[ndim + 1][0, 0]


def edt_tile_solve_queued(vr_r, vr_c, valid, row, col, seed=None, *,
                          connectivity=8,
                          max_iters: int = 1024, queue_capacity: int = 64,
                          interpret: bool = True):
    """Queued drain of one 2D EDT halo block — the historical spelling of
    :func:`edt_tile_solve_queued_nd`.  Returns (vr_r, vr_c, iters, spills)."""
    o, iters, spills = edt_tile_solve_queued_nd(
        jnp.stack([vr_r, vr_c]), valid, jnp.stack([row, col]), seed,
        connectivity=connectivity, max_iters=max_iters,
        queue_capacity=queue_capacity, interpret=interpret)
    return o[0], o[1], iters, spills


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters",
                                             "queue_capacity", "interpret"))
def edt_tile_solve_queued_batched_nd(vr, valid, coords, seed=None, *,
                                     connectivity=8, max_iters: int = 1024,
                                     queue_capacity: int = 64,
                                     interpret: bool = True):
    """Queued drain of a (K, ndim, *spatial) EDT batch; one local queue per
    grid step.  Returns (vr_out, iters, spills) with (K,) counters.

    ``seed`` — optional per-block resident queues ``(indices, counts)``
    with shapes (K, n) / (K,)."""
    K, ndim = vr.shape[0], vr.shape[1]
    spatial = valid.shape[1:]
    n_off = len(offsets_for(connectivity))
    cap = _clip_capacity(queue_capacity, math.prod(spatial), n_off)
    kernel = _make_queued_kernel(connectivity, max_iters, cap, batched=True,
                                 seeded=seed is not None)
    out_shape = tuple(jax.ShapeDtypeStruct((K,) + spatial, vr.dtype)
                      for _ in range(ndim))
    out_shape += (jax.ShapeDtypeStruct((K, 1, 1), jnp.int32),
                  jax.ShapeDtypeStruct((K, 1, 1), jnp.int32))
    blk = _batch_blk(spatial)
    scalar = pl.BlockSpec((1, 1, 1), lambda k: (k, 0, 0))
    in_specs = [blk] * (2 * ndim + 1)
    args = tuple(vr[:, i] for i in range(ndim)) + (valid,)
    args += tuple(coords[:, i] for i in range(ndim))
    if seed is not None:
        sq, cnt = seed
        sq = jax.vmap(lambda s_: _fit_seed(s_, cap))(sq)      # (K, cap)
        cnt = jnp.asarray(cnt, jnp.int32).reshape(K, 1, 1)
        in_specs += [pl.BlockSpec((1, cap), lambda k: (k, 0)), scalar]
        args += (sq, cnt)
    outs = pl.pallas_call(
        kernel,
        grid=(K,),
        out_shape=out_shape,
        in_specs=in_specs,
        out_specs=tuple([blk] * ndim) + (scalar, scalar),
        interpret=interpret,
    )(*args)
    return (jnp.stack(outs[:ndim], axis=1),
            outs[ndim][:, 0, 0], outs[ndim + 1][:, 0, 0])


def edt_tile_solve_queued_batched(vr_r, vr_c, valid, row, col, seed=None, *,
                                  connectivity=8, max_iters: int = 1024,
                                  queue_capacity: int = 64,
                                  interpret: bool = True):
    """Queued drain of a (K, T+2, T+2) 2D EDT batch — historical spelling of
    :func:`edt_tile_solve_queued_batched_nd`."""
    o, iters, spills = edt_tile_solve_queued_batched_nd(
        jnp.stack([vr_r, vr_c], axis=1), valid,
        jnp.stack([row, col], axis=1), seed,
        connectivity=connectivity, max_iters=max_iters,
        queue_capacity=queue_capacity, interpret=interpret)
    return o[:, 0], o[:, 1], iters, spills


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters", "interpret"))
def edt_tile_solve_batched_nd(vr, valid, coords, *, connectivity=8,
                              max_iters: int = 1024, interpret: bool = True):
    """Drain a (K, ndim, *spatial) batch of EDT halo blocks concurrently.

    Returns (vr_out, iters) with iters shaped (K,); each grid step iterates
    its own block to stability independently.
    """
    K, ndim = vr.shape[0], vr.shape[1]
    spatial = valid.shape[1:]
    kernel = _make_kernel(connectivity, max_iters, batched=True)
    out_shape = tuple(jax.ShapeDtypeStruct((K,) + spatial, vr.dtype)
                      for _ in range(ndim))
    out_shape += (jax.ShapeDtypeStruct((K, 1, 1), jnp.int32),)
    blk = _batch_blk(spatial)
    outs = pl.pallas_call(
        kernel,
        grid=(K,),
        out_shape=out_shape,
        in_specs=[blk] * (2 * ndim + 1),
        out_specs=tuple([blk] * ndim) + (pl.BlockSpec((1, 1, 1), lambda k: (k, 0, 0)),),
        interpret=interpret,
    )(*[vr[:, i] for i in range(ndim)], valid, *[coords[:, i] for i in range(ndim)])
    return jnp.stack(outs[:ndim], axis=1), outs[ndim][:, 0, 0]


def edt_tile_solve_batched(vr_r, vr_c, valid, row, col, *, connectivity=8,
                           max_iters: int = 1024, interpret: bool = True):
    """Drain a (K, T+2, T+2) batch of 2D EDT halo blocks — historical
    spelling of :func:`edt_tile_solve_batched_nd`."""
    o, iters = edt_tile_solve_batched_nd(
        jnp.stack([vr_r, vr_c], axis=1), valid,
        jnp.stack([row, col], axis=1),
        connectivity=connectivity, max_iters=max_iters, interpret=interpret)
    return o[:, 0], o[:, 1], iters
