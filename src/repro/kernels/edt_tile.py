"""Pallas TPU kernel: drain one EDT (Voronoi-pointer) tile in VMEM.

Same structure as morph_tile: the (T+2, T+2) halo block iterates the
8-neighbor candidate min-reduction to local stability without leaving VMEM.
Distances are int32 (exact for grids < 8192 with the far sentinel; see
repro.edt.ref.SENTINEL).  This kernel replaces Algorithm 6's atomicCAS retry
loop with a race-free vector reduction — the TPU-native adaptation.

:func:`edt_tile_solve_batched` drains a (K, T+2, T+2) batch with a
``pallas_call`` grid over the batch dimension (DESIGN.md §2 "batched queue
drain"); each grid step converges independently.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pattern import offsets_for
from repro.edt.ref import SENTINEL
from repro.kernels.queue import fit_seed as _fit_seed
from repro.kernels.queue import queued_fixed_point


def _make_kernel(connectivity: int, max_iters: int, batched: bool = False):
    offsets = offsets_for(connectivity)

    def kernel(vr_r_ref, vr_c_ref, valid_ref, row_ref, col_ref, or_ref, oc_ref, iters_ref):
        if batched:  # refs carry a leading (1,)-block batch dim under the grid
            vr_r, vr_c = vr_r_ref[0], vr_c_ref[0]
            valid = valid_ref[0]
            row, col = row_ref[0], col_ref[0]
        else:
            vr_r, vr_c = vr_r_ref[...], vr_c_ref[...]
            valid = valid_ref[...]
            row, col = row_ref[...], col_ref[...]
        Hp, Wp = vr_r.shape
        s = jnp.int32(SENTINEL)
        # Invalid in-block pixels must never source propagation: pin them to
        # the sentinel before the first iteration reads them as neighbors.
        vr_r = jnp.where(valid, vr_r, s)
        vr_c = jnp.where(valid, vr_c, s)

        def shifted(x, dr, dc):
            xp = jnp.pad(x, 1, constant_values=s)
            return jax.lax.slice(xp, (1 + dr, 1 + dc), (1 + dr + Hp, 1 + dc + Wp))

        def dist2(rr, cc, pr, pc):
            dr_ = rr - pr
            dc_ = cc - pc
            return dr_ * dr_ + dc_ * dc_

        def cond(carry):
            _, _, changed, it = carry
            return changed & (it < max_iters)

        def body(carry):
            vr_r, vr_c, _, it = carry
            br, bc = vr_r, vr_c
            bd = dist2(row, col, br, bc)
            for dr, dc in offsets:
                cr, cc_ = shifted(vr_r, dr, dc), shifted(vr_c, dr, dc)
                cd = dist2(row, col, cr, cc_)
                upd = cd < bd
                br = jnp.where(upd, cr, br)
                bc = jnp.where(upd, cc_, bc)
                bd = jnp.where(upd, cd, bd)
            br = jnp.where(valid, br, s)
            bc = jnp.where(valid, bc, s)
            changed = jnp.any((br != vr_r) | (bc != vr_c))
            return br, bc, changed, it + 1

        vr_r, vr_c, _, iters = jax.lax.while_loop(
            cond, body, (vr_r, vr_c, jnp.bool_(True), jnp.int32(0)))
        if batched:
            or_ref[0] = vr_r
            oc_ref[0] = vr_c
            iters_ref[0, 0, 0] = iters
        else:
            or_ref[...] = vr_r
            oc_ref[...] = vr_c
            iters_ref[0, 0] = iters

    return kernel


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters", "interpret"))
def edt_tile_solve(vr_r, vr_c, valid, row, col, *, connectivity: int = 8,
                   max_iters: int = 1024, interpret: bool = True):
    """Drain one (T+2, T+2) EDT halo block.  Returns (vr_r, vr_c, iters)."""
    kernel = _make_kernel(connectivity, max_iters)
    shp = vr_r.shape
    out_shape = (
        jax.ShapeDtypeStruct(shp, vr_r.dtype),
        jax.ShapeDtypeStruct(shp, vr_c.dtype),
        jax.ShapeDtypeStruct((1, 1), jnp.int32),
    )
    full = lambda s: pl.BlockSpec(s, lambda: (0, 0))
    o_r, o_c, iters = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        in_specs=[full(shp)] * 5,
        out_specs=(full(shp), full(shp), full((1, 1))),
        interpret=interpret,
    )(vr_r, vr_c, valid, row, col)
    return o_r, o_c, iters[0, 0]


def _make_queued_kernel(connectivity: int, max_iters: int, capacity: int,
                        batched: bool = False, seeded: bool = False):
    """Queued EDT variant (DESIGN.md §2.5), push formulation: the queue
    holds last round's improved pixels; each round gathers only their
    pre-round pointers and pushes them to neighbors with one sequential
    scatter pass per offset, in the dense kernel's offset order.  Each pass
    compares against the target's *current* (partially updated) pointer —
    the dense round's evolving per-pixel best accumulator — so even Voronoi
    *tie* resolution, not just distances, is bit-identical to
    :func:`_make_kernel`, as is the iteration count.  Queue overflow spills
    to one dense full-block round.

    ``seeded`` adds two input refs (resident queue indices + live count,
    DESIGN.md §2.6) and starts the drain from them, skipping the O(block)
    seeding sweep."""
    offsets = offsets_for(connectivity)

    def kernel(vr_r_ref, vr_c_ref, valid_ref, row_ref, col_ref, *refs):
        if seeded:
            seed_ref, cnt_ref = refs[0], refs[1]
            or_ref, oc_ref, iters_ref, spills_ref = refs[2:6]
        else:
            or_ref, oc_ref, iters_ref, spills_ref = refs[0:4]
        if batched:  # refs carry a leading (1,)-block batch dim under the grid
            vr_r, vr_c = vr_r_ref[0], vr_c_ref[0]
            valid = valid_ref[0]
            row, col = row_ref[0], col_ref[0]
        else:
            vr_r, vr_c = vr_r_ref[...], vr_c_ref[...]
            valid = valid_ref[...]
            row, col = row_ref[...], col_ref[...]
        Hp, Wp = vr_r.shape
        n = Hp * Wp
        s = jnp.int32(SENTINEL)
        vr_r = jnp.where(valid, vr_r, s)
        vr_c = jnp.where(valid, vr_c, s)

        def dist2(rr, cc, pr, pc):
            dr_ = rr - pr
            dc_ = cc - pc
            return dr_ * dr_ + dc_ * dc_

        def shifted(x, dr, dc):
            xp = jnp.pad(x, 1, constant_values=s)
            return jax.lax.slice(xp, (1 + dr, 1 + dc), (1 + dr + Hp, 1 + dc + Wp))

        def dense_round(carry):
            # Same body as the dense kernel's while-loop step.
            vr_r, vr_c = carry
            br, bc = vr_r, vr_c
            bd = dist2(row, col, br, bc)
            for dr, dc in offsets:
                cr, cc_ = shifted(vr_r, dr, dc), shifted(vr_c, dr, dc)
                cd = dist2(row, col, cr, cc_)
                upd = cd < bd
                br = jnp.where(upd, cr, br)
                bc = jnp.where(upd, cc_, bc)
                bd = jnp.where(upd, cd, bd)
            br = jnp.where(valid, br, s)
            bc = jnp.where(valid, bc, s)
            return (br, bc), (br != vr_r) | (bc != vr_c)

        row_flat = row.reshape(-1)
        col_flat = col.reshape(-1)
        valid_flat = valid.reshape(-1)

        def queued_round(carry, queue):
            # Push formulation: gather the queued sources' pre-round pointers
            # once, then one sequential scatter pass per offset in the dense
            # kernel's order.  Each pass reads the target's current pointer —
            # the dense round's evolving best accumulator — and targets are
            # unique within a pass (distinct sources, one common shift), so
            # every scatter is race-free and deterministic.
            vr_r, vr_c = carry
            rf = vr_r.reshape(-1)
            cf = vr_c.reshape(-1)
            live = queue >= 0
            src = jnp.where(live, queue, 0)
            pr = rf[src]          # pre-round source pointers (the offers)
            pc = cf[src]
            srow = row_flat[src]  # global coords are affine in the local
            scol = col_flat[src]  # index, so target coords are arithmetic
            sr, sc = src // Wp, src % Wp
            tgts, flags = [], []
            for dr, dc in offsets:
                # The pixel that reads source s under offset (dr, dc) is
                # t = s - (dr, dc): dense's shifted() hands (i, j) the
                # neighbor at (i + dr, j + dc).
                tr, tc = sr - dr, sc - dc
                inb = live & (tr >= 0) & (tr < Hp) & (tc >= 0) & (tc < Wp)
                tg = jnp.where(inb, tr * Wp + tc, n)  # n -> dropped
                trow, tcol = srow - dr, scol - dc
                cd = dist2(trow, tcol, pr, pc)
                od = dist2(trow, tcol,
                           jnp.take(rf, tg, mode="fill", fill_value=SENTINEL),
                           jnp.take(cf, tg, mode="fill", fill_value=SENTINEL))
                upd = (inb & (cd < od)
                       & jnp.take(valid_flat, tg, mode="fill", fill_value=False))
                tdrop = jnp.where(upd, tg, n)
                rf = rf.at[tdrop].set(pr, mode="drop")
                cf = cf.at[tdrop].set(pc, mode="drop")
                tgts.append(tg)
                flags.append(upd)
            return ((rf.reshape(Hp, Wp), cf.reshape(Hp, Wp)),
                    jnp.concatenate(tgts), jnp.concatenate(flags))

        initial_queue = None
        if seeded:
            if batched:
                initial_queue = (seed_ref[0], cnt_ref[0, 0, 0])
            else:
                initial_queue = (seed_ref[0], cnt_ref[0, 0])
        (vr_r, vr_c), iters, spills = queued_fixed_point(
            dense_round, queued_round, (vr_r, vr_c),
            max_iters=max_iters, capacity=capacity,
            initial_queue=initial_queue)
        if batched:
            or_ref[0] = vr_r
            oc_ref[0] = vr_c
            iters_ref[0, 0, 0] = iters
            spills_ref[0, 0, 0] = spills
        else:
            or_ref[...] = vr_r
            oc_ref[...] = vr_c
            iters_ref[0, 0] = iters
            spills_ref[0, 0] = spills

    return kernel


def _clip_capacity(queue_capacity: int, n: int) -> int:
    # The queue counts per-contribution (duplicates included), so up to 8*n
    # slots are meaningful — a capacity of 8*n can never overflow.
    return max(1, min(int(queue_capacity), 8 * n))


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters",
                                             "queue_capacity", "interpret"))
def edt_tile_solve_queued(vr_r, vr_c, valid, row, col, seed=None, *,
                          connectivity: int = 8,
                          max_iters: int = 1024, queue_capacity: int = 64,
                          interpret: bool = True):
    """Queued drain of one EDT halo block (DESIGN.md §2.5).

    Returns (vr_r, vr_c, iters, spills) — pointer planes and iters
    bit-identical to :func:`edt_tile_solve`; ``spills`` counts overflow
    rounds that fell back to a dense sweep.

    ``seed`` — optional resident queue ``(indices, count)`` (DESIGN.md
    §2.6; see :func:`repro.kernels.morph_tile.morph_tile_solve_queued` for
    the contract): start the drain from a known frontier instead of the
    O(block) seeding sweep.
    """
    shp = vr_r.shape
    cap = _clip_capacity(queue_capacity, shp[0] * shp[1])
    kernel = _make_queued_kernel(connectivity, max_iters, cap,
                                 seeded=seed is not None)
    out_shape = (
        jax.ShapeDtypeStruct(shp, vr_r.dtype),
        jax.ShapeDtypeStruct(shp, vr_c.dtype),
        jax.ShapeDtypeStruct((1, 1), jnp.int32),
        jax.ShapeDtypeStruct((1, 1), jnp.int32),
    )
    full = lambda s_: pl.BlockSpec(s_, lambda: (0, 0))
    in_specs = [full(shp)] * 5
    args = (vr_r, vr_c, valid, row, col)
    if seed is not None:
        sq, cnt = seed
        sq = _fit_seed(sq, cap)[None, :]            # (1, cap)
        cnt = jnp.asarray(cnt, jnp.int32).reshape(1, 1)
        in_specs += [full(sq.shape), full((1, 1))]
        args += (sq, cnt)
    o_r, o_c, iters, spills = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        in_specs=in_specs,
        out_specs=(full(shp), full(shp), full((1, 1)), full((1, 1))),
        interpret=interpret,
    )(*args)
    return o_r, o_c, iters[0, 0], spills[0, 0]


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters",
                                             "queue_capacity", "interpret"))
def edt_tile_solve_queued_batched(vr_r, vr_c, valid, row, col, seed=None, *,
                                  connectivity: int = 8, max_iters: int = 1024,
                                  queue_capacity: int = 64,
                                  interpret: bool = True):
    """Queued drain of a (K, T+2, T+2) EDT batch; one local queue per grid
    step.  Returns (vr_r, vr_c, iters, spills) with (K,) counters.

    ``seed`` — optional per-block resident queues ``(indices, counts)``
    with shapes (K, n) / (K,)."""
    K, Hp, Wp = vr_r.shape
    cap = _clip_capacity(queue_capacity, Hp * Wp)
    kernel = _make_queued_kernel(connectivity, max_iters, cap, batched=True,
                                 seeded=seed is not None)
    out_shape = (
        jax.ShapeDtypeStruct((K, Hp, Wp), vr_r.dtype),
        jax.ShapeDtypeStruct((K, Hp, Wp), vr_c.dtype),
        jax.ShapeDtypeStruct((K, 1, 1), jnp.int32),
        jax.ShapeDtypeStruct((K, 1, 1), jnp.int32),
    )
    blk = pl.BlockSpec((1, Hp, Wp), lambda k: (k, 0, 0))
    scalar = pl.BlockSpec((1, 1, 1), lambda k: (k, 0, 0))
    in_specs = [blk] * 5
    args = (vr_r, vr_c, valid, row, col)
    if seed is not None:
        sq, cnt = seed
        sq = jax.vmap(lambda s_: _fit_seed(s_, cap))(sq)      # (K, cap)
        cnt = jnp.asarray(cnt, jnp.int32).reshape(K, 1, 1)
        in_specs += [pl.BlockSpec((1, cap), lambda k: (k, 0)), scalar]
        args += (sq, cnt)
    o_r, o_c, iters, spills = pl.pallas_call(
        kernel,
        grid=(K,),
        out_shape=out_shape,
        in_specs=in_specs,
        out_specs=(blk, blk, scalar, scalar),
        interpret=interpret,
    )(*args)
    return o_r, o_c, iters[:, 0, 0], spills[:, 0, 0]


@functools.partial(jax.jit, static_argnames=("connectivity", "max_iters", "interpret"))
def edt_tile_solve_batched(vr_r, vr_c, valid, row, col, *, connectivity: int = 8,
                           max_iters: int = 1024, interpret: bool = True):
    """Drain a (K, T+2, T+2) batch of EDT halo blocks concurrently.

    Returns (vr_r, vr_c, iters) with iters shaped (K,); each grid step
    iterates its own block to stability independently.
    """
    K, Hp, Wp = vr_r.shape
    kernel = _make_kernel(connectivity, max_iters, batched=True)
    out_shape = (
        jax.ShapeDtypeStruct((K, Hp, Wp), vr_r.dtype),
        jax.ShapeDtypeStruct((K, Hp, Wp), vr_c.dtype),
        jax.ShapeDtypeStruct((K, 1, 1), jnp.int32),
    )
    blk = pl.BlockSpec((1, Hp, Wp), lambda k: (k, 0, 0))
    o_r, o_c, iters = pl.pallas_call(
        kernel,
        grid=(K,),
        out_shape=out_shape,
        in_specs=[blk] * 5,
        out_specs=(blk, blk, pl.BlockSpec((1, 1, 1), lambda k: (k, 0, 0))),
        interpret=interpret,
    )(vr_r, vr_c, valid, row, col)
    return o_r, o_c, iters[:, 0, 0]
