"""Jit'd public wrappers around the Pallas kernels.

* dtype policy: TPU vector units want >=int16 payloads; uint8 images are
  upcast to int32 for the kernel and cast back (exactness preserved — the
  ops are min/max/compare).
* `tile_solver_morph` / `tile_solver_edt` / `tile_solver_label` adapt the
  kernels to the tiled engine's `tile_solver` interface (block pytree ->
  (block pytree, unconverged)) — the label solver is the *morph kernel
  parametrized* (mask = fg ? LABEL_CAP : 0), the registry-level kernel
  reuse of DESIGN.md §2.4; the `*_batched` variants adapt the grid-over-batch kernels
  to the engine's `batched_tile_solver` interface (leaves carry a leading
  (K,) batch dim — the paper's parallel queue drain, DESIGN.md §2).  The
  same batched contract backs the hybrid engine's device workers
  (`solve(engine="hybrid", hybrid_pallas=True)` — DESIGN.md §2.3), so a
  `DeviceWorker` drains its claimed chunks through these kernels unchanged.
* the adapters take the engine's iteration bound as ``max_iters`` (the
  tiled engine passes its (T+2)² geodesic bound) and report
  ``iters >= max_iters`` as the *unconverged* flag, so a drain cut off at
  the bound is re-queued by the engine instead of silently accepted as a
  fixed point.  The flag is conservative: a drain that stabilized exactly
  at the bound re-queues once and converges immediately on the re-drain.
* every directional raster pass is expressed through the single
  `raster_down` kernel via flips/transposes.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.edt.ops import COORD_LEAVES
from repro.kernels.edt_tile import (edt_tile_solve_batched_nd,
                                    edt_tile_solve_nd,
                                    edt_tile_solve_queued_batched_nd,
                                    edt_tile_solve_queued_nd)
from repro.kernels.morph_tile import (morph_tile_solve,
                                      morph_tile_solve_batched,
                                      morph_tile_solve_queued,
                                      morph_tile_solve_queued_batched)
from repro.kernels.raster_scan import raster_down
from repro.label.ops import LABEL_CAP

DEFAULT_MAX_ITERS = 1024


def default_kernel_queue_capacity(block) -> int:
    """Default in-kernel queue capacity for a halo block.

    ``block`` is the block's spatial shape tuple (an int means a square 2D
    block — the historical spelling).  The queue holds last round's
    *improved* pixels — a propagating wavefront crossing the block is a
    band of O(prod(B)/min(B)) of them (a row of a 2D block, a slab of a 3D
    one).  A push round's cost scales with the capacity whether or not the
    slots are occupied, so the default tracks the band, floored at 64 so
    tiny tiles don't thrash the dense-spill path and capped at the block
    size (a queue bigger than the block is just the block).  See DESIGN.md
    §2.5/§2.7.
    """
    shape = (block, block) if isinstance(block, int) else tuple(block)
    band = math.prod(shape) // min(shape)
    return int(min(math.prod(shape), max(64, band)))


def _up(x):
    if x.dtype in (jnp.uint8, jnp.int8, jnp.uint16, jnp.int16):
        return x.astype(jnp.int32), x.dtype
    return x, None


def morph_tile_pallas(J, I, valid, connectivity: int = 8, interpret: bool = True,
                      max_iters: int = DEFAULT_MAX_ITERS):
    Ju, orig = _up(J)
    Iu, _ = _up(I)
    out, iters = morph_tile_solve(Ju, Iu, valid, connectivity=connectivity,
                                  max_iters=max_iters, interpret=interpret)
    return (out.astype(orig) if orig is not None else out), iters


def tile_solver_morph(connectivity: int = 8, interpret: bool = True,
                      max_iters: int = DEFAULT_MAX_ITERS):
    """Adapter: tiled-engine `tile_solver` backed by the Pallas kernel."""
    def solver(block):
        J, iters = morph_tile_pallas(block["J"], block["I"], block["valid"],
                                     connectivity, interpret, max_iters)
        out = dict(block)
        out["J"] = J
        return out, iters >= max_iters
    return solver


def morph_tile_pallas_batched(J, I, valid, connectivity: int = 8,
                              interpret: bool = True,
                              max_iters: int = DEFAULT_MAX_ITERS):
    """(K, T+2, T+2) batch drain; returns (J_out, iters[K])."""
    Ju, orig = _up(J)
    Iu, _ = _up(I)
    out, iters = morph_tile_solve_batched(Ju, Iu, valid,
                                          connectivity=connectivity,
                                          max_iters=max_iters,
                                          interpret=interpret)
    return (out.astype(orig) if orig is not None else out), iters


def tile_solver_morph_batched(connectivity: int = 8, interpret: bool = True,
                              max_iters: int = DEFAULT_MAX_ITERS):
    """Adapter: tiled-engine `batched_tile_solver` backed by the grid kernel."""
    def solver(blocks):
        J, iters = morph_tile_pallas_batched(blocks["J"], blocks["I"],
                                             blocks["valid"], connectivity,
                                             interpret, max_iters)
        out = dict(blocks)
        out["J"] = J
        return out, iters >= max_iters
    return solver


# LABEL_CAP is an op-level invariant (label_seeds raises above it); here
# it is the "mask" plane value when the morph kernel is parametrized into
# the label solver: min(LABEL_CAP, ·) is then the identity on foreground,
# and 0 clamps background — the masked-max label update.
def _label_as_morph(blocks):
    """Express a label block in morph-kernel terms: J = lab, I = fg-mask."""
    I = jnp.where(blocks["fg"], jnp.int32(LABEL_CAP), jnp.int32(0))
    return blocks["lab"], I


def tile_solver_label(connectivity: int = 8, interpret: bool = True,
                      max_iters: int = DEFAULT_MAX_ITERS):
    """Adapter: the *morph* Pallas kernel, parametrized into the label op's
    masked-max update (DESIGN.md §2.4 — new ops reuse kernels through the
    registry instead of shipping their own)."""
    def solver(block):
        J, I = _label_as_morph(block)
        lab, iters = morph_tile_solve(J, I, block["valid"],
                                      connectivity=connectivity,
                                      max_iters=max_iters,
                                      interpret=interpret)
        out = dict(block)
        out["lab"] = lab
        return out, iters >= max_iters
    return solver


def tile_solver_label_batched(connectivity: int = 8, interpret: bool = True,
                              max_iters: int = DEFAULT_MAX_ITERS):
    """Batched (K, T+2, T+2) variant over the morph grid-over-batch kernel."""
    def solver(blocks):
        J, I = _label_as_morph(blocks)
        lab, iters = morph_tile_solve_batched(J, I, blocks["valid"],
                                              connectivity=connectivity,
                                              max_iters=max_iters,
                                              interpret=interpret)
        out = dict(blocks)
        out["lab"] = lab
        return out, iters >= max_iters
    return solver


def _edt_coords(state_block, ndim: int, stack_axis: int = 0):
    """Stack the op's coordinate leaves ((row, col) or (dep, row, col))
    into the (ndim, *spatial) array the ``*_nd`` kernels take."""
    return jnp.stack([state_block[k] for k in COORD_LEAVES[ndim]],
                     axis=stack_axis)


def edt_tile_pallas(state_block, connectivity=8, interpret: bool = True,
                    max_iters: int = DEFAULT_MAX_ITERS):
    vr = state_block["vr"]  # (ndim, *spatial)
    o, iters = edt_tile_solve_nd(
        vr, state_block["valid"], _edt_coords(state_block, vr.shape[0]),
        connectivity=connectivity, max_iters=max_iters, interpret=interpret)
    out = dict(state_block)
    out["vr"] = o
    return out, iters


def tile_solver_edt(connectivity: int = 8, interpret: bool = True,
                    max_iters: int = DEFAULT_MAX_ITERS):
    def solver(block):
        out, iters = edt_tile_pallas(block, connectivity, interpret, max_iters)
        return out, iters >= max_iters
    return solver


def edt_tile_pallas_batched(state_blocks, connectivity=8,
                            interpret: bool = True,
                            max_iters: int = DEFAULT_MAX_ITERS):
    """Batched EDT drain over leaves with a leading (K,) batch dim."""
    vr = state_blocks["vr"]  # (K, ndim, *spatial)
    o, iters = edt_tile_solve_batched_nd(
        vr, state_blocks["valid"],
        _edt_coords(state_blocks, vr.shape[1], stack_axis=1),
        connectivity=connectivity, max_iters=max_iters, interpret=interpret)
    out = dict(state_blocks)
    out["vr"] = o
    return out, iters


def tile_solver_edt_batched(connectivity: int = 8, interpret: bool = True,
                            max_iters: int = DEFAULT_MAX_ITERS):
    def solver(blocks):
        out, iters = edt_tile_pallas_batched(blocks, connectivity, interpret,
                                             max_iters)
        return out, iters >= max_iters
    return solver


# ---------------------------------------------------------------------------
# Queued-kernel adapters (DESIGN.md §2.5).  Same tile_solver contract as the
# dense adapters above — the per-kernel `spills` counter is an intra-kernel
# diagnostic and is not surfaced through the engine's block pytree.
#
# Every queued solver additionally accepts ``queue=(indices, count)`` — a
# *resident* in-kernel queue (DESIGN.md §2.6): flat block indices of the
# pixels whose values have not yet been offered to their neighbors (compact
# layout, dead slots -1) plus the live count.  When given, the kernel drain
# starts from that frontier and skips its O(block) seeding sweep; a count
# above the kernel's queue capacity safely spills to a dense first round.
# Batched solvers take per-block (K, n) indices and (K,) counts.
# ---------------------------------------------------------------------------

def morph_tile_pallas_queued(J, I, valid, connectivity: int = 8,
                             interpret: bool = True,
                             max_iters: int = DEFAULT_MAX_ITERS,
                             queue_capacity: int | None = None,
                             queue=None):
    if queue_capacity is None:
        queue_capacity = default_kernel_queue_capacity(J.shape)
    Ju, orig = _up(J)
    Iu, _ = _up(I)
    out, iters, spills = morph_tile_solve_queued(
        Ju, Iu, valid, queue, connectivity=connectivity, max_iters=max_iters,
        queue_capacity=queue_capacity, interpret=interpret)
    return (out.astype(orig) if orig is not None else out), iters, spills


def tile_solver_morph_queued(connectivity: int = 8, interpret: bool = True,
                             max_iters: int = DEFAULT_MAX_ITERS,
                             queue_capacity: int | None = None):
    """`tile_solver` backed by the queued morph kernel."""
    def solver(block, queue=None):
        J, iters, _ = morph_tile_pallas_queued(
            block["J"], block["I"], block["valid"], connectivity, interpret,
            max_iters, queue_capacity, queue)
        out = dict(block)
        out["J"] = J
        return out, iters >= max_iters
    return solver


def tile_solver_morph_queued_batched(connectivity: int = 8,
                                     interpret: bool = True,
                                     max_iters: int = DEFAULT_MAX_ITERS,
                                     queue_capacity: int | None = None):
    """`batched_tile_solver` over the queued grid-over-batch morph kernel."""
    def solver(blocks, queue=None):
        cap = (default_kernel_queue_capacity(blocks["J"].shape[1:])
               if queue_capacity is None else queue_capacity)
        Ju, orig = _up(blocks["J"])
        Iu, _ = _up(blocks["I"])
        J, iters, _ = morph_tile_solve_queued_batched(
            Ju, Iu, blocks["valid"], queue, connectivity=connectivity,
            max_iters=max_iters, queue_capacity=cap, interpret=interpret)
        out = dict(blocks)
        out["J"] = J.astype(orig) if orig is not None else J
        return out, iters >= max_iters
    return solver


def tile_solver_label_queued(connectivity: int = 8, interpret: bool = True,
                             max_iters: int = DEFAULT_MAX_ITERS,
                             queue_capacity: int | None = None):
    """Queued morph kernel parametrized into the label masked-max update."""
    def solver(block, queue=None):
        J, I = _label_as_morph(block)
        cap = (default_kernel_queue_capacity(J.shape)
               if queue_capacity is None else queue_capacity)
        lab, iters, _ = morph_tile_solve_queued(
            J, I, block["valid"], queue, connectivity=connectivity,
            max_iters=max_iters, queue_capacity=cap, interpret=interpret)
        out = dict(block)
        out["lab"] = lab
        return out, iters >= max_iters
    return solver


def tile_solver_label_queued_batched(connectivity: int = 8,
                                     interpret: bool = True,
                                     max_iters: int = DEFAULT_MAX_ITERS,
                                     queue_capacity: int | None = None):
    def solver(blocks, queue=None):
        J, I = _label_as_morph(blocks)
        cap = (default_kernel_queue_capacity(J.shape[1:])
               if queue_capacity is None else queue_capacity)
        lab, iters, _ = morph_tile_solve_queued_batched(
            J, I, blocks["valid"], queue, connectivity=connectivity,
            max_iters=max_iters, queue_capacity=cap, interpret=interpret)
        out = dict(blocks)
        out["lab"] = lab
        return out, iters >= max_iters
    return solver


def tile_solver_edt_queued(connectivity=8, interpret: bool = True,
                           max_iters: int = DEFAULT_MAX_ITERS,
                           queue_capacity: int | None = None):
    def solver(block, queue=None):
        vr = block["vr"]
        cap = (default_kernel_queue_capacity(block["valid"].shape)
               if queue_capacity is None else queue_capacity)
        o, iters, _ = edt_tile_solve_queued_nd(
            vr, block["valid"], _edt_coords(block, vr.shape[0]), queue,
            connectivity=connectivity, max_iters=max_iters,
            queue_capacity=cap, interpret=interpret)
        out = dict(block)
        out["vr"] = o
        return out, iters >= max_iters
    return solver


def tile_solver_edt_queued_batched(connectivity=8,
                                   interpret: bool = True,
                                   max_iters: int = DEFAULT_MAX_ITERS,
                                   queue_capacity: int | None = None):
    def solver(blocks, queue=None):
        vr = blocks["vr"]  # (K, ndim, *spatial)
        cap = (default_kernel_queue_capacity(blocks["valid"].shape[1:])
               if queue_capacity is None else queue_capacity)
        o, iters, _ = edt_tile_solve_queued_batched_nd(
            vr, blocks["valid"], _edt_coords(blocks, vr.shape[1], stack_axis=1),
            queue, connectivity=connectivity, max_iters=max_iters,
            queue_capacity=cap, interpret=interpret)
        out = dict(blocks)
        out["vr"] = o
        return out, iters >= max_iters
    return solver


@partial(jax.jit, static_argnames=("interpret",))
def raster_pass_kernel(J, I, interpret: bool = True):
    """Full raster half-pass (left->right then top->down) via the kernel.

    Left->right is the same recurrence on the transpose.
    """
    Ju, orig = _up(J)
    Iu, _ = _up(I)
    Jt = raster_down(Ju.T, Iu.T, interpret=interpret).T     # row-wise forward
    Jv = raster_down(Jt, Iu, interpret=interpret)           # column-wise forward
    return Jv.astype(orig) if orig is not None else Jv


@partial(jax.jit, static_argnames=("interpret",))
def antiraster_pass_kernel(J, I, interpret: bool = True):
    Ju, orig = _up(J)
    Iu, _ = _up(I)
    Jt = raster_down(Ju[:, ::-1].T, Iu[:, ::-1].T, interpret=interpret).T[:, ::-1]
    Jv = raster_down(Jt[::-1], Iu[::-1], interpret=interpret)[::-1]
    return Jv.astype(orig) if orig is not None else Jv
