"""In-kernel multi-level queue: scan-compaction plus the queued drain loop.

The paper's GPU kernels (arXiv:1209.3314 §4) owe their speedup to a
multi-level queue: each thread block keeps a local queue of active pixels in
fast memory and only touches those, instead of sweeping the whole tile every
iteration.  This module is the TPU-native analogue used by the queued
variants of the Pallas tile solvers (DESIGN.md §2.5):

* :func:`compact_mask` — the scan-compaction primitive.  A prefix sum over
  the active mask assigns each active pixel a queue slot; a single scatter
  packs the flattened pixel indices into a fixed-capacity queue.  This is
  the vector formulation of the paper's warp-level prefix-sum queue insert
  (its Figure 7), with the capacity overflow reported instead of hidden.
* :func:`compact_flags` — the same packing for an index list that is
  *already small*: the queued rounds below produce per-contribution
  ``(target index, improved?)`` pairs of length ``F * capacity`` (F =
  neighbor count), so their compaction never touches an O(block) array.
* :func:`dilate` — one step of mask dilation (the candidate set of a
  mask-based round: last round's improved pixels plus their neighbors).
  Kept as the reference formulation; the production drain below is
  push-based and never materializes this mask.
* :func:`queued_fixed_point` — the drain loop, *push* formulation.  One
  unconditional dense round seeds the queue with the improved pixels (the
  paper's raster-init building the initial queue); every later round either
  pushes each queued pixel's value to its neighbors — touching only
  O(capacity) memory — or, when the queue overflowed, *spills* to one dense
  full-block sweep.  Spilling never drops work: the dense round is a
  superset of any queued round, so overflow costs time, not correctness.

Because IWPP updates are commutative and monotone (DESIGN.md §1), enqueuing
a pixel that cannot improve (a duplicate, or an over-eager candidate) is
idempotent: the extra evaluation recomputes the same value.  That is what
makes both the overflow/spill contract and the push rounds' duplicate
targets (two sources improving a common neighbor enqueue it twice) safe.

Everything here runs inside Pallas kernel bodies: index vectors are built
with ``broadcasted_iota`` (1-D ``iota`` does not lower on TPU) and the
compaction is one cumsum + one scatter, both vector-unit friendly.
"""

from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.pattern import shiftnd


def _iota1d(n: int) -> jnp.ndarray:
    """1-D [0..n) index vector via 2-D iota (TPU cannot lower 1-D iota)."""
    return jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0).reshape(n)


def dilate(mask: jnp.ndarray, offsets: Sequence[Tuple[int, ...]]) -> jnp.ndarray:
    """Pixels adjacent (under ``offsets``) to a set pixel.

    Every ``Neighborhood`` offset table is symmetric, so shifting the mask
    by each offset covers both "my neighbor changed" directions.  The result
    does *not* include ``mask`` itself — callers union it in explicitly.
    """
    out = jnp.zeros_like(mask)
    for off in offsets:
        out = out | shiftnd(mask, off, fill=False)
    return out


def compact_mask(mask: jnp.ndarray, capacity: int):
    """Pack the flat indices of set pixels into a fixed-capacity queue.

    Returns ``(queue, count, overflow)``:

    * ``queue`` — int32[capacity]; the first ``min(count, capacity)`` slots
      hold the flattened indices of set pixels in raster order, remaining
      slots hold ``-1`` (the dead-slot marker).
    * ``count`` — total number of set pixels (may exceed ``capacity``).
    * ``overflow`` — ``count > capacity``; when true, indices past the
      capacity were not enqueued and the caller must fall back to a dense
      round (:func:`queued_fixed_point` does exactly that).

    ``count == capacity`` packs every index with no overflow — the boundary
    is exact.
    """
    flat = mask.reshape(-1)
    n = flat.shape[0]
    act = flat.astype(jnp.int32)
    # Exclusive prefix sum = the queue slot each active pixel would take.
    pos = jnp.cumsum(act) - act
    count = jnp.sum(act)
    idx = _iota1d(n)
    # Inactive pixels and past-capacity actives target slot `capacity`,
    # which is out of range for the queue and dropped by the scatter.
    slot = jnp.where(flat & (pos < capacity), pos, capacity)
    queue = jnp.full((capacity,), -1, jnp.int32).at[slot].set(idx, mode="drop")
    return queue, count, count > capacity


def compact_flags(indices: jnp.ndarray, flags: jnp.ndarray, capacity: int):
    """:func:`compact_mask` for an explicit (small) index list.

    Packs ``indices[i]`` for every set ``flags[i]`` into a
    ``capacity``-slot queue, preserving order; same return contract as
    :func:`compact_mask`.  Duplicate indices are packed as-is — the queued
    rounds rely on duplicate enqueues being idempotent, and ``count``
    therefore counts contributions, not distinct pixels (a conservative
    overflow trigger).
    """
    act = flags.astype(jnp.int32)
    pos = jnp.cumsum(act) - act
    count = jnp.sum(act)
    slot = jnp.where(flags & (pos < capacity), pos, capacity)
    queue = jnp.full((capacity,), -1, jnp.int32).at[slot].set(
        indices.astype(jnp.int32), mode="drop")
    return queue, count, count > capacity


def fit_seed(indices: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """Statically resize a resident-queue index vector to ``capacity`` slots.

    Seeds use the :func:`compact_mask` layout — live flat indices first,
    ``-1`` dead slots after — so padding appends dead slots and truncation
    only ever drops dead ones *provided the live count fits the capacity*;
    a count above capacity makes the first drain round spill to a dense
    sweep anyway (:func:`queued_fixed_point`), so nothing is lost either
    way.
    """
    idx = indices.astype(jnp.int32).reshape(-1)
    n = idx.shape[0]
    if n >= capacity:
        return idx[:capacity]
    return jnp.concatenate([idx, jnp.full((capacity - n,), -1, jnp.int32)])


def queued_fixed_point(
    dense_round: Callable,
    queued_round: Callable,
    carry,
    *,
    max_iters: int,
    capacity: int,
    initial_queue=None,
):
    """Iterate to a fixed point, pushing from queued pixels per round.

    ``carry`` is the op-specific value state (morph: the J plane; EDT: the
    ``(vr_r, vr_c)`` pointer planes).  The two round callbacks:

    * ``dense_round(carry) -> (carry, improved)`` — one full-block sweep,
      returning the boolean plane of pixels whose value changed;
    * ``queued_round(carry, queue) -> (carry, targets, improved)`` — push
      each queued pixel's value to its neighbors, touching only those;
      returns the per-contribution flat target indices and improvement
      flags (length ``F * capacity``, duplicates allowed).

    The loop runs one unconditional dense round first (every pixel may be
    initially unstable — the same implicit seed as the dense-only kernel's
    first iteration) and compacts its improved plane into the queue.  Each
    later round drains the queue if the previous round's improvement count
    fit ``capacity``, and otherwise *spills* to another dense sweep; either
    way the improved pixels become the next queue.  Stops when a round
    improves nothing or after ``max_iters`` rounds (the initial dense round
    counts as round one).  Returns ``(carry, iters, spills)`` where
    ``spills`` counts overflow rounds after the first.

    Push rounds are bit-identical to dense rounds: a neighbor that did not
    improve last round already offered its candidate the last time it did
    improve, and the monotone strict-improvement compare rejects it now —
    so the accepted updates (and, for EDT, their per-offset order, hence
    tie resolution) coincide exactly, and the loop converges in exactly as
    many rounds as the dense-only kernel (one trailing round observes no
    improvement, same as the dense loop's final ``changed == False``
    iteration).

    ``initial_queue`` — optional resident queue ``(queue, count)`` (the
    :func:`compact_mask` layout: int32[capacity] flat indices, dead slots
    ``-1``).  When given, the seeding dense round is SKIPPED and the drain
    starts directly from the provided frontier — the re-entry path of the
    persistent round state (DESIGN.md §2.6): a caller that already knows
    which pixels changed (a BP halo update, a previous drain's unfinished
    queue) pays O(capacity) instead of O(block) to resume.  The caller
    asserts that every pixel holding a value not yet offered to its
    neighbors is queued; ``count > capacity`` is safe (the first round
    spills to a dense sweep, so an overflowing resident frontier degrades
    to exactly the unseeded behavior), and ``count == 0`` returns
    immediately (the caller asserted a fixed point).
    """
    if initial_queue is not None:
        queue, count = initial_queue
        count = jnp.asarray(count, jnp.int32)
        it0 = jnp.int32(0)           # no seeding round to count
    else:
        carry, imp0 = dense_round(carry)
        queue, count, _ = compact_mask(imp0, capacity)
        it0 = jnp.int32(1)

    def cond(state):
        _, _, count, it, _ = state
        return (count > 0) & (it < max_iters)

    def body(state):
        carry, queue, count, it, spills = state
        overflow = count > capacity

        def spill(c):
            c, imp = dense_round(c)
            return (c,) + compact_mask(imp, capacity)[:2]

        def drain(c):
            c, targets, imp = queued_round(c, queue)
            return (c,) + compact_flags(targets, imp, capacity)[:2]

        carry, queue, count = jax.lax.cond(overflow, spill, drain, carry)
        return carry, queue, count, it + 1, spills + overflow.astype(jnp.int32)

    carry, _, _, iters, spills = jax.lax.while_loop(
        cond, body, (carry, queue, count, it0, jnp.int32(0)))
    return carry, iters, spills
