"""Pallas TPU kernel: directional raster pass for the FH initialization.

The column-direction pass propagates down rows:
    v[r, :] = min(I[r, :], max(J[r, :], v[r-1, :]))
— one W-lane vector op per row with a row-vector carry, the natural TPU
layout (the GPU version launches one thread per column; paper Algorithm 5).
Other directions are realized by flips/transposes in `ops.py`.

The grid is split along columns into (H, Wb) VMEM panels so wide images
stream through VMEM; the row recurrence stays within each panel (columns
are independent for this direction).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(j_ref, i_ref, o_ref):
    H = j_ref.shape[0]

    def body(r, prev):
        row = jnp.maximum(j_ref[pl.ds(r, 1), :], prev)
        row = jnp.minimum(row, i_ref[pl.ds(r, 1), :])
        o_ref[pl.ds(r, 1), :] = row
        return row

    neut = (jnp.iinfo(j_ref.dtype).min if jnp.issubdtype(j_ref.dtype, jnp.integer)
            else -jnp.inf)
    init = jnp.full((1, j_ref.shape[1]), neut, dtype=j_ref.dtype)
    jax.lax.fori_loop(0, H, body, init)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def raster_down(J, I, *, block_w: int = 512, interpret: bool = True):
    """Top-to-bottom FH pass: v[r] = min(I[r], max(J[r], v[r-1]))."""
    H, W = J.shape
    bw = min(block_w, W)
    assert W % bw == 0, (W, bw)
    grid = (W // bw,)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(J.shape, J.dtype),
        in_specs=[pl.BlockSpec((H, bw), lambda c: (0, c)),
                  pl.BlockSpec((H, bw), lambda c: (0, c))],
        out_specs=pl.BlockSpec((H, bw), lambda c: (0, c)),
        grid=grid,
        interpret=interpret,
    )(J, I)
