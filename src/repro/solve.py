"""Unified entry point for every IWPP engine: ``solve(op, state, ...)``.

The paper's central claim (§3-§4) is that the *right* execution strategy for
the irregular wavefront propagation pattern depends on the input: wavefront
density, grid size, and the devices available.  The repo implements the
strategies as separate engines; this module is the seam that picks among
them:

  engine name        implementation                        paper analogue
  ----------------   -----------------------------------   -------------------
  "sweep"            core.frontier.run_dense  (E0)         SR_GPU full sweeps
  "frontier"         core.frontier.run_dense  (E1)         Naive/PF queue
  "tiled"            core.tiles.run_tiled     (E2)         TQ/BQ/GBQ hierarchy
  "tiled-pallas"     run_tiled + kernels.ops tile solver   BQ drain in VMEM
  "shard_map"        core.distributed.run_sharded (E3)     §4 TP/BP multi-GPU
  "shard_map-tiled"  run_sharded w/ per-shard run_tiled    §4 pipeline over
                     TP drains (E3∘E2, DESIGN.md §2.2)     §3.2 queues
  "scheduler"        core.scheduler.TileScheduler          §4 Fig. 8 host FCFS
  "hybrid"           TileScheduler + DeviceWorker pool     §4 cooperative
                     (DESIGN.md §2.3)                      CPU+GPU execution
  "auto"             CostModel ranking (+ autotune)        §4 demand-driven map

``engine="auto"`` ranks candidate ``(engine, tile, queue_capacity)``
configurations with a pluggable :class:`CostModel` — transfer cost plus
per-tile drain cost, in the style of MATCH's ZigZag cost model — fed by
cheap input statistics (seed-pixel density from ``op.init_frontier``, grid
size, device count).  ``autotune=True`` additionally micro-benchmarks the
model's top candidates on the real input and caches the winner keyed by an
input signature, so repeated solves of same-shaped inputs pay nothing.

Every engine returns the same normalized :class:`SolveStats` record so
benchmarks and docs can compare engines uniformly.  See DESIGN.md §4 for
the architecture and README.md for the engine-selection matrix.

Operations plug in through the first-class ``repro.ops`` registry
(DESIGN.md §2.4, docs/OPS.md): an :class:`~repro.ops.OpSpec` declares the
op factory, state builder, result extractor, Pallas tile-solver factories,
the host scheduler's commutative merge, and the cost-model weights.
``solve()`` accepts either a :class:`PropagationOp` instance or a
registered op *name* — ``solve("edt", fg_image)`` builds the op and state
through the spec.  The legacy per-plug-point registrars
(:func:`register_pallas_solver`, :func:`register_scheduler_merge`) remain
as shims over the registry.
"""

from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune_disk, calibrate, compile_cache
from repro.core.distributed import run_sharded
from repro.core.frontier import run_dense
from repro.core.pattern import PropagationOp, restore_invalid, tree_shape
from repro.core.scheduler import ChunkPolicy, DeviceWorker, TileScheduler
from repro.core.tiles import (active_tiles_from_frontier, default_batched_solver,
                              default_tile_solver, initial_active_tiles,
                              run_tiled)
# Importing repro.ops registers the built-in op catalog (morph, edt,
# fill_holes, label) before any dispatch can happen.
from repro.ops import (amend_op_class, get_op, list_ops, on_spec_change,
                       spec_for)

ENGINES = ("sweep", "frontier", "tiled", "tiled-pallas", "shard_map",
           "shard_map-tiled", "scheduler", "hybrid", "auto")

DEFAULT_TILES = (32, 64, 128)
DEFAULT_QUEUE_CAPACITY = 64
# Queue slots drained concurrently per dispatch by the tiled engines (the
# paper's parallel consumption of the global queue; DESIGN.md §2).
DEFAULT_DRAIN_BATCH = 4
# Largest tile that batches by default.  Small blocks are dispatch-bound, so
# draining K=4 of them per dispatch is a measured ~4-5x win on CPU hosts
# (BENCH_tiled.json); large blocks are bandwidth-bound and the batch pays
# max-of-batch iteration inflation plus cache pressure, so they stay
# sequential unless the caller (or autotune) asks otherwise.  Compiled TPU grid kernels shift this
# break-even upward — then pass drain_batch explicitly.
BATCH_DEFAULT_MAX_TILE = 32


def _default_drain_batch(tile: int) -> int:
    return DEFAULT_DRAIN_BATCH if tile <= BATCH_DEFAULT_MAX_TILE else 1


# ---------------------------------------------------------------------------
# Normalized stats — the uniform record every engine reports.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SolveStats:
    """Engine-independent work record (rounds / sources / tiles / overflow).

    ``rounds`` counts the engine's outermost convergence loop: dense rounds
    for E0/E1, outer queue rounds for E2, BP rounds for E3, and FCFS
    passes (always reported as 1) for the host scheduler.
    """

    engine: str
    rounds: int = 0
    sources_processed: int = 0     # frontier pixels acted on (dense engines)
    tiles_processed: int = 0       # tile drains (tiled/scheduler engines)
    overflow_events: int = 0       # rounds where active tiles > queue capacity
    requeues: int = 0              # scheduler fault-tolerance requeues
    tiles_requeued: int = 0        # unconverged (partial) drains re-queued
    tile: Optional[int] = None
    queue_capacity: Optional[int] = None
    drain_batch: Optional[int] = None        # blocks drained per dispatch
    kernel_queue: bool = False               # in-kernel queue (DESIGN.md §2.5)
    kernel_queue_capacity: Optional[int] = None  # resolved local-queue slots
    n_devices: int = 1
    predicted_cost: Optional[float] = None   # CostModel units (auto only)
    autotuned: bool = False
    # True iff the engine gave up before reaching (and verifying) the fixed
    # point — the result is a monotone-valid *partial* state, never to be
    # treated as converged.  Filled by the `hybrid` engine when its BP
    # verification round still finds a residual frontier at max_rounds; the
    # `scheduler` engine raises instead (no BP loop to recover through).
    incomplete: bool = False
    # Compiled-step builds (core.compile_cache misses) that happened during
    # this run.  The persistent-RunState contract (DESIGN.md §2.6) is that
    # this stays *constant in the round count*: a warm re-solve reports 0,
    # and an engine whose recompiles grow with `rounds` is leaking traces.
    recompiles: int = 0
    # Which cost model decided an `auto` run: "analytic" (cold start) or
    # "measured" (a calibration profile was installed; DESIGN.md §2.8).
    # None for explicitly-chosen engines — nothing decided anything.
    cost_model: Optional[str] = None
    # Monotonic-clock wall seconds of the engine run, measured around the
    # engine adapter with the output forced resident (block_until_ready) —
    # the one truthful latency source the serving layer (DESIGN.md §2.9)
    # and the benches report from instead of re-timing around solve().
    wall_time_s: float = 0.0
    # Requests coalesced into the one solve that produced this record
    # (solve_batch's vmapped dense path); None for solo solves.
    batch_size: Optional[int] = None


# ---------------------------------------------------------------------------
# Op plug points — backed by the repro.ops registry (DESIGN.md §2.4).
#
# The three legacy Dict[type, Callable] registries that used to live here
# (_PALLAS_SOLVERS / _PALLAS_BATCH_SOLVERS / _SCHEDULER_MERGES) are gone:
# every per-op plug point is a field of the op's OpSpec.  The two functions
# below are compatibility shims re-exported for callers of the old API.
# ---------------------------------------------------------------------------


def register_pallas_solver(op_cls: type, factory: Callable,
                           batched_factory: Optional[Callable] = None) -> None:
    """Shim over ``repro.ops``: patch ``OpSpec.pallas_solver`` (and
    optionally ``pallas_batch_solver``) for ``op_cls``.

    ``factory(op, interpret, max_iters) -> tile_solver``; ``max_iters`` is
    the engine's per-drain iteration bound ((T+2)² — the longest geodesic
    inside one halo block); solvers must return ``(block, unconverged)``
    with ``unconverged`` True when the drain was cut off at the bound, so
    the engine re-queues instead of silently accepting a partial drain.
    ``batched_factory(op, interpret, max_iters) -> batched_tile_solver``
    (leaves carry a leading (K,) batch dim) backs the batched drain;
    without one, the engine falls back to ``jax.vmap`` of the per-tile
    solver.  New code should ship a full ``OpSpec`` via
    :func:`repro.ops.register_op` instead (docs/OPS.md).
    """
    fields: Dict[str, Callable] = {"pallas_solver": factory}
    if batched_factory is not None:
        fields["pallas_batch_solver"] = batched_factory
    amend_op_class(op_cls, **fields)


def register_scheduler_merge(op_cls: type, factory: Callable) -> None:
    """Shim over ``repro.ops``: patch ``OpSpec.scheduler_merge`` for
    ``op_cls`` (``factory(op) -> merge_block_fn``; returning None selects
    the scheduler's built-in elementwise-max merge)."""
    amend_op_class(op_cls, scheduler_merge=factory)


# ---------------------------------------------------------------------------
# Input statistics — the cheap probes that feed the cost model.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputStats:
    """What the cost model knows about one input (all O(N) probes).

    ``bytes_per_pixel`` / ``round_cost_weight`` are the *op's* cost hints,
    copied from its :class:`~repro.ops.OpSpec` by
    :func:`collect_input_stats` (defaults = the morph reference op).  They
    let one CostModel price every registered op without per-op branches.
    """

    height: int
    width: int
    n_sources: int                      # initial frontier population
    active_tiles: Dict[int, int]        # tile size -> initially-active tiles
    n_devices: int
    bytes_per_pixel: float = 4.0        # mutable HBM payload per pixel
    round_cost_weight: float = 1.0      # per-round compute vs morph's max
    shape: Tuple[int, ...] = ()         # full spatial shape (() = 2-D compat)
    n_offsets: int = 8                  # neighborhood size (offsets/pixel)
    op_name: str = ""                   # registry name ("" = unregistered op)

    @property
    def spatial(self) -> Tuple[int, ...]:
        return self.shape if self.shape else (self.height, self.width)

    @property
    def ndim(self) -> int:
        return len(self.spatial)

    @property
    def area(self) -> int:
        return math.prod(self.spatial)

    @property
    def density(self) -> float:
        return self.n_sources / max(self.area, 1)

    @property
    def depth_est(self) -> float:
        """Expected propagation depth (rounds to the fixed point).

        Mean inter-source spacing: sparse seeds must sweep waves across
        O((area / n_sources)^(1/ndim)) pixels; a near-full frontier
        converges in O(1) rounds.  This single number is what separates the
        dense and tiled regimes (paper Table 1 / Fig. 12).
        """
        return max(1.0, (self.area / max(self.n_sources, 1))
                   ** (1.0 / self.ndim))

    def n_tiles(self, tile: int) -> int:
        return math.prod(-(-s // tile) for s in self.spatial)


def collect_input_stats(op: PropagationOp, state, n_devices: int = 1,
                        tiles: Sequence[int] = DEFAULT_TILES) -> InputStats:
    spatial = tree_shape(state, op.ndim)
    H, W = spatial[-2:]
    f0 = op.init_frontier(state)
    n_sources = int(jnp.sum(f0))
    active = {t: int(jnp.sum(initial_active_tiles(op, state, t)))
              for t in tiles}
    spec = spec_for(op)
    return InputStats(H, W, n_sources, active, n_devices,
                      bytes_per_pixel=spec.bytes_per_pixel if spec else 4.0,
                      round_cost_weight=spec.round_cost_weight if spec else 1.0,
                      shape=spatial, n_offsets=len(op.offsets),
                      op_name=spec.name if spec else "")


# ---------------------------------------------------------------------------
# Cost model — MATCH-style: transfer cost + innermost (drain) cost.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    engine: str
    tile: Optional[int] = None
    queue_capacity: Optional[int] = None
    drain_batch: Optional[int] = None   # queue slots drained per dispatch
    # tiled-pallas only: drain each block through the in-kernel multi-level
    # queue (DESIGN.md §2.5) instead of dense full-block sweeps.
    kernel_queue: bool = False
    kernel_queue_capacity: Optional[int] = None  # None = kernel-side default


class CostModel:
    """Relative-cost model for engine selection (unit: one HBM pixel touch).

    Follows the MATCH/ZigZag split: ``transfer_cost`` charges the data an
    engine moves through the slow memory level, ``drain_cost`` charges the
    compute of the innermost propagation loops.  Subclass and override the
    two methods (and/or the constants) to retarget the model — e.g. measured
    HBM/VMEM bandwidths of a specific TPU generation.

    The qualitative shape mirrors the paper's findings: dense engines pay
    the full grid every round, so they win when the wavefront covers the
    grid and converges in few rounds; the tiled hierarchy pays only active
    tiles plus a per-drain dispatch overhead, so it wins as the wavefront
    sparsifies (paper Fig. 12: speedups grow with wave sparsity).
    """

    # Which model decided, reported through SolveStats.cost_model (the
    # MeasuredCostModel subclass overrides this with "measured").
    kind = "analytic"

    # Relative VMEM:HBM bandwidth — inner drain iterations stay on-chip, so
    # a tile's local rounds are discounted by this factor (the paper's BQ
    # amortization argument).
    vmem_discount = 1.0 / 16.0
    # Fixed cost of dispatching one tile drain (lax.scan step / host call).
    # A batched drain issues one dispatch per `drain_batch` blocks, so the
    # effective per-tile term is tile_dispatch / drain_batch (the paper's
    # point that queue consumption must be parallel across SMs to pay off).
    tile_dispatch = 500.0
    # E0 recomputes every valid pixel with no tracking: constant-factor
    # penalty over E1 plus the extra settle rounds.
    sweep_penalty = 1.25
    # Per-BP-round collective latency on a mesh, per device.
    collective_latency = 5_000.0
    # Host (numpy/threading) path: slower per-pixel than the XLA path, plus
    # Python dispatch per drain.
    host_penalty = 20.0
    host_dispatch = 20_000.0
    # Pallas interpret mode executes the kernel body in Python — only ever
    # competitive when compiled for a real TPU.
    interpret_penalty = 50.0
    # Queued-kernel push rounds (kernel_queue=True, DESIGN.md §2.5) touch
    # only O(queue capacity) pixels, but their gather/scatter/compaction
    # steps do not fuse the way a dense round's shifted-plane passes do, so
    # each round pays a fixed multi-dispatch overhead (in dense pixel-visit
    # units; calibrated against the measured ~8x round-time gap on a 256²
    # block).  Each drain also pays one dense seeding round up front.
    kernel_queue_round_overhead = 6_000.0
    # Host threads assumed alongside the device stream in the `hybrid`
    # cooperative pool (solve()'s n_workers default).
    hybrid_host_workers = 4
    # Fixed cost an engine pays per outer round regardless of work done:
    # dispatching the round's (already-compiled) step, host-side carry
    # bookkeeping.  The persistent RunState machinery (DESIGN.md §2.6)
    # exists precisely to keep this term *per-round-constant* instead of
    # hiding a retrace in it.
    round_overhead = 200.0
    # One XLA trace+compile, in pixel-visit units.  Deliberately enormous:
    # an engine whose `SolveStats.recompiles` grows with the round count
    # (a leaked trace — what the composed engines did before ISSUE 7)
    # should price itself out of the auto ranking once `calibrate` has
    # observed it.
    recompile_cost = 2_000_000.0

    def __init__(self, interpret: bool = True):
        self.interpret = interpret
        # engine name -> EWMA of observed recompiles per outer round,
        # fed by `calibrate`.  Empty = trust the engines' no-leak contract.
        self._recompile_rate: Dict[str, float] = {}

    # -- helpers -----------------------------------------------------------
    @staticmethod
    def _lead(stats: InputStats) -> int:
        """Product of the leading (non-mesh-sharded) spatial extents — the
        per-ring-cell depth multiplier of an N-D shard's halo traffic."""
        return max(1, stats.area // max(1, stats.height * stats.width))

    def depth(self, stats: InputStats) -> float:
        """Expected propagation depth (outer rounds to the fixed point).

        The analytic model uses the inter-source-spacing guess
        ``stats.depth_est``; the measured subclass replaces this with the
        rounds-per-extent profile — the single hook through which every
        rounds-dependent term below (dense transfer, drain counts, BP
        rounds) switches from guessed to measured.
        """
        return stats.depth_est

    def _drains(self, stats: InputStats, tile: int) -> float:
        """Expected tile drains: initially-active tiles, re-drained once per
        tile-layer the wavefront crosses."""
        active0 = max(1, stats.active_tiles.get(tile, stats.n_tiles(tile)))
        return active0 * max(1.0, self.depth(stats) / tile)

    # -- the two MATCH-style plug points -----------------------------------
    def transfer_cost(self, stats: InputStats, cfg: EngineConfig) -> float:
        """Slow-memory traffic (pixels moved between rounds)."""
        e = cfg.engine
        if e == "frontier":
            return self.depth(stats) * stats.area
        if e == "sweep":
            return (self.depth(stats) + 2) * stats.area * self.sweep_penalty
        if e in ("tiled", "tiled-pallas", "scheduler", "hybrid"):
            block = (cfg.tile + 2) ** stats.ndim
            return self._drains(stats, cfg.tile) * block
        if e == "shard_map":
            bp_rounds = self._bp_rounds(stats)
            halo = 2 * (stats.height + stats.width) * self._lead(stats)
            return (self.depth(stats) * stats.area / stats.n_devices
                    + bp_rounds * halo)
        if e == "shard_map-tiled":
            # Composed hierarchy: transfer = the BP halo rings (same
            # collective traffic as the flat shard_map) + only the *active*
            # tile blocks each TP stage touches, split across devices —
            # never the whole shard per round (the flat engine's
            # depth*area/n term).
            bp_rounds = self._bp_rounds(stats)
            halo = 2 * (stats.height + stats.width) * self._lead(stats)
            block = (cfg.tile + 2) ** stats.ndim
            drains = self._drains(stats, cfg.tile) / stats.n_devices
            return drains * block + bp_rounds * halo
        raise ValueError(f"unknown engine {e!r}")

    def drain_cost(self, stats: InputStats, cfg: EngineConfig) -> float:
        """Innermost-loop compute (discounted when resident on-chip)."""
        e = cfg.engine
        if e in ("frontier", "sweep"):
            return 0.0  # dense engines are bandwidth-bound; folded above
        if e in ("tiled", "tiled-pallas"):
            block = (cfg.tile + 2) ** stats.ndim
            inner = block * cfg.tile * self.vmem_discount
            if e == "tiled-pallas" and cfg.kernel_queue:
                from repro.kernels.ops import default_kernel_queue_capacity
                qcap = (cfg.kernel_queue_capacity
                        or default_kernel_queue_capacity(
                            (cfg.tile + 2,) * stats.ndim))
                # One dense seeding round + ~tile push rounds of fixed
                # dispatch overhead plus (n_offsets + 1) contribution lanes
                # per slot: queued only wins on big blocks with sparse
                # wavefronts.
                inner = ((block + (self.kernel_queue_round_overhead
                                   + (stats.n_offsets + 1) * qcap) * cfg.tile)
                         * self.vmem_discount)
            if e == "tiled-pallas" and self.interpret:
                inner *= self.interpret_penalty
            drains = self._drains(stats, cfg.tile)
            dispatch = self.tile_dispatch / max(1, cfg.drain_batch or 1)
            return drains * inner + drains * dispatch
        if e == "scheduler":
            block = (cfg.tile + 2) ** stats.ndim
            drains = self._drains(stats, cfg.tile)
            return (drains * block * cfg.tile * self.vmem_discount
                    * self.host_penalty + drains * self.host_dispatch)
        if e == "hybrid":
            # Cooperative pool: host threads and the batched device stream
            # consume one queue, so throughputs *add* (harmonic combination
            # of the per-drain unit costs) — the paper's §4 claim that the
            # hybrid split beats either processor alone.  Plus a
            # conservative O(area) charge for the pass's host-side overhead
            # (padded-state copies, and the BP recovery probe when a pass
            # loses its workers).
            host_unit, dev_unit = self._hybrid_units(cfg.tile,
                                                     cfg.drain_batch or 1)
            drains = self._drains(stats, cfg.tile)
            rate = self.hybrid_host_workers / host_unit + 1.0 / dev_unit
            return drains / rate + stats.area
        if e == "shard_map":
            return self._bp_rounds(stats) * self.collective_latency * stats.n_devices
        if e == "shard_map-tiled":
            # Per-shard amortized tile dispatch (the E2 drain cost at 1/n
            # devices worth of drains each) + the same per-BP-round
            # collective latency as the flat shard_map.
            block = (cfg.tile + 2) ** stats.ndim
            inner = block * cfg.tile * self.vmem_discount
            drains = self._drains(stats, cfg.tile) / stats.n_devices
            dispatch = self.tile_dispatch / max(1, cfg.drain_batch or 1)
            return (drains * (inner + dispatch)
                    + self._bp_rounds(stats) * self.collective_latency
                    * stats.n_devices)
        raise ValueError(f"unknown engine {e!r}")

    def _hybrid_units(self, tile: int, drain_batch: int) -> Tuple[float, float]:
        """Per-drain unit costs of the hybrid pool's two worker classes."""
        block = (tile + 2) ** 2
        inner = block * tile * self.vmem_discount
        host_unit = inner * self.host_penalty + self.host_dispatch
        dev_unit = inner + self.tile_dispatch / max(1, drain_batch)
        return host_unit, dev_unit

    def hybrid_rel_speed(self, tile: int, drain_batch: int = 1) -> float:
        """Analytic seed for the hybrid engine's :class:`ChunkPolicy`: how
        many tiles the device stream should claim per host-thread tile.

        Both worker classes run the same jitted drain, so the only *a
        priori* device advantage is dispatch amortization — one host-side
        dispatch per ``drain_batch`` blocks instead of per block.  (A real
        accelerator's compute advantage is discovered by the online EWMA,
        not assumed: a wrong seed only costs the first few claims.)"""
        inner = (tile + 2) ** 2 * tile * self.vmem_discount
        return ((inner + self.host_dispatch)
                / (inner + self.host_dispatch / max(1, drain_batch)))

    def _bp_rounds(self, stats: InputStats) -> float:
        side = max(1.0, math.sqrt(stats.n_devices))
        block_side = min(stats.height, stats.width) / side
        return max(1.0, self.depth(stats) / max(block_side, 1.0))

    # -- per-round fixed overhead (calibrated from SolveStats.recompiles) --
    def rounds_est(self, stats: InputStats, cfg: EngineConfig) -> float:
        """Expected outer rounds — the multiplier of the fixed overhead."""
        e = cfg.engine
        if e in ("sweep", "frontier"):
            return self.depth(stats)
        if e in ("tiled", "tiled-pallas"):
            # Outer queue rounds ~ wavefront layers measured in tiles.
            return max(1.0, self.depth(stats) / max(cfg.tile or 1, 1))
        if e in ("scheduler", "hybrid"):
            return 1.0  # one FCFS pass (hybrid BP recovery is the rare path)
        return self._bp_rounds(stats)

    def round_overhead_cost(self, stats: InputStats,
                            cfg: EngineConfig) -> float:
        """Fixed per-round charge + any *observed* per-round retrace leak."""
        per_round = (self.round_overhead
                     + self._recompile_rate.get(cfg.engine, 0.0)
                     * self.recompile_cost)
        return self.rounds_est(stats, cfg) * per_round

    def calibrate(self, solve_stats: "SolveStats") -> None:
        """Feed one measured run back into the per-round overhead term.

        ``recompiles / rounds`` from a *warm* steady state is the engine's
        trace-leak rate (a healthy engine reports 0).  An EWMA over runs
        lets the first, legitimately-cold solve (one-time compiles) wash
        out instead of permanently branding the engine.  ``solve()`` calls
        this automatically on every ``engine="auto"`` run.
        """
        rounds = max(1, solve_stats.rounds)
        rate = solve_stats.recompiles / rounds
        old = self._recompile_rate.get(solve_stats.engine)
        self._recompile_rate[solve_stats.engine] = (
            rate if old is None else 0.5 * old + 0.5 * rate)

    # Reference op payload: morph's single int32 mutable plane.  OpSpec cost
    # hints are scaled against this so the morph numbers match the model's
    # historical calibration exactly.
    ref_bytes_per_pixel = 4.0

    # -- ranking -----------------------------------------------------------
    def cost(self, stats: InputStats, cfg: EngineConfig) -> float:
        """Total = op-weighted transfer + drain (OpSpec hints via InputStats):
        transfer scales with the op's mutable bytes/pixel, drain with its
        per-round arithmetic weight."""
        scale_t = stats.bytes_per_pixel / self.ref_bytes_per_pixel
        return (scale_t * self.transfer_cost(stats, cfg)
                + stats.round_cost_weight * self.drain_cost(stats, cfg)
                + self.round_overhead_cost(stats, cfg))

    def candidates(self, stats: InputStats,
                   tiles: Sequence[int] = DEFAULT_TILES) -> List[EngineConfig]:
        out = [EngineConfig("frontier"), EngineConfig("sweep")]
        usable = [t for t in tiles if t <= 2 * max(stats.height, stats.width)]
        for t in usable or [min(tiles)]:
            cap = min(max(4, stats.n_tiles(t)), 256)
            db = min(cap, _default_drain_batch(t))
            out.append(EngineConfig("tiled", t, cap, db))
            out.append(EngineConfig("tiled-pallas", t, cap, db))
            out.append(EngineConfig("tiled-pallas", t, cap, db,
                                    kernel_queue=True))
            out.append(EngineConfig("scheduler", t, cap))
            out.append(EngineConfig("hybrid", t, cap, db))
            if stats.n_devices > 1:
                out.append(EngineConfig("shard_map-tiled", t, cap, db))
        if stats.n_devices > 1:
            out.append(EngineConfig("shard_map"))
        return out

    def rank(self, stats: InputStats,
             candidates: Optional[Sequence[EngineConfig]] = None
             ) -> List[Tuple[float, EngineConfig]]:
        cands = candidates if candidates is not None else self.candidates(stats)
        scored = [(self.cost(stats, c), c) for c in cands]
        scored.sort(key=lambda sc: sc[0])
        return scored


class MeasuredCostModel(CostModel):
    """Cost model over a measured :class:`~repro.core.calibrate.
    CalibrationProfile` (DESIGN.md §2.8); unit = wall seconds.

    Same MATCH-style structure as the analytic parent, but every ingredient
    the profile measured replaces its guessed counterpart:

    * ``depth`` — the measured rounds-per-extent curve over seed density
      replaces the inter-source-spacing guess (``InputStats.depth_est``);
      since every rounds-dependent term routes through :meth:`CostModel.
      depth`, the fix propagates to dense transfer, drain counts and BP
      rounds at once.
    * dense engines — measured seconds per round, interpolated over area
      (so the HBM bandwidth knee is in the curve, not a constant).
    * tiled families — measured wall seconds per drain over block pixels,
      scaled by the measured density factor (shallow drains near
      convergence), the measured batched-drain amortization curve, and the
      op's neighborhood-size ratio.  Scheduler/hybrid profiles are wall
      seconds per tile *at the calibration worker counts* (recorded in
      ``profile.meta``).

    Anything the profile did not measure — an unprofiled op, a Pallas
    family measured under a different ``interpret`` mode, the shard_map
    engines — falls back to the *op's cost hints over the morph reference
    curves*, and past that to the analytic formula bridged into seconds,
    so every candidate stays comparable in one ranking.  Construct via
    :func:`default_cost_model`, which picks this subclass exactly when a
    profile is installed.
    """

    kind = "measured"

    def __init__(self, profile, interpret: bool = True):
        super().__init__(interpret)
        self.profile = profile

    # -- profile lookups with the op -> morph -> analytic fallback chain ---
    def _op_key(self, stats: InputStats, table: Dict[str, Any],
                need: Optional[str] = None) -> Optional[str]:
        """The table key to price ``stats``'s op from: the op's own entry
        when present (and carrying ``need``), else the morph reference."""
        for key in (stats.op_name, "morph"):
            entry = table.get(key)
            if entry is None:
                continue
            if need is not None and need not in entry:
                continue
            return key
        return None

    def _hint_scale(self, stats: InputStats, key: str, weight: float) -> float:
        """Scaling applied when pricing an op off another op's curves: the
        OpSpec cost hints (bytes for transfer-bound terms, round weight for
        compute-bound terms) — 1.0 when the op owns the curve."""
        if key == stats.op_name:
            return 1.0
        return weight

    def _offs_ratio(self, stats: InputStats, key: str) -> float:
        """Neighborhood-size correction: per-round and per-drain work is
        linear in the offsets applied per pixel (conn26 rounds cost ~3x a
        conn8 round of the same area)."""
        ref = self.profile.ref_n_offsets.get(key)
        return stats.n_offsets / ref if ref else 1.0

    # -- measured ingredients ----------------------------------------------
    def depth(self, stats: InputStats) -> float:
        rc = self.profile.rounds_per_extent.get(stats.op_name)
        if rc is None:
            return stats.depth_est
        ld = math.log10(max(stats.density, 1e-9))
        return max(1.0, rc.interp(ld) * max(stats.spatial))

    def _density_factor(self, stats: InputStats) -> float:
        # Only the op's *own* measured curve: regime-vs-drain-depth
        # dynamics don't transfer across ops the way per-pixel rates do.
        df = self.profile.drain_density_factor.get(stats.op_name)
        if df is None:
            return 1.0
        ld = math.log10(max(stats.density, 1e-9))
        return max(df.interp(ld), 1e-3)

    def _family(self, cfg: EngineConfig) -> str:
        if cfg.engine == "tiled-pallas" and cfg.kernel_queue:
            return "tiled-pallas-queued"
        return cfg.engine

    def _nearest_block(self, curves: Dict[str, Any], block: float) -> str:
        """Key of the measured block size closest (log-distance) to
        ``block`` — 3-D blocks land on the largest measured 2-D one."""
        return min(curves, key=lambda k: abs(math.log(float(k) / block)))

    def _grid_factor(self, stats: InputStats, block: float) -> float:
        """Growth of per-drain cost with the *full grid* (queue compaction
        and block scatter touch every tile each round): the measured
        drain-grid curve at the nearest block size, normalized to its
        calibration-grid anchor (its first point)."""
        curves = self.profile.drain_grid
        if not curves:
            return 1.0
        c = curves[self._nearest_block(curves, block)]
        return max(c.interp(float(stats.area)) / c.ys[0], 1e-3)

    def _batch_factor(self, block: float, drain_batch: float) -> float:
        curves = self.profile.batch_factor
        if not curves:
            return 1.0
        c = curves[self._nearest_block(curves, block)]
        return max(c.interp(drain_batch), 1e-3)

    def _drain_seconds(self, stats: InputStats,
                       cfg: EngineConfig) -> Optional[float]:
        """Measured wall seconds for one drain of ``cfg``'s family at
        ``cfg.tile``, fully corrected — None when unprofiled."""
        fam = self._family(cfg)
        if fam.startswith("tiled-pallas") and \
                self.profile.meta.get("interpret") != self.interpret:
            return None     # interpret-mode timings don't transfer
        key = self._op_key(stats, self.profile.drain, need=fam)
        if key is None:
            return None
        block = float((cfg.tile + 2) ** stats.ndim)
        sec = self.profile.drain[key][fam].scaled(block)
        sec *= self._hint_scale(stats, key, stats.round_cost_weight)
        sec *= self._offs_ratio(stats, key)
        sec *= self._density_factor(stats)
        if fam in ("tiled", "tiled-pallas", "tiled-pallas-queued"):
            # scheduler/hybrid wall-per-tile rates already include their
            # host-side overheads and transfer across grid sizes; the
            # block-drain families need the measured grid and batch
            # corrections (both measured with the tiled outer loop, which
            # the Pallas families share).
            sec *= self._grid_factor(stats, block)
            sec *= self._batch_factor(block, float(cfg.drain_batch or 1))
        return sec

    def _unit_seconds(self, stats: InputStats) -> float:
        """Seconds per analytic pixel-visit unit — the bridge that keeps
        analytically-priced candidates comparable with measured ones.
        Preferred source: the measured HBM byte rate at this input's
        working-set size; else the measured dispatch overhead against the
        analytic per-round charge; else a nominal DRAM-era constant."""
        if self.profile.transfer is not None:
            nbytes = max(1.0, stats.area * stats.bytes_per_pixel)
            return (self.profile.transfer.scaled(nbytes) / nbytes
                    * self.ref_bytes_per_pixel)
        if self.profile.round_overhead_s > 0:
            return self.profile.round_overhead_s / CostModel.round_overhead
        return 1e-9

    def _bridge(self, stats: InputStats, cfg: EngineConfig) -> float:
        return self._unit_seconds(stats) * super().cost(stats, cfg)

    # -- the overridden MATCH plug points (now in seconds) -----------------
    def round_overhead_cost(self, stats: InputStats,
                            cfg: EngineConfig) -> float:
        per_round = (self.profile.round_overhead_s
                     + self._recompile_rate.get(cfg.engine, 0.0)
                     * self.profile.recompile_s)
        return self.rounds_est(stats, cfg) * per_round

    def hybrid_rel_speed(self, tile: int, drain_batch: int = 1) -> float:
        if self.profile.hybrid_rel_speed:
            return self.profile.hybrid_rel_speed
        return super().hybrid_rel_speed(tile, drain_batch)

    def cost(self, stats: InputStats, cfg: EngineConfig) -> float:
        e = cfg.engine
        if e in ("frontier", "sweep"):
            key = self._op_key(stats, self.profile.dense_round, need=e)
            if key is None:
                return self._bridge(stats, cfg)
            sec_per_round = (
                self.profile.dense_round[key][e].scaled(float(stats.area))
                * self._hint_scale(stats, key,
                                   stats.bytes_per_pixel
                                   / self.ref_bytes_per_pixel)
                * self._offs_ratio(stats, key))
            # sweep pays the extra settle rounds past the fixed point (the
            # analytic model's +2) on top of the measured per-round rate
            rounds = self.depth(stats) + (2.0 if e == "sweep" else 0.0)
            return (rounds * sec_per_round
                    + self.round_overhead_cost(stats, cfg))
        if e in ("tiled", "tiled-pallas", "scheduler", "hybrid"):
            sec = self._drain_seconds(stats, cfg)
            if sec is None:
                return self._bridge(stats, cfg)
            return (self._drains(stats, cfg.tile) * sec
                    + self.round_overhead_cost(stats, cfg))
        # shard_map engines: no measured profile (needs a mesh to time);
        # analytic shape, measured depth, bridged into seconds.
        return self._bridge(stats, cfg)


def default_cost_model(interpret: bool = True) -> CostModel:
    """The model ``engine="auto"`` uses when the caller passed none: the
    :class:`MeasuredCostModel` over the installed calibration profile when
    one exists for this (device kind, code version), else the analytic
    :class:`CostModel` — the cold-start path (DESIGN.md §2.8)."""
    from repro.core import calibrate
    profile = calibrate.current_profile()
    if profile is not None:
        return MeasuredCostModel(profile, interpret=interpret)
    return CostModel(interpret=interpret)


# ---------------------------------------------------------------------------
# Autotune — micro-benchmark the model's top candidates, cache winners.
# ---------------------------------------------------------------------------

# signature -> (EngineConfig, measured seconds).  Backed by the disk layer
# (core.autotune_disk, ~/.cache/repro-iwpp/autotune.json): a process-local
# miss falls through to disk before re-measuring, and measured winners are
# persisted so a fresh interpreter skips the whole micro-benchmark sweep.
_AUTOTUNE_CACHE: Dict[tuple, Tuple[EngineConfig, float]] = {}
# signature -> tuple of (EngineConfig, repr(exception)) for candidates that
# raised during micro-benchmarking — kept so a fully-failing candidate set is
# distinguishable from a fast one (and surfaced via warnings.warn).
_AUTOTUNE_FAILURES: Dict[tuple, tuple] = {}


def autotune_signature(op: PropagationOp, stats: InputStats,
                       restrictions: tuple = ()) -> tuple:
    """Cache key: op identity + shape + density bucket + device count, plus
    any caller restrictions on the candidate set (tile / queue_capacity) so
    a restricted solve never reuses an unrestricted winner.

    The density bucket (decade of the seed-pixel density) is what the cost
    regimes actually depend on; exact pixel values don't matter.
    """
    bucket = (-99 if stats.n_sources == 0
              else int(math.floor(math.log10(max(stats.density, 1e-9)))))
    return (type(op).__name__, op.neighborhood.name, stats.spatial,
            bucket, stats.n_devices) + tuple(restrictions)


def clear_autotune_cache(disk: bool = False) -> None:
    """Drop the in-process autotune winners; ``disk=True`` also deletes the
    persisted ``autotune.json`` (e.g. before a clean benchmark run)."""
    _AUTOTUNE_CACHE.clear()
    _AUTOTUNE_FAILURES.clear()
    if disk:
        autotune_disk.clear()


def _autotune(op, state, stats, model: CostModel, candidates, restrictions,
              top_k: int, repeats: int, **run_kw) -> EngineConfig:
    sig = autotune_signature(op, stats, restrictions)
    if sig in _AUTOTUNE_CACHE:
        return _AUTOTUNE_CACHE[sig][0]
    hit = autotune_disk.load(type(op).__name__, sig, EngineConfig)
    if hit is not None and hit[0] in candidates:
        # A persisted winner from an earlier process on the same device
        # kind + code version: trust it without re-measuring (promote to
        # the in-process cache so the disk is read at most once per sig).
        # Only honored when the persisted config is still in the caller's
        # candidate set — a restricted/custom candidate list must not be
        # bypassed by a winner measured over a different set.
        _AUTOTUNE_CACHE[sig] = hit
        return hit[0]
    ranked = model.rank(stats, candidates)
    best_cfg, best_t = None, float("inf")
    failures = []
    for _, cfg in ranked[:top_k]:
        try:
            runner = lambda: _run_engine(op, state, cfg, **run_kw)
            jax.block_until_ready(runner()[0])       # warm/compile
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(runner()[0])
                ts.append(time.perf_counter() - t0)
            t = min(ts)
        except Exception as e:
            warnings.warn(f"autotune: candidate {cfg} failed with {e!r}; "
                          "excluding it from the measured ranking",
                          RuntimeWarning, stacklevel=2)
            failures.append((cfg, repr(e)))
            continue
        if t < best_t:
            best_cfg, best_t = cfg, t
    if best_cfg is None:                              # all candidates failed
        warnings.warn(
            f"autotune: all {len(ranked[:top_k])} measured candidates failed; "
            "falling back to the cost model's top prediction "
            f"{ranked[0][1]} (unmeasured)", RuntimeWarning, stacklevel=2)
        best_cfg, best_t = ranked[0][1], float("nan")
    _AUTOTUNE_CACHE[sig] = (best_cfg, best_t)
    if failures:
        _AUTOTUNE_FAILURES[sig] = tuple(failures)
    if best_t == best_t:                     # measured (not the NaN fallback)
        autotune_disk.store(type(op).__name__, sig, best_cfg, best_t)
    return best_cfg


# ---------------------------------------------------------------------------
# Engine adapters.
# ---------------------------------------------------------------------------

def pad_state_to(op, state, target: Sequence[int]):
    """High-side-pad every leaf's trailing spatial axes to exactly
    ``target`` with the op's neutral values.

    Padded cells are invalid and hold ``op.pad_value`` fills, so they can
    never source a propagation; cropping afterwards restores the domain.
    Shared by the engines' grid-multiple padding and the serving layer's
    pad-to-bucket coalescing (DESIGN.md §2.9).  Returns ``(padded,
    orig_spatial)``; shrinking is an error.
    """
    nd = op.ndim
    spatial = tree_shape(state, nd)
    target = tuple(target)
    if any(t < s for s, t in zip(spatial, target)):
        raise ValueError(f"pad_state_to cannot shrink {spatial} to {target}")
    if target == spatial:
        return state, spatial
    pv = op.pad_value(state)
    grow = [t - s for s, t in zip(spatial, target)]
    padded = jax.tree_util.tree_map(
        lambda x, v: jnp.pad(
            x, [(0, 0)] * (x.ndim - nd) + [(0, g) for g in grow],
            constant_values=v),
        state, pv)
    return padded, spatial


def _pad_to_multiple(op, state, mults: Sequence[int]):
    """High-side-pad the trailing ``len(mults)`` spatial axes of every leaf
    to grid multiples with neutral values (see :func:`pad_state_to`)."""
    nd = op.ndim
    spatial = tree_shape(state, nd)
    mults = (1,) * (nd - len(mults)) + tuple(mults)
    return pad_state_to(op, state,
                        tuple(-(-s // m) * m for s, m in zip(spatial, mults)))


def _crop(state, spatial: Sequence[int]):
    idx = (Ellipsis,) + tuple(slice(0, s) for s in spatial)
    return jax.tree_util.tree_map(lambda x: x[idx], state)


def _mesh_shape(n: int) -> Tuple[int, int]:
    """Most-square factorization of the device count."""
    r = int(math.sqrt(n))
    while n % r:
        r -= 1
    return r, n // r


def _run_dense_engine(op, state, cfg, max_rounds, **_):
    out, st = run_dense(op, state, cfg.engine, max_rounds)
    return out, SolveStats(cfg.engine, rounds=int(st.rounds),
                           sources_processed=int(st.sources_processed))


# Every per-op compiled artifact in this module lives in the one process
# cache (core.compile_cache): keys carry a site tag first and the op class
# second, so ``SolveStats.recompiles`` counts builds uniformly across the
# layers and the spec-change hook below drops every affected entry at once.
# Re-registering/amending a spec invalidates the op's entries, so a replaced
# Pallas solver is picked up instead of a stale memo serving the old kernel.


def _invalidate_solver_memo(op_cls: type) -> None:
    # A subclass may resolve its solver through the amended ancestor's
    # spec, so drop every cache row whose op class sits below op_cls too —
    # collecting the affected class names on the way out for the autotune
    # invalidation below.
    names = {op_cls.__name__}

    def pred(key: tuple) -> bool:
        if len(key) < 2:
            return False
        tagged = key[1]
        cls = tagged if isinstance(tagged, type) else type(tagged)
        if isinstance(cls, type) and issubclass(cls, op_cls):
            names.add(cls.__name__)
            return True
        return False

    compile_cache.invalidate(pred)
    # A spec change can also *fix* a candidate that failed during autotune
    # micro-benchmarking (e.g. a broken queued-kernel factory): entries
    # recorded under the old spec would keep serving the stale winner — and
    # the stale failure verdict — forever, so the fixed candidate would
    # never be retried.  Autotune signatures carry the op class *name* at
    # position 0 (autotune_signature), which is the best subclass net we
    # have here.
    for cache in (_AUTOTUNE_CACHE, _AUTOTUNE_FAILURES):
        for sig in [s for s in cache if s and s[0] in names]:
            del cache[sig]
    # ... and the persisted winners, across ALL code versions: the disk
    # entry records the op name, so a stale winner written by an older
    # build can't outlive the spec that produced it either.
    autotune_disk.invalidate_op(names)


on_spec_change(_invalidate_solver_memo)


def _pallas_solver_for(op, interpret: bool, batched: bool = False,
                       max_iters: int = None, engine: str = "tiled-pallas",
                       kernel_queue: bool = False,
                       kernel_queue_capacity: Optional[int] = None):
    from repro.kernels.ops import DEFAULT_MAX_ITERS
    if max_iters is None:
        max_iters = DEFAULT_MAX_ITERS
    key = ("pallas-solver", type(op), op.connectivity, interpret, batched,
           max_iters, kernel_queue, kernel_queue_capacity)

    def build():
        spec = spec_for(op)
        if kernel_queue:
            factory = (None if spec is None else
                       (spec.pallas_queue_batch_solver if batched
                        else spec.pallas_queue_solver))
            per_tile = None if spec is None else spec.pallas_queue_solver
        else:
            factory = (None if spec is None else
                       (spec.pallas_batch_solver if batched
                        else spec.pallas_solver))
            per_tile = None if spec is None else spec.pallas_solver
        if factory is None:
            if batched and per_tile is not None:
                # Fall back to vmapping the per-tile kernel; a dedicated
                # grid-over-batch kernel is only an optimization.  (The
                # cache lock is re-entrant, so the recursive lookup is
                # safe.)
                return jax.vmap(
                    _pallas_solver_for(op, interpret, max_iters=max_iters,
                                       engine=engine,
                                       kernel_queue=kernel_queue,
                                       kernel_queue_capacity=kernel_queue_capacity))
            what = ("queued Pallas tile solver (OpSpec.pallas_queue_solver, "
                    "required by kernel_queue=True)" if kernel_queue
                    else "Pallas tile solver")
            raise ValueError(
                f"op {type(op).__name__} has no {what} "
                f"registered, which engine {engine!r} requires; registered "
                f"ops: {list_ops()}.  Provide OpSpec.pallas_solver via "
                "repro.ops.register_op() (or the register_pallas_solver "
                "shim), or pick an op-generic engine such as 'tiled'.")
        return (factory(op, interpret, max_iters, kernel_queue_capacity)
                if kernel_queue
                else factory(op, interpret, max_iters))

    return compile_cache.get(key, build)


def _tiled_cfg_defaults(cfg: EngineConfig) -> Tuple[int, int, int]:
    """Resolve (tile, queue_capacity, drain_batch) for the queued engines."""
    tile = cfg.tile or DEFAULT_TILES[1]
    cap = cfg.queue_capacity or DEFAULT_QUEUE_CAPACITY
    drain_batch = (cfg.drain_batch if cfg.drain_batch is not None
                   else _default_drain_batch(tile))
    return tile, cap, drain_batch


def _run_tiled_engine(op, state, cfg, max_rounds, interpret=True, **_):
    solver = batched_solver = None
    tile, cap, drain_batch = _tiled_cfg_defaults(cfg)
    kq = bool(cfg.kernel_queue)
    kq_cap = None
    if cfg.engine == "tiled-pallas":
        # Thread the engine's prod(T_i+2) geodesic bound into the kernels:
        # the kernel-default 1024 is *below* the bound for any 2-D tile
        # >= 32, and a drain cut off there must re-queue, not masquerade as
        # converged.
        max_iters = (tile + 2) ** op.ndim
        if kq:
            from repro.kernels.ops import default_kernel_queue_capacity
            kq_cap = (cfg.kernel_queue_capacity
                      or default_kernel_queue_capacity(
                          (tile + 2,) * op.ndim))
        solver = _pallas_solver_for(op, interpret, max_iters=max_iters,
                                    engine=cfg.engine, kernel_queue=kq,
                                    kernel_queue_capacity=kq_cap)
        if drain_batch > 1:
            batched_solver = _pallas_solver_for(op, interpret, batched=True,
                                                max_iters=max_iters,
                                                engine=cfg.engine,
                                                kernel_queue=kq,
                                                kernel_queue_capacity=kq_cap)
    out, st = run_tiled(op, state, tile=tile, queue_capacity=cap,
                        max_outer_rounds=max_rounds, tile_solver=solver,
                        drain_batch=drain_batch,
                        batched_tile_solver=batched_solver)
    return out, SolveStats(cfg.engine, rounds=int(st.outer_rounds),
                           tiles_processed=int(st.tiles_processed),
                           overflow_events=int(st.overflow_events),
                           tiles_requeued=int(st.tiles_requeued),
                           tile=tile, queue_capacity=cap,
                           drain_batch=drain_batch,
                           kernel_queue=kq, kernel_queue_capacity=kq_cap)


def _run_shard_map_engine(op, state, cfg, max_rounds, devices=None, **_):
    devices = list(devices) if devices is not None else jax.devices()
    nr, nc = _mesh_shape(len(devices))
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(devices).reshape(nr, nc), ("data", "model"))
    padded, orig = _pad_to_multiple(op, state, (nr, nc))
    if cfg.engine == "shard_map-tiled":
        tile, cap, drain_batch = _tiled_cfg_defaults(cfg)
        out, st = run_sharded(op, padded, mesh, tile=tile,
                              queue_capacity=cap, drain_batch=drain_batch,
                              max_bp_rounds=max_rounds)
        return _crop(out, orig), SolveStats(
            cfg.engine, rounds=int(st.bp_rounds),
            tiles_processed=int(st.tiles_processed),
            overflow_events=int(st.overflow_events),
            tiles_requeued=int(st.tiles_requeued),
            tile=tile, queue_capacity=cap, drain_batch=drain_batch,
            n_devices=len(devices))
    out, st = run_sharded(op, padded, mesh, max_bp_rounds=max_rounds)
    return _crop(out, orig), SolveStats("shard_map", rounds=int(st.bp_rounds),
                                        n_devices=len(devices))


def _scheduler_drain_for(op, tile: int):
    # (T+2)^2 iterations bound the longest geodesic inside one block
    # (e.g. a spiral mask); the while_loop exits at stability, so the
    # generous bound costs nothing in the common case.  Out-of-array
    # halo cells arrive already holding the op's neutral pad values
    # (TileScheduler pad_values), so no sanitize pass is needed.  The
    # (block, unconverged) pair is the truncation contract: the host
    # scheduler self-requeues an unconverged drain like run_tiled does.
    # Cached process-wide, so every scheduler/hybrid worker thread shares
    # ONE compiled drain instead of re-tracing per worker (the
    # fig10/scheduler workers=2 regression).
    key = ("scheduler-drain", type(op), op.connectivity, tile)
    return compile_cache.get(key,
                             lambda: jax.jit(default_tile_solver(op, tile)))


def _batched_drain_for(op, tile: int, interpret: bool, pallas: bool,
                       drain_batch: int = 1):
    """Jitted `batched_tile_solver` for the hybrid engine's device workers:
    plain `jax.vmap` of the dense drain, or the Pallas grid-over-batch
    kernels — both at the (T+2)² truncation bound.

    ``drain_batch <= 1`` adapts the *unbatched* jitted solver instead of a
    degenerate K=1 vmap: vmapping `lax.while_loop` re-lowers the drain body
    in batched form, which measures several times slower than the plain
    drain even at batch 1 (the same reason `run_tiled` keeps a sequential
    scan path).
    """
    if pallas:
        return _pallas_solver_for(op, interpret, batched=True,
                                  max_iters=(tile + 2) ** op.ndim,
                                  engine="hybrid")
    if drain_batch <= 1:
        per = _scheduler_drain_for(op, tile)

        def batch_fn(stacked):
            # Strip the batch axis host-side: np slicing is a free view,
            # whereas jnp.asarray(v)[0] would issue an *eager* device slice
            # per leaf per tile — measured at ~2x the whole per-tile drain
            # cost for the hybrid device stream.
            out, unconv = per({k: jnp.asarray(np.asarray(v)[0])
                               for k, v in stacked.items()})
            return ({k: np.asarray(v)[None] for k, v in out.items()},
                    np.asarray(unconv)[None])

        return batch_fn
    key = ("hybrid-batched", type(op), op.connectivity, tile)
    return compile_cache.get(key,
                             lambda: jax.jit(default_batched_solver(op, tile)))


def _host_tile_fn_for(op, tile: int):
    """Host-thread tile task: jitted dense drain over a numpy halo block."""
    _drain = _scheduler_drain_for(op, tile)

    def tile_fn(block):
        out, unconv = _drain({k: jnp.asarray(b) for k, b in block.items()})
        return {k: np.asarray(b) for k, b in out.items()}, bool(unconv)

    return tile_fn


def _scheduler_merge_for(op, engine: str):
    """The host engines' commutative write-back merge, from the op's spec.

    ``None`` (the spec default) selects the scheduler's built-in
    elementwise-max merge — correct for any single-plane monotone-max op.
    An *unregistered* op is an error here (not a silent default): the
    default merge is wrong for coupled/coordinate-dependent state (EDT),
    and silently applying it used to surface as a corrupted fixed point.
    """
    spec = spec_for(op)
    if spec is None:
        raise ValueError(
            f"op {type(op).__name__} is not a registered op, and engine "
            f"{engine!r} needs its commutative merge_block_fn; registered "
            f"ops: {list_ops()}.  Register it with repro.ops.register_op() "
            "(OpSpec.scheduler_merge defaults to the elementwise-max merge) "
            "or the register_scheduler_merge shim.")
    return spec.scheduler_merge(op)


def _scheduler_state_for(op, state, tile: int, engine: str):
    """Shared host-engine setup: padded numpy state + scheduler plumbing."""
    padded, orig = _pad_to_multiple(op, state, (tile,) * op.ndim)
    # np.array (not asarray): JAX buffers give read-only numpy views, and the
    # scheduler writes tile interiors back into this state in place.
    np_state = {k: np.array(v) for k, v in padded.items()}
    active = np.asarray(initial_active_tiles(op, padded, tile))
    merge_block_fn = _scheduler_merge_for(op, engine)
    mutable = tuple(k for k in np_state if k not in op.static_leaves)
    pad_values = {k: np.asarray(v).item()
                  for k, v in op.pad_value(padded).items()}
    return np_state, active, merge_block_fn, mutable, pad_values, orig


def _run_scheduler_engine(op, state, cfg, max_rounds, n_workers=4, **_):
    tile = cfg.tile or DEFAULT_TILES[1]
    (np_state, active, merge_block_fn, mutable, pad_values,
     orig) = _scheduler_state_for(op, state, tile, "scheduler")
    sched = TileScheduler(np_state, tile, _host_tile_fn_for(op, tile), active,
                          n_workers=n_workers, mutable=mutable,
                          merge_block_fn=merge_block_fn,
                          pad_values=pad_values)
    st = sched.run()
    if st.incomplete:
        # Never hand back a partial drain as a solve() result (the scheduler
        # already warned); autotune treats this as a failed candidate.
        raise RuntimeError(
            "scheduler engine gave up with tiles still queued "
            f"(requeues_from_failures={st.requeues_from_failures}); "
            "the state did not reach its fixed point")
    out = _crop({k: jnp.asarray(v) for k, v in np_state.items()}, orig)
    # Engine output contract: invalid cells hold their input values.
    out = restore_invalid(op, state, out)
    return out, SolveStats("scheduler", rounds=1,
                           tiles_processed=st.tiles_processed,
                           requeues=st.requeues_from_failures,
                           tiles_requeued=st.tiles_requeued,
                           tile=tile)


def _bp_residual_for(op):
    """One dense round sourcing from every valid pixel.

    ``state`` is at its fixed point iff this round changes nothing — the
    returned frontier is exactly the set of pixels it improved (the
    "halo-improved" seed of the next hybrid pass, DESIGN.md §2.3).
    """
    def build():
        @jax.jit
        def _residual(state):
            f0 = jnp.ones(tree_shape(state, op.ndim), dtype=bool)
            if "valid" in state:
                f0 = f0 & state["valid"]
            return op.round(state, f0)
        return _residual

    return compile_cache.get(("bp-residual", type(op), op.connectivity),
                             build)


# Test hook: (worker_id | "all", fail_after) injected into every hybrid
# scheduler pass — exercises the cooperative pool's fault tolerance without
# widening the public solve() signature.
_HYBRID_FAIL_INJECT: Optional[Tuple[Any, int]] = None


def _run_hybrid_engine(op, state, cfg, max_rounds, interpret=True,
                       n_workers=4, n_device_workers=1,
                       hybrid_pallas=False, cost_model=None, **_):
    """The cooperative CPU+device engine (paper §4, DESIGN.md §2.3).

    One demand-driven FCFS tile queue, consumed concurrently by
    ``n_workers`` host threads (jitted per-tile drains with commutative
    merge writeback) and ``n_device_workers`` device streams (batched
    `run_tiled`-style drains, ``drain_batch`` blocks per dispatch, chunks
    sized by the ChunkPolicy's measured relative speed).  ``queue_capacity``
    does not apply — the host FCFS queue is unbounded, so the stats report
    it as None rather than echoing an inert knob.  A completed pass
    certifies the fixed point; a pass that lost every worker wave triggers
    a BP recovery round (one dense valid-sourced round) that re-seeds the
    queue with only the tiles it improved (`active_tiles_from_frontier` —
    the same seam as the composed `shard_map-tiled` engine's BP re-seed).
    """
    tile, _, drain_batch = _tiled_cfg_defaults(cfg)
    if n_workers <= 0 and n_device_workers <= 0:
        raise ValueError("hybrid engine needs n_workers >= 1 or "
                         "n_device_workers >= 1")
    (np_state, active, merge_block_fn, mutable, pad_values,
     orig) = _scheduler_state_for(op, state, tile, "hybrid")
    grid = tuple(s // tile
                 for s in np_state[mutable[0]].shape[-op.ndim:])

    tile_fn = _host_tile_fn_for(op, tile) if n_workers > 0 else None
    batch_fn = _batched_drain_for(op, tile, interpret, hybrid_pallas,
                                  drain_batch)
    devs = [DeviceWorker(batch_fn, drain_batch=drain_batch,
                         name=f"device{d}") for d in range(n_device_workers)]
    model = (cost_model if cost_model is not None
             else default_cost_model(interpret))
    # One policy across all BP passes: the EWMA keeps learning the real
    # host:device speed ratio over the whole solve.
    # max_chunk ~ two batched dispatches ahead: more claim-ahead only adds
    # halo staleness without further dispatch amortization.
    policy = ChunkPolicy(model.hybrid_rel_speed(tile, drain_batch),
                         max_chunk=max(2 * max(1, drain_batch), 4),
                         seed_kind=model.kind)
    residual = _bp_residual_for(op)
    fail = _HYBRID_FAIL_INJECT

    tiles_processed = requeues = tiles_requeued = 0
    bp_rounds = 0
    incomplete = True
    while True:
        sched = TileScheduler(
            np_state, tile, tile_fn, active, n_workers=n_workers,
            mutable=mutable, merge_block_fn=merge_block_fn,
            pad_values=pad_values, device_workers=devs, chunk_policy=policy,
            fail_worker=fail[0] if fail else None,
            fail_after=fail[1] if fail else 3)
        st = sched.run()
        tiles_processed += st.tiles_processed
        requeues += st.requeues_from_failures
        tiles_requeued += st.tiles_requeued
        bp_rounds += 1
        if not st.incomplete:
            # A completed pass certifies the fixed point by construction:
            # queue empty + nothing inflight means no pending dirty marks,
            # so every tile is locally stable against its current halos —
            # the same guarantee the solo scheduler engine rests on.
            incomplete = False
            break
        if bp_rounds >= max(1, max_rounds):
            break
        # BP recovery round (the pass lost every worker wave): one dense
        # valid-sourced round makes monotone progress and yields the
        # improved-pixel frontier, which re-seeds the shared queue with
        # only the tiles it touches.  Re-draining any superset of the
        # dirty tiles is exact (monotone commutative updates), so worker
        # death costs extra rounds, never a wrong result — total failure
        # degrades to E1's dense rounds rather than a partial answer.
        new_state, f_in = residual({k: jnp.asarray(v)
                                    for k, v in np_state.items()})
        if not bool(jnp.any(f_in)):
            incomplete = False
            break
        for k in mutable:
            np_state[k] = np.array(new_state[k])
        active = np.asarray(active_tiles_from_frontier(op, f_in, tile, grid))
    if incomplete:
        warnings.warn(
            f"hybrid engine stopped after {bp_rounds} BP rounds with a "
            "non-empty residual frontier; the state is NOT at its fixed "
            "point (SolveStats.incomplete=True)", RuntimeWarning,
            stacklevel=2)
    out = _crop({k: jnp.asarray(v) for k, v in np_state.items()}, orig)
    # Engine output contract: invalid cells hold their input values.
    out = restore_invalid(op, state, out)
    return out, SolveStats("hybrid", rounds=bp_rounds,
                           tiles_processed=tiles_processed,
                           requeues=requeues, tiles_requeued=tiles_requeued,
                           tile=tile, drain_batch=drain_batch,
                           incomplete=incomplete)


_ENGINE_RUNNERS = {
    "sweep": _run_dense_engine,
    "frontier": _run_dense_engine,
    "tiled": _run_tiled_engine,
    "tiled-pallas": _run_tiled_engine,
    "shard_map": _run_shard_map_engine,
    "shard_map-tiled": _run_shard_map_engine,
    "scheduler": _run_scheduler_engine,
    "hybrid": _run_hybrid_engine,
}


def _run_engine(op, state, cfg: EngineConfig, **kw):
    # `recompiles` is the compile-cache miss delta across the run: 0 on a
    # warm re-solve, and — the DESIGN.md §2.6 contract — *independent of
    # the round count* even on a cold one (tests/test_runstate.py).
    t0 = time.monotonic()
    with compile_cache.MissSnapshot() as snap:
        out, st = _ENGINE_RUNNERS[cfg.engine](op, state, cfg, **kw)
    # Force the result resident before closing the clock: with async
    # dispatch the dense engines would otherwise return an unmaterialized
    # future and wall_time_s would under-report the actual solve.
    jax.block_until_ready(out)
    return out, dataclasses.replace(st, recompiles=snap.count,
                                    wall_time_s=time.monotonic() - t0)


# ---------------------------------------------------------------------------
# Public API.
# ---------------------------------------------------------------------------

def solve(op, state, *, engine: str = "auto",
          connectivity: Optional[Union[int, str]] = None,
          devices: Optional[Sequence] = None,
          tile: Optional[int] = None,
          queue_capacity: Optional[int] = None,
          drain_batch: Optional[int] = None,
          kernel_queue: Optional[bool] = None,
          kernel_queue_capacity: Optional[int] = None,
          max_rounds: int = 1_000_000,
          cost_model: Optional[CostModel] = None,
          autotune: bool = False,
          autotune_top_k: int = 3,
          autotune_repeats: int = 2,
          interpret: bool = True,
          n_workers: int = 4,
          n_device_workers: int = 1,
          hybrid_pallas: bool = False) -> Tuple[Any, SolveStats]:
    """Run ``op`` on ``state`` to its fixed point; return (state, SolveStats).

    Parameters
    ----------
    op : a :class:`PropagationOp` instance, or the *name* of a registered
        op (``repro.ops.list_ops()``: ``"morph"``, ``"edt"``,
        ``"fill_holes"``, ``"label"``, ...).  By name, the op is built via
        its :class:`~repro.ops.OpSpec` factory and ``state`` may be the
        op's natural **raw input** instead of a state pytree — a non-dict
        ``state`` (array, or tuple of arrays for multi-input ops like
        morph's ``(marker, mask)``) is passed through the spec's
        ``make_state`` builder: ``solve("edt", fg_image)``.  The result is
        still the converged *state*; apply ``get_op(name).extract`` (or use
        the per-op wrappers) for the user-facing array.
    connectivity : op-level knob for by-name calls, forwarded to the spec
        factory (each op's default applies when None).  Accepts a
        neighborhood *name* (``"conn4"``/``"conn8"`` in 2-D;
        ``"conn6"``/``"conn18"``/``"conn26"`` in 3-D — DESIGN.md §2.7) or
        the legacy 2-D ints 4/8; an unknown name or one the op does not
        support raises ``ValueError`` naming the op and its supported
        neighborhoods.  Invalid with an op instance — construct the
        instance with the connectivity you want.
    engine : one of :data:`ENGINES`.  ``"auto"`` ranks candidates with
        ``cost_model`` (default :class:`CostModel`) and runs the cheapest.
        ``"shard_map-tiled"`` composes the mesh TP/BP pipeline with a
        per-shard active-tile queue (the paper's full two-level hierarchy;
        DESIGN.md §2.2) — ``tile``/``queue_capacity``/``drain_batch`` all
        apply per shard.  It uses the plain per-tile drain; for
        Pallas-backed TP drains call
        :func:`repro.core.distributed.run_sharded` with ``tile_solver``.
    devices : device list for ``"shard_map"`` / ``"shard_map-tiled"``
        (default: ``jax.devices()``); also sets the device count the cost
        model sees.
    tile, queue_capacity : override the tiled engines' blocking; under
        ``"auto"`` they restrict the candidate set instead.
    drain_batch : queue slots the tiled engines drain concurrently per
        dispatch; ``1`` keeps the sequential per-tile scan.  Default: batch
        by :data:`DEFAULT_DRAIN_BATCH` for tiles up to
        :data:`BATCH_DEFAULT_MAX_TILE` (dispatch-bound regime), sequential
        above.  Under ``"auto"`` it restricts the candidate set like
        ``tile``/``queue_capacity``.
    kernel_queue : ``"tiled-pallas"`` only — drain each block through the
        in-kernel multi-level queue (DESIGN.md §2.5): per kernel round only
        the compacted candidate pixels are updated, spilling to one dense
        sweep when they overflow ``kernel_queue_capacity`` (None = a
        wavefront-band default, ``kernels.ops.default_kernel_queue_capacity``).
        Results and round counts are bit-identical to the dense kernels —
        only the per-round work changes.  Under ``"auto"``, ``None``
        (default) keeps both dense and queued ``tiled-pallas`` candidates
        in the ranking; True/False restricts to that variant.
    autotune : with ``engine="auto"``, micro-benchmark the model's top
        ``autotune_top_k`` candidates on this input (``autotune_repeats``
        timed runs each after a warm-up) and cache the winner keyed by
        :func:`autotune_signature`.
    interpret : run Pallas kernels in interpret mode (required off-TPU).
    n_workers : host threads for the ``"scheduler"`` and ``"hybrid"``
        engines (``"hybrid"`` accepts 0 for a device-only pool).
    n_device_workers : batched device drain streams sharing the
        ``"hybrid"`` engine's queue with the host threads (0 for a
        host-only pool; at least one worker of either kind is required).
    hybrid_pallas : back the ``"hybrid"`` device workers with the Pallas
        grid-over-batch kernels instead of the vmapped dense drain.
    """
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    if isinstance(op, str):
        spec = get_op(op)
        op = spec.make_op(connectivity)
        if not isinstance(state, dict):
            # Raw input(s), not a state pytree: build through the spec.
            inputs = state if isinstance(state, tuple) else (state,)
            state = spec.build_state(op, *inputs)
    elif connectivity is not None:
        raise ValueError(
            "connectivity= applies to by-name solve() calls only; construct "
            "the op instance with the desired connectivity instead")
    run_kw = dict(max_rounds=max_rounds, devices=devices,
                  interpret=interpret, n_workers=n_workers,
                  n_device_workers=n_device_workers,
                  hybrid_pallas=hybrid_pallas, cost_model=cost_model)
    if (kernel_queue or kernel_queue_capacity is not None) \
            and engine not in ("tiled-pallas", "auto"):
        raise ValueError(
            "kernel_queue / kernel_queue_capacity apply to the "
            f"'tiled-pallas' engine (or 'auto') only, not {engine!r}: the "
            "in-kernel queue lives inside the Pallas tile solvers "
            "(DESIGN.md §2.5)")

    if engine != "auto":
        cfg = EngineConfig(engine, tile, queue_capacity, drain_batch,
                           kernel_queue=bool(kernel_queue),
                           kernel_queue_capacity=kernel_queue_capacity)
        with calibrate.solve_guard():
            return _run_engine(op, state, cfg, **run_kw)

    n_devices = len(devices) if devices is not None else len(jax.devices())
    tiles = (tile,) if tile is not None else DEFAULT_TILES
    with calibrate.solve_guard():
        return _solve_auto(op, state, tile, tiles, n_devices, queue_capacity,
                           drain_batch, kernel_queue, kernel_queue_capacity,
                           cost_model, interpret, autotune, autotune_top_k,
                           autotune_repeats, run_kw)


def _solve_auto(op, state, tile, tiles, n_devices, queue_capacity,
                drain_batch, kernel_queue, kernel_queue_capacity,
                cost_model, interpret, autotune, autotune_top_k,
                autotune_repeats, run_kw) -> Tuple[Any, SolveStats]:
    """The ``engine="auto"`` path: rank candidates, run the winner, report
    which model decided through ``SolveStats.cost_model``."""
    stats_in = collect_input_stats(op, state, n_devices, tiles)
    model = (cost_model if cost_model is not None
             else default_cost_model(interpret=interpret))

    cands = model.candidates(stats_in, tiles)
    if queue_capacity is not None:
        cands = [dataclasses.replace(c, queue_capacity=queue_capacity)
                 if c.queue_capacity is not None else c for c in cands]
    if drain_batch is not None:
        cands = [dataclasses.replace(c, drain_batch=drain_batch)
                 if c.engine in ("tiled", "tiled-pallas", "shard_map-tiled",
                                 "hybrid")
                 else c for c in cands]
    if kernel_queue is not None:
        # True/False restricts the tiled-pallas candidates to that kernel
        # variant; None (the default) lets dense and queued compete.
        cands = [c for c in cands
                 if c.engine != "tiled-pallas"
                 or c.kernel_queue == bool(kernel_queue)]
    if kernel_queue_capacity is not None:
        cands = [dataclasses.replace(c,
                                     kernel_queue_capacity=kernel_queue_capacity)
                 if c.engine == "tiled-pallas" and c.kernel_queue
                 else c for c in cands]

    if autotune:
        cfg = _autotune(op, state, stats_in, model, cands,
                        (tile, queue_capacity, drain_batch, kernel_queue,
                         kernel_queue_capacity),
                        autotune_top_k, autotune_repeats, **run_kw)
        out, st = _run_engine(op, state, cfg, **run_kw)
        model.calibrate(st)
        return out, dataclasses.replace(
            st, autotuned=True, predicted_cost=model.cost(stats_in, cfg),
            n_devices=max(st.n_devices, 1), cost_model=model.kind)

    cost, cfg = model.rank(stats_in, cands)[0]
    out, st = _run_engine(op, state, cfg, **run_kw)
    model.calibrate(st)
    return out, dataclasses.replace(st, predicted_cost=cost,
                                    cost_model=model.kind)


# ---------------------------------------------------------------------------
# Batch-of-states entry — the serving layer's coalesced solve
# (DESIGN.md §2.9).
# ---------------------------------------------------------------------------

# Engines whose convergence loop is a pure lax.while_loop over the state,
# and therefore vmap cleanly into ONE batched fixed-point program: the
# batching rule freezes converged elements via per-element select, so each
# request's result (and round/source counters) is bit-identical to its solo
# run — extra rounds past an element's fixed point are no-ops.
BATCHABLE_ENGINES = ("frontier", "sweep")


def _batched_dense_for(op, engine: str, max_rounds: int):
    key = ("batch-dense", type(op), op.connectivity, engine, max_rounds)
    return compile_cache.get(
        key, lambda: jax.jit(jax.vmap(
            lambda s: run_dense(op, s, engine, max_rounds))))


def _tree_signature(state):
    return tuple(sorted((k, tuple(v.shape), str(jnp.asarray(v).dtype))
                        for k, v in state.items()))


def solve_batch(op, states: Sequence[Any], *,
                engine: str = "auto",
                connectivity: Optional[Union[int, str]] = None,
                cost_model: Optional[CostModel] = None,
                autotune: bool = False,
                max_rounds: int = 1_000_000,
                interpret: bool = True,
                **engine_kw) -> List[Tuple[Any, SolveStats]]:
    """Solve ``len(states)`` independent same-shaped inputs as one batch.

    The coalescing entry the serving layer (``repro.serve``, DESIGN.md
    §2.9) drains its request queue through: all states must share one tree
    signature (leaf names, shapes, dtypes) — the coalescer's grouping
    contract — and the batch runs as **one** solve wherever the engine
    supports it:

    * dense engines (:data:`BATCHABLE_ENGINES`) — the states are stacked on
      a new leading axis and run under one ``jax.vmap``-ed fixed-point
      loop.  Results are bit-identical to per-state solo solves (the
      while_loop batching rule freezes converged elements), and the
      per-element round/source counters stay exact.
    * every other engine (host-loop engines: tiled/scheduler/hybrid/...) —
      the states run sequentially under the chosen config, still amortizing
      the compiled-step cache and the autotune winner across the batch.

    ``engine="auto"`` ranks candidates once on the first state via
    ``cost_model`` (default :func:`default_cost_model` — the calibrated
    profile when installed) and applies the winner to the whole batch;
    ``autotune=True`` micro-benchmarks the top candidates on the first
    state, sharing the process + disk autotune caches with solo solves.

    Returns a list of ``(state, SolveStats)`` in input order.  Batched
    elements report ``batch_size=len(states)`` and the *batch's* wall time
    (one program solved them all); sequential elements report their own.
    ``engine_kw`` takes the same per-engine knobs as :func:`solve`
    (``tile``, ``queue_capacity``, ``drain_batch``, ...).
    """
    if isinstance(op, str):
        spec = get_op(op)
        op = spec.make_op(connectivity)
        states = [s if isinstance(s, dict) else
                  spec.build_state(op, *(s if isinstance(s, tuple) else (s,)))
                  for s in states]
    elif connectivity is not None:
        raise ValueError(
            "connectivity= applies to by-name solve_batch() calls only; "
            "construct the op instance with the desired connectivity instead")
    states = list(states)
    if not states:
        return []
    sig0 = _tree_signature(states[0])
    for i, s in enumerate(states[1:], start=1):
        if _tree_signature(s) != sig0:
            raise ValueError(
                f"solve_batch needs one tree signature across the batch; "
                f"states[{i}] has {_tree_signature(s)} != states[0]'s "
                f"{sig0}.  Group requests by (op, shape, dtype) first — "
                "the serve-layer coalescer's pad-to-bucket policy exists "
                "for exactly this (docs/SERVING.md)")
    if len(states) == 1:
        out, st = solve(op, states[0], engine=engine, cost_model=cost_model,
                        autotune=autotune, max_rounds=max_rounds,
                        interpret=interpret, **engine_kw)
        return [(out, st)]

    if engine == "auto":
        stats_in = collect_input_stats(op, states[0])
        model = (cost_model if cost_model is not None
                 else default_cost_model(interpret=interpret))
        cands = model.candidates(stats_in)
        with calibrate.solve_guard():
            if autotune:
                cfg = _autotune(op, states[0], stats_in, model, cands,
                                ("batch",), top_k=3, repeats=2,
                                max_rounds=max_rounds, interpret=interpret,
                                devices=None, n_workers=4,
                                n_device_workers=1, hybrid_pallas=False,
                                cost_model=cost_model)
            else:
                cfg = model.rank(stats_in, cands)[0][1]
        chosen, decided_by = cfg, model.kind
    else:
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
        chosen = EngineConfig(engine, engine_kw.get("tile"),
                              engine_kw.get("queue_capacity"),
                              engine_kw.get("drain_batch"),
                              kernel_queue=bool(engine_kw.get("kernel_queue")),
                              kernel_queue_capacity=engine_kw.get(
                                  "kernel_queue_capacity"))
        decided_by = None

    if chosen.engine in BATCHABLE_ENGINES:
        t0 = time.monotonic()
        with calibrate.solve_guard(), compile_cache.MissSnapshot() as snap:
            stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                             *states)
            fn = _batched_dense_for(op, chosen.engine, max_rounds)
            out, rst = fn(stacked)
            jax.block_until_ready(out)
        wall = time.monotonic() - t0
        results = []
        for i in range(len(states)):
            st_i = SolveStats(
                chosen.engine, rounds=int(rst.rounds[i]),
                sources_processed=(int(rst.sources_hi[i]) << 32)
                | int(rst.sources_lo[i]),
                recompiles=snap.count, cost_model=decided_by,
                wall_time_s=wall, batch_size=len(states))
            results.append(
                (jax.tree_util.tree_map(lambda x: x[i], out), st_i))
        return results

    # Host-loop engines: no single-program batch formulation — run the
    # batch sequentially under the one chosen config (compiled steps and
    # autotune winners are shared across the loop via the process caches).
    run_kw = dict(max_rounds=max_rounds, interpret=interpret,
                  devices=engine_kw.get("devices"),
                  n_workers=engine_kw.get("n_workers", 4),
                  n_device_workers=engine_kw.get("n_device_workers", 1),
                  hybrid_pallas=engine_kw.get("hybrid_pallas", False),
                  cost_model=cost_model)
    results = []
    with calibrate.solve_guard():
        for s in states:
            out, st = _run_engine(op, s, chosen, **run_kw)
            results.append((out, dataclasses.replace(
                st, cost_model=decided_by)))
    return results
