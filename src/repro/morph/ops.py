"""Morphological reconstruction as an IWPP `PropagationOp`, plus the FH
initialization (raster/anti-raster) passes in two formulations:

* ``raster_pass_scan``  — the GPU decomposition of paper Algorithm 5 (four
  directional passes), each computed as an O(log n)-depth *associative
  clamp-scan*: the FH row update  v_i = min(I_i, max(J_i, v_{i-1}))  is the
  map x -> min(B, max(A, x)), and such clamps are closed under composition:
      (A1,B1) then (A2,B2)  ==  (max(A1,A2), min(B2, max(A2,B1)))
  This replaces the GPU's sequential per-row loop with a vectorizable scan —
  the TPU-native adaptation described in DESIGN.md §2.
* a dense full-sweep fallback used by the E0 engine (SR_GPU analogue).

State pytree: {"J": marker (mutable), "I": mask (static), "valid": bool}.
Updates only ever *increase* J toward min-with-I — commutative + monotone,
satisfying the IWPP contract.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.pattern import PropagationOp, shiftnd


def _neutral_min(dtype):
    return jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer) else -jnp.inf


@dataclasses.dataclass(frozen=True)
class MorphReconstructOp(PropagationOp):
    """Grayscale reconstruction-by-dilation under mask I (paper §2.1)."""

    @property
    def static_leaves(self):
        return ("I", "valid")

    def make_state(self, marker: jnp.ndarray, mask: jnp.ndarray, valid=None):
        J = jnp.minimum(marker, mask)
        if valid is None:
            valid = jnp.ones(J.shape, dtype=bool)
        return {"J": J, "I": mask, "valid": valid}

    def pad_value(self, state):
        neut = _neutral_min(state["J"].dtype)
        return {"J": neut, "I": neut, "valid": False}

    def init_frontier(self, state) -> jnp.ndarray:
        """FH queue condition (Algorithm 2 line 8, extended to full N_G as in
        the GPU version, Algorithm 5 lines 16-18): p is queued iff it can
        still propagate to some neighbor q: J(q) < J(p) and J(q) < I(q)."""
        J, I = state["J"], state["I"]
        neut = _neutral_min(J.dtype)
        can = jnp.zeros(J.shape, dtype=bool)
        for off in self.offsets:
            Jq = shiftnd(J, off, neut)
            Iq = shiftnd(I, off, neut)
            can = can | ((Jq < J) & (Jq < Iq))
        return can & state["valid"]

    def round(self, state, frontier) -> Tuple[dict, jnp.ndarray]:
        """One bulk round: every frontier pixel propagates to all neighbors.

        J'(q) = min(I(q), max(J(q), max_{p in N(q) & frontier} J(p))).
        The max-reduction over shifted neighbor planes computes, race-free,
        what the GPU does with atomicMax (paper Algorithm 5 line 24).
        """
        J, I = state["J"], state["I"]
        neut = _neutral_min(J.dtype)
        src = jnp.where(frontier, J, neut)
        cand = jnp.full_like(J, neut)
        for off in self.offsets:
            cand = jnp.maximum(cand, shiftnd(src, off, neut))
        Jn = jnp.minimum(I, jnp.maximum(J, cand))
        new_frontier = (Jn > J) & state["valid"]
        return {"J": Jn, "I": I, "valid": state["valid"]}, new_frontier


def reconstruct(marker, mask, *, connectivity: int = 8, engine: str = "auto",
                n_sweeps: int = 0, **solve_kw):
    """One-call morphological reconstruction through the solve() dispatcher.

    Optionally runs ``n_sweeps`` FH raster/anti-raster init passes first
    (paper Table 1's knob: deeper init -> smaller irregular wavefront), then
    dispatches to the engine picked by ``engine`` (see repro.solve.ENGINES).
    Returns (reconstructed J, SolveStats).  Thin registry-backed wrapper:
    op construction, state building and result extraction all go through
    the ``"morph"`` :class:`~repro.ops.OpSpec`.
    """
    from repro.ops import run_op
    J = jnp.asarray(marker)
    I = jnp.asarray(mask)
    if n_sweeps:
        J = fh_init(J, I, n_sweeps=n_sweeps)
    return run_op("morph", J, I, connectivity=connectivity, engine=engine,
                  **solve_kw)


# ---------------------------------------------------------------------------
# FH initialization phase: directional raster passes.
# ---------------------------------------------------------------------------

def _clamp_compose(left, right):
    """Compose two clamps x -> min(B, max(A, x)); `left` is applied first."""
    A1, B1 = left
    A2, B2 = right
    return jnp.maximum(A1, A2), jnp.minimum(B2, jnp.maximum(A2, B1))


def _directional_scan(J, I, axis: int, reverse: bool):
    """One directional FH pass via associative clamp-scan along `axis`."""
    A, B = jax.lax.associative_scan(
        lambda l, r: _clamp_compose(l, r), (J, I), axis=axis, reverse=reverse)
    # v_i = g_i(-inf) = min(B_i, A_i)
    return jnp.minimum(B, A)


def raster_pass_scan(J, I):
    """Raster half-pass (row-wise then column-wise forward), Algorithm 5 l.2-8."""
    J = _directional_scan(J, I, axis=1, reverse=False)
    J = _directional_scan(J, I, axis=0, reverse=False)
    return J


def antiraster_pass_scan(J, I):
    """Anti-raster half-pass (row/col backward), Algorithm 5 l.9-15."""
    J = _directional_scan(J, I, axis=1, reverse=True)
    J = _directional_scan(J, I, axis=0, reverse=True)
    return J


def fh_init(marker, mask, n_sweeps: int = 1):
    """FH initialization: n_sweeps x (raster + anti-raster).  Returns J.

    ``n_sweeps`` is the knob the paper uses (Table 1) to vary the initial
    queue size: more sweeps resolve more propagation regularly, leaving a
    smaller irregular wavefront.
    """
    J = jnp.minimum(marker, mask)
    for _ in range(n_sweeps):
        J = raster_pass_scan(J, mask)
        J = antiraster_pass_scan(J, mask)
    return J
