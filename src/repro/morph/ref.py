"""Sequential reference implementations of morphological reconstruction.

These are the paper's own algorithms, transcribed verbatim from the text:

* ``reconstruct_naive``  — iterated elementary dilation + pixelwise min with
  the mask, run to the fixed point (the *definition* of grayscale
  reconstruction, Vincent [55]).  Oracle-of-oracles.
* ``reconstruct_sr``     — Sequential Reconstruction (SR): alternating
  raster / anti-raster sweeps until stability (paper §2.1).
* ``reconstruct_fh``     — Fast Hybrid (FH), paper Algorithm 2: one raster +
  one anti-raster pass, then a FIFO-queue wavefront propagation phase.
  This is the baseline every parallel engine must match exactly.

All operate on integer or float grayscale images with ``marker <= mask``
elementwise (enforced by clipping, as in standard implementations).
"""

from __future__ import annotations

from collections import deque

import numpy as np

# Neighborhoods.  N_PLUS / N_MINUS are the causal / anti-causal halves used
# by the raster and anti-raster sweeps (paper §2.1).
N8 = ((-1, -1), (-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0), (1, 1))
N4 = ((-1, 0), (0, -1), (0, 1), (1, 0))
N8_PLUS = ((-1, -1), (-1, 0), (-1, 1), (0, -1))
N8_MINUS = ((0, 1), (1, -1), (1, 0), (1, 1))
N4_PLUS = ((-1, 0), (0, -1))
N4_MINUS = ((0, 1), (1, 0))


def _nbrs(connectivity: int):
    if connectivity == 8:
        return N8, N8_PLUS, N8_MINUS
    if connectivity == 4:
        return N4, N4_PLUS, N4_MINUS
    raise ValueError(f"connectivity must be 4 or 8, got {connectivity}")


def _dilate(J: np.ndarray, connectivity: int) -> np.ndarray:
    """Elementary (3x3 or plus-shaped) grayscale dilation."""
    full, _, _ = _nbrs(connectivity)
    out = J.copy()
    H, W = J.shape
    for dr, dc in full:
        src = np.full_like(J, np.iinfo(J.dtype).min if J.dtype.kind in "iu" else -np.inf)
        rs, re = max(0, -dr), min(H, H - dr)
        cs, ce = max(0, -dc), min(W, W - dc)
        src[rs:re, cs:ce] = J[rs + dr : re + dr, cs + dc : ce + dc]
        out = np.maximum(out, src)
    return out


def reconstruct_naive(marker: np.ndarray, mask: np.ndarray, connectivity: int = 8,
                      max_iters: int = 10_000_000) -> np.ndarray:
    """Fixed point of J <- min(dilate(J), I).  Slow; for tiny test images."""
    J = np.minimum(marker, mask).astype(marker.dtype)
    I = mask
    for _ in range(max_iters):
        Jn = np.minimum(_dilate(J, connectivity), I)
        if np.array_equal(Jn, J):
            return Jn
        J = Jn
    raise RuntimeError("reconstruct_naive did not converge")


def _raster_pass(J, I, offsets, order):
    """One raster (order=+1) or anti-raster (order=-1) sweep, in place."""
    H, W = J.shape
    rows = range(H) if order > 0 else range(H - 1, -1, -1)
    cols = range(W) if order > 0 else range(W - 1, -1, -1)
    changed = False
    for r in rows:
        for c in cols:
            v = J[r, c]
            for dr, dc in offsets:
                rr, cc = r + dr, c + dc
                if 0 <= rr < H and 0 <= cc < W and J[rr, cc] > v:
                    v = J[rr, cc]
            v = min(v, I[r, c])
            if v != J[r, c]:
                J[r, c] = v
                changed = True
    return changed


def reconstruct_sr(marker, mask, connectivity: int = 8, max_sweeps: int = 1_000_000):
    """Sequential Reconstruction: alternating raster/anti-raster to stability."""
    _, plus, minus = _nbrs(connectivity)
    I = np.asarray(mask)
    J = np.minimum(marker, I).copy()
    for _ in range(max_sweeps):
        ch1 = _raster_pass(J, I, plus, +1)
        ch2 = _raster_pass(J, I, minus, -1)
        if not (ch1 or ch2):
            return J
    raise RuntimeError("reconstruct_sr did not converge")


def reconstruct_fh(marker, mask, connectivity: int = 8):
    """Fast Hybrid reconstruction — paper Algorithm 2, verbatim."""
    full, plus, minus = _nbrs(connectivity)
    I = np.asarray(mask)
    J = np.minimum(marker, I).copy()
    H, W = J.shape

    # Initialization phase: raster pass with N+, anti-raster with N-.
    _raster_pass(J, I, plus, +1)
    # Anti-raster pass; queue pixels per Algorithm 2 line 8.
    q: deque = deque()
    for r in range(H - 1, -1, -1):
        for c in range(W - 1, -1, -1):
            v = J[r, c]
            for dr, dc in minus:
                rr, cc = r + dr, c + dc
                if 0 <= rr < H and 0 <= cc < W and J[rr, cc] > v:
                    v = J[rr, cc]
            v = min(v, I[r, c])
            J[r, c] = v
            for dr, dc in minus:
                rr, cc = r + dr, c + dc
                if 0 <= rr < H and 0 <= cc < W:
                    if J[rr, cc] < v and J[rr, cc] < I[rr, cc]:
                        q.append((r, c))
                        break

    # Wavefront propagation phase (lines 11-16).
    while q:
        r, c = q.popleft()
        vp = J[r, c]
        for dr, dc in full:
            rr, cc = r + dr, c + dc
            if 0 <= rr < H and 0 <= cc < W:
                if J[rr, cc] < vp and I[rr, cc] != J[rr, cc]:
                    J[rr, cc] = min(vp, I[rr, cc])
                    q.append((rr, cc))
    return J
