"""The first-class operation plugin registry: ``OpSpec`` + ``register_op``.

The paper frames IWPP as a *pattern* shared by a whole family of image
operations — morphological reconstruction and EDT are the two it
benchmarks, with fill-holes and h-maxima named as further instances (§2),
and the MIC follow-up (Gomes & Teodoro 2016) ports the pattern across
operations by swapping the propagation condition, not the engine.  This
module is that seam made explicit: **an operation is a declarative
:class:`OpSpec`**, and every engine-facing plug point the dispatch layer
needs — Pallas tile solvers, the host scheduler's commutative merge, the
cost model's per-op weights, state construction and result extraction —
lives on the spec, not inside ``solve.py``.

Adding an operation therefore never touches engine code (the acceptance
bar of docs/OPS.md "add your own op in ~50 lines"):

    from repro.ops import OpSpec, register_op
    register_op("my_op", OpSpec(op_cls=MyOp, factory=MyOp, ...))
    solve("my_op", my_input, engine="tiled")      # every engine, by name

Two indices back the registry:

* **by name** — what ``solve("edt", ...)``, :func:`get_op` and
  :func:`list_ops` use;
* **by op class** — what the engines use to resolve an op *instance* to
  its spec (:func:`spec_for`, MRO walk so derived ops inherit their
  parent's plug points unless they register their own).

The legacy per-plug-point registrars (``repro.solve.register_pallas_solver``
/ ``register_scheduler_merge``) remain as shims over :func:`amend_op_class`:
they patch the class-indexed spec in place, creating an anonymous (unnamed)
spec when the class was never ``register_op``'d.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple, Union

__all__ = [
    "OpSpec", "register_op", "get_op", "list_ops", "spec_for",
    "amend_op_class", "default_scheduler_merge", "on_spec_change", "run_op",
]


def default_scheduler_merge(op) -> None:
    """The default ``scheduler_merge`` factory: ``None`` tells the host
    scheduler to use its built-in elementwise-max merge — correct for any
    op whose mutable state is a single monotone-max plane (morph, fill
    holes, label propagation).  Ops whose merge couples leaves or depends
    on pixel coordinates (EDT's Voronoi pointers) register a real factory.
    """
    return None


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Declarative description of one IWPP operation (DESIGN.md §2.4).

    Only ``op_cls`` and ``factory`` are mandatory; everything else has a
    working default, so a minimal op runs on the generic engines (sweep /
    frontier / tiled / shard_map) immediately and opts into the specialized
    ones (Pallas drains, host scheduler, cost-model weighting) by filling
    the corresponding fields.

    Plug points
    -----------
    op_cls : the ``PropagationOp`` subclass instances of which this spec
        describes.  Engines resolve an op instance to its spec by MRO walk
        over this index, so a derived op (e.g. ``FillHolesOp`` deriving
        from ``MorphReconstructOp``) inherits plug points it doesn't
        override.
    factory : ``factory(**op_kw) -> PropagationOp`` — builds the op for
        by-name ``solve()`` calls (op-level knobs such as ``connectivity``
        pass through).
    make_state : ``make_state(op, *inputs, **kw) -> state`` — builds the
        op's state pytree from its natural raw inputs (image(s)).  Default
        delegates to ``op.make_state``.
    finalize : ``finalize(op, out_state) -> result`` — extracts the
        user-facing result array from a converged state (morph: the ``J``
        plane; EDT: the squared distance map).  Default: the state itself.
    pallas_solver / pallas_batch_solver : ``f(op, interpret, max_iters) ->
        tile_solver`` factories for the ``tiled-pallas`` engine and the
        hybrid engine's Pallas device workers; the solver contract is
        ``block -> (block, unconverged)`` (``kernels/ops.py``,
        DESIGN.md §2.1).  Without a batched factory the engine falls back
        to ``jax.vmap`` of the per-tile solver.
    pallas_queue_solver / pallas_queue_batch_solver :
        ``f(op, interpret, max_iters, queue_capacity) -> tile_solver`` —
        the queued-kernel variants behind ``solve(..., kernel_queue=True)``
        (in-kernel multi-level queue, DESIGN.md §2.5).  Same solver
        contract; ``queue_capacity`` is the per-block local-queue size
        (``None`` = the kernel-side default).  Optional: ops without them
        simply reject ``kernel_queue=True`` with a clear error.
    scheduler_merge : ``f(op) -> merge_block_fn | None`` — the host
        scheduler's commutative write-back merge (None = built-in
        elementwise max, see :func:`default_scheduler_merge`).
    example_state : ``f(rng, (H, W)) -> (op, state)`` — a representative
        random *masked* input for the op-contract conformance suite
        (``tests/test_op_contract.py``): registering an op with this field
        buys idempotence / engine-equivalence / invalid-restore checks for
        free.

    Geometry capabilities (DESIGN.md §2.7)
    --------------------------------------
    supported_ndims : spatial ranks the op's state builder and round
        support (default: 2-D only).  Ops whose rounds are rank-generic
        (morph, edt) declare ``(2, 3)``.
    neighborhoods : canonical connectivity names accepted by
        :meth:`make_op` — a subset of ``repro.core.geometry.NEIGHBORHOODS``
        (2-D: ``conn4``/``conn8``; 3-D: ``conn6``/``conn18``/``conn26``).
        A by-name ``solve(..., connectivity=...)`` request outside this set
        raises ``ValueError`` naming the op, the requested name, and this
        list.  Legacy ints 4/8 mean ``conn4``/``conn8``.

    Cost-model hints
    ----------------
    bytes_per_pixel : HBM bytes of *mutable* payload per pixel (morph: one
        int32 ``J`` plane = 4; EDT: the (ndim, *spatial) int32 ``vr``
        pointer = 4*ndim).  Scales ``CostModel.transfer_cost``.
    round_cost_weight : relative compute of one propagation round per
        pixel against morph's 8-neighbor max (EDT's distance arithmetic
        ~ 2x).  Scales ``CostModel.drain_cost``.
    calibration_states : ``f(size) -> [(label, op, state), ...]`` —
        representative workloads (typically one sparse-wavefront and one
        dense/near-converged regime) that :func:`repro.core.calibrate.
        run_calibration` measures to build this op's entries in the
        measured cost profile (DESIGN.md §2.8).  Ops without it are priced
        by the morph reference rates scaled by the two hint fields above.
    """

    op_cls: type
    factory: Callable
    name: str = ""
    make_state: Optional[Callable] = None
    finalize: Optional[Callable] = None
    pallas_solver: Optional[Callable] = None
    pallas_batch_solver: Optional[Callable] = None
    pallas_queue_solver: Optional[Callable] = None
    pallas_queue_batch_solver: Optional[Callable] = None
    scheduler_merge: Callable = default_scheduler_merge
    example_state: Optional[Callable] = None
    supported_ndims: Tuple[int, ...] = (2,)
    neighborhoods: Tuple[str, ...] = ("conn4", "conn8")
    bytes_per_pixel: float = 4.0
    round_cost_weight: float = 1.0
    calibration_states: Optional[Callable] = None
    doc: str = ""

    def make_op(self, connectivity: Optional[Union[int, str]] = None):
        """Build the op via the factory, forwarding the op-level
        ``connectivity`` knob only when given (each op's own default
        applies otherwise).  The single construction path behind both
        by-name ``solve()`` and :func:`run_op` — and the single validation
        point for the connectivity-by-name contract: an unknown name, or a
        known one this op does not declare in ``neighborhoods``, raises
        ``ValueError`` here, before any engine work happens."""
        if connectivity is not None:
            from repro.core.geometry import NEIGHBORHOODS, connectivity_name
            canon = connectivity_name(connectivity)   # raises on unknown
            if canon not in self.neighborhoods:
                label = self.name or self.op_cls.__name__
                raise ValueError(
                    f"op {label!r} does not support connectivity "
                    f"{connectivity!r} ({canon!r}, "
                    f"{NEIGHBORHOODS[canon].ndim}-D); supported "
                    f"neighborhoods: {list(self.neighborhoods)} "
                    f"(supported ndims: {list(self.supported_ndims)})")
        return self.factory(**({} if connectivity is None
                               else {"connectivity": connectivity}))

    def build_state(self, op, *inputs, **kw):
        """Build the op's state from raw inputs via the spec's builder."""
        if self.make_state is not None:
            return self.make_state(op, *inputs, **kw)
        return op.make_state(*inputs, **kw)

    def extract(self, op, out_state):
        """Extract the user-facing result from a converged state."""
        if self.finalize is not None:
            return self.finalize(op, out_state)
        return out_state


_BY_NAME: Dict[str, OpSpec] = {}
_BY_CLASS: Dict[type, OpSpec] = {}
# Hooks fired with the op class whenever its spec is (re)registered or
# amended — lets spec-derived caches elsewhere (e.g. the solve layer's
# jitted-solver memo) invalidate instead of serving a stale plug point.
_SPEC_CHANGE_HOOKS: list = []


def on_spec_change(hook: Callable[[type], None]) -> None:
    """Subscribe ``hook(op_cls)`` to spec registrations/amendments."""
    _SPEC_CHANGE_HOOKS.append(hook)


def _notify_spec_change(op_cls: type) -> None:
    for hook in _SPEC_CHANGE_HOOKS:
        hook(op_cls)


def register_op(name: str, spec: OpSpec) -> OpSpec:
    """Register ``spec`` under ``name`` (and under ``spec.op_cls``).

    Re-registering a name replaces the previous spec (latest wins — the
    same semantics as the legacy per-plug-point registrars).  Returns the
    stored spec (with ``name`` filled in).
    """
    if not name:
        raise ValueError("op name must be a non-empty string")
    spec = dataclasses.replace(spec, name=name)
    _BY_NAME[name] = spec
    _BY_CLASS[spec.op_cls] = spec
    _notify_spec_change(spec.op_cls)
    return spec


def get_op(name: str) -> OpSpec:
    """Look up a registered op by name; raises with the alternatives."""
    try:
        return _BY_NAME[name]
    except KeyError:
        raise ValueError(
            f"unknown op {name!r}; registered ops: {list_ops()} "
            "(register new ops with repro.ops.register_op)") from None


def list_ops() -> Tuple[str, ...]:
    """Names of all registered ops, sorted."""
    return tuple(sorted(_BY_NAME))


def spec_for(op) -> Optional[OpSpec]:
    """Resolve an op *instance* to its spec via MRO walk (None if the op's
    class hierarchy was never registered)."""
    for cls in type(op).__mro__:
        if cls in _BY_CLASS:
            return _BY_CLASS[cls]
    return None


def amend_op_class(op_cls: type, **fields) -> OpSpec:
    """Patch plug-point fields onto the spec indexed under ``op_cls``.

    Backs the legacy ``register_pallas_solver`` / ``register_scheduler_merge``
    shims: if ``op_cls`` itself was never registered, an *anonymous* spec is
    created for it (class index only — it does not appear in
    :func:`list_ops` and cannot be solved by name), **seeded from the
    nearest registered ancestor's spec** so amending one plug point on a
    subclass keeps every other plug point the old per-plug-point MRO
    registries would have inherited (e.g. ``register_pallas_solver`` on an
    ``EdtOp`` subclass must not silently swap its coordinate-aware
    scheduler merge for the elementwise-max default).
    """
    spec = _BY_CLASS.get(op_cls)
    if spec is None:
        parent = next((_BY_CLASS[c] for c in op_cls.__mro__ if c in _BY_CLASS),
                      None)
        spec = (OpSpec(op_cls=op_cls, factory=op_cls) if parent is None else
                dataclasses.replace(parent, op_cls=op_cls, factory=op_cls,
                                    name=""))
    spec = dataclasses.replace(spec, **fields)
    _BY_CLASS[op_cls] = spec
    if spec.name:
        _BY_NAME[spec.name] = spec
    _notify_spec_change(op_cls)
    return spec


def run_op(name: str, *inputs, connectivity: Optional[Union[int, str]] = None,
           **solve_kw):
    """Run a registered op end to end: build, solve, extract.

    The one-call protocol every per-op wrapper (``reconstruct``, ``edt``,
    ``fill_holes``, ``label``) delegates to: build the op via the spec
    factory (forwarding ``connectivity`` when given), build the state from
    the raw ``inputs``, ``solve()`` with the remaining keywords, and
    return ``(spec.extract(op, out), SolveStats)`` — the user-facing
    result, not the state pytree (use ``solve(name, ...)`` directly when
    the converged state itself is wanted).
    """
    from repro.solve import solve
    spec = get_op(name)
    op = spec.make_op(connectivity)
    out, stats = solve(op, spec.build_state(op, *inputs), **solve_kw)
    return spec.extract(op, out), stats
