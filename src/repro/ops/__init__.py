"""`repro.ops` — the first-class operation plugin API (DESIGN.md §2.4).

Public surface:

* :class:`~repro.ops.registry.OpSpec` — declarative op description (state
  builder, result extractor, Pallas solver factories, scheduler merge,
  cost-model hints, conformance example).
* :func:`~repro.ops.registry.register_op` / :func:`get_op` /
  :func:`list_ops` / :func:`spec_for` — the registry.
* Importing this package registers the built-in catalog (morph, edt,
  fill_holes, label) — see ``repro/ops/builtin.py`` and docs/OPS.md.
"""

from repro.ops.registry import (OpSpec, amend_op_class,  # noqa: F401
                                default_scheduler_merge, get_op, list_ops,
                                on_spec_change, register_op, run_op, spec_for)
from repro.ops.builtin import ensure_builtin_ops  # noqa: F401

ensure_builtin_ops()
