"""Built-in `OpSpec` registrations: the op catalog shipped with the repo.

One function per op builds and registers its spec; :func:`ensure_builtin_ops`
is idempotent and is called by ``repro.solve`` (and ``repro.ops``) at import
time, so ``list_ops()`` is populated before any dispatch happens.  The specs
here are the reference examples for docs/OPS.md "add your own op":

* ``morph``       — grayscale reconstruction-by-dilation (paper §2.1); the
                    cost model's reference op (weights 1.0/4B).
* ``edt``         — euclidean distance transform via Voronoi pointers
                    (paper Alg. 3/6); coordinate-aware scheduler merge,
                    2 int32 mutable planes, ~2x round arithmetic.
* ``fill_holes``  — border-seeded reconstruction of the complement: a
                    *derived* op whose spec reuses the morph Pallas solvers
                    **through the registry** (spec-level composition).
* ``label``       — connected-component labeling as monotone max-label
                    flood fill; Pallas solver = the morph kernel
                    parametrized (`kernels/ops.py: tile_solver_label`).
"""

from __future__ import annotations

import numpy as np

from repro.ops.registry import OpSpec, get_op, register_op

_REGISTERED = False


def _rng_valid(rng, shape, frac: float = 0.85):
    """Random non-rectangular valid mask for conformance examples."""
    import jax.numpy as jnp
    v = rng.random(shape) < frac
    # keep the mask non-degenerate: at least one valid pixel
    v[tuple(s // 2 for s in shape)] = True
    return jnp.asarray(v)


def _example_connectivity(shape):
    """The conformance suite's default neighborhood for a given rank: full
    Moore connectivity (2-D conn8 keeps its historical legacy-int spelling
    so cache keys and stats stay bit-identical; 3-D uses conn26)."""
    return 8 if len(shape) == 2 else "conn26"


def _calibration_states_morph(size: int):
    from repro.ops.workloads import morph_state
    # Two regimes on purpose (DESIGN.md §2.8): the sparse seeded wavefront
    # (long rounds, deep per-tile drains) and the fh_init near-converged
    # marker (long rounds, shallow drains) — the pair spans the density
    # axis the measured model interpolates over.
    return [("sparse",) + morph_state(size, coverage=1.0, seed=0,
                                      marker_kind="seeded"),
            ("dense",) + morph_state(size, coverage=1.0, seed=0,
                                     n_sweeps=1)]


def _calibration_states_edt(size: int):
    from repro.ops.workloads import edt_state
    return [("sparse",) + edt_state(size, coverage=0.9, seed=0)]


def _calibration_states_fill(size: int):
    from repro.ops.workloads import fill_state
    return [("sparse",) + fill_state(size, coverage=0.5, seed=0)]


def _calibration_states_label(size: int):
    from repro.ops.workloads import label_state
    return [("dense",) + label_state(size, coverage=0.55, seed=0)]


def _register_morph():
    import jax.numpy as jnp
    from repro.kernels.ops import (tile_solver_morph,
                                   tile_solver_morph_batched,
                                   tile_solver_morph_queued,
                                   tile_solver_morph_queued_batched)
    from repro.morph.ops import MorphReconstructOp

    def example_state(rng, shape):
        op = MorphReconstructOp(connectivity=_example_connectivity(shape))
        mask = rng.integers(0, 200, shape).astype(np.int32)
        marker = np.where(rng.random(shape) < 0.03, mask, 0).astype(np.int32)
        return op, op.make_state(jnp.asarray(marker), jnp.asarray(mask),
                                 _rng_valid(rng, shape))

    register_op("morph", OpSpec(
        op_cls=MorphReconstructOp,
        factory=MorphReconstructOp,
        finalize=lambda op, out: out["J"],
        pallas_solver=lambda op, interpret, max_iters:
            tile_solver_morph(op.connectivity, interpret, max_iters),
        pallas_batch_solver=lambda op, interpret, max_iters:
            tile_solver_morph_batched(op.connectivity, interpret, max_iters),
        pallas_queue_solver=lambda op, interpret, max_iters, queue_capacity:
            tile_solver_morph_queued(op.connectivity, interpret, max_iters,
                                     queue_capacity),
        pallas_queue_batch_solver=(
            lambda op, interpret, max_iters, queue_capacity:
            tile_solver_morph_queued_batched(op.connectivity, interpret,
                                             max_iters, queue_capacity)),
        # default elementwise-max merge; single int32 mutable plane (J) and
        # the 8-neighbor max round define the cost model's unit weights.
        example_state=example_state,
        supported_ndims=(2, 3),
        neighborhoods=("conn4", "conn8", "conn6", "conn18", "conn26"),
        bytes_per_pixel=4.0, round_cost_weight=1.0,
        calibration_states=_calibration_states_morph,
        doc="grayscale morphological reconstruction-by-dilation (paper §2.1)"))


def _register_edt():
    import jax.numpy as jnp
    from repro.edt.ops import EdtOp, distance_map
    from repro.kernels.ops import (tile_solver_edt, tile_solver_edt_batched,
                                   tile_solver_edt_queued,
                                   tile_solver_edt_queued_batched)

    def merge_factory(op):
        def merge(origin, old_inner, new_inner):
            # Keep, per pixel, whichever Voronoi pointer is closer; the
            # host-scheduler analogue of Algorithm 6's atomicCAS retry.
            # ``origin`` is the interior's global ndim-tuple; the global
            # coordinate grids are rebuilt per axis (np.ogrid broadcasts).
            vo = old_inner["vr"].astype(np.int64)
            vn = new_inner["vr"].astype(np.int64)
            grids = np.ogrid[tuple(slice(o, o + s)
                                   for o, s in zip(origin, vo.shape[1:]))]
            d_old = sum((g - vo[a]) ** 2 for a, g in enumerate(grids))
            d_new = sum((g - vn[a]) ** 2 for a, g in enumerate(grids))
            take = d_new < d_old
            return {"vr": np.where(take[None], new_inner["vr"], old_inner["vr"])}
        return merge

    def example_state(rng, shape):
        op = EdtOp(connectivity=_example_connectivity(shape))
        fg = rng.random(shape) < 0.9
        return op, op.make_state(jnp.asarray(fg), _rng_valid(rng, shape))

    register_op("edt", OpSpec(
        op_cls=EdtOp,
        factory=EdtOp,
        finalize=lambda op, out: distance_map(out),
        pallas_solver=lambda op, interpret, max_iters:
            tile_solver_edt(op.connectivity, interpret, max_iters),
        pallas_batch_solver=lambda op, interpret, max_iters:
            tile_solver_edt_batched(op.connectivity, interpret, max_iters),
        pallas_queue_solver=lambda op, interpret, max_iters, queue_capacity:
            tile_solver_edt_queued(op.connectivity, interpret, max_iters,
                                   queue_capacity),
        pallas_queue_batch_solver=(
            lambda op, interpret, max_iters, queue_capacity:
            tile_solver_edt_queued_batched(op.connectivity, interpret,
                                           max_iters, queue_capacity)),
        scheduler_merge=merge_factory,
        example_state=example_state,
        supported_ndims=(2, 3),
        neighborhoods=("conn4", "conn8", "conn6", "conn18", "conn26"),
        # mutable payload = the (ndim, *spatial) int32 vr pointer; one round
        # does n_offsets squared-distance computes vs morph's maxes.
        bytes_per_pixel=8.0, round_cost_weight=2.0,
        calibration_states=_calibration_states_edt,
        doc="squared euclidean distance transform (Danielsson/paper Alg. 3)"))


def _register_fill_holes():
    import jax.numpy as jnp
    from repro.fill.ops import FillHolesOp

    def example_state(rng, shape):
        op = FillHolesOp(connectivity=4)
        img = rng.random(shape) < 0.45
        return op, op.make_state(jnp.asarray(img), _rng_valid(rng, shape))

    register_op("fill_holes", OpSpec(
        op_cls=FillHolesOp,
        factory=FillHolesOp,
        finalize=lambda op, out: op.filled(out),
        # Spec-level composition: a derived op reuses its parent's Pallas
        # kernels *through the registry* — fill-holes state is literally a
        # morph state (J/I/valid), so the morph solvers apply verbatim.
        pallas_solver=lambda op, interpret, max_iters:
            get_op("morph").pallas_solver(op, interpret, max_iters),
        pallas_batch_solver=lambda op, interpret, max_iters:
            get_op("morph").pallas_batch_solver(op, interpret, max_iters),
        pallas_queue_solver=lambda op, interpret, max_iters, queue_capacity:
            get_op("morph").pallas_queue_solver(op, interpret, max_iters,
                                                queue_capacity),
        pallas_queue_batch_solver=(
            lambda op, interpret, max_iters, queue_capacity:
            get_op("morph").pallas_queue_batch_solver(op, interpret,
                                                      max_iters,
                                                      queue_capacity)),
        example_state=example_state,
        bytes_per_pixel=4.0, round_cost_weight=1.0,
        calibration_states=_calibration_states_fill,
        doc="binary fill-holes = border-seeded reconstruction of the "
            "complement (paper §2's named further IWPP instance)"))


def _register_label():
    import jax.numpy as jnp
    from repro.kernels.ops import (tile_solver_label,
                                   tile_solver_label_batched,
                                   tile_solver_label_queued,
                                   tile_solver_label_queued_batched)
    from repro.label.ops import LabelPropagationOp

    def example_state(rng, shape):
        op = LabelPropagationOp(connectivity=8)
        fg = rng.random(shape) < 0.55
        return op, op.make_state(jnp.asarray(fg), _rng_valid(rng, shape))

    register_op("label", OpSpec(
        op_cls=LabelPropagationOp,
        factory=LabelPropagationOp,
        finalize=lambda op, out: out["lab"],
        pallas_solver=lambda op, interpret, max_iters:
            tile_solver_label(op.connectivity, interpret, max_iters),
        pallas_batch_solver=lambda op, interpret, max_iters:
            tile_solver_label_batched(op.connectivity, interpret, max_iters),
        pallas_queue_solver=lambda op, interpret, max_iters, queue_capacity:
            tile_solver_label_queued(op.connectivity, interpret, max_iters,
                                     queue_capacity),
        pallas_queue_batch_solver=(
            lambda op, interpret, max_iters, queue_capacity:
            tile_solver_label_queued_batched(op.connectivity, interpret,
                                             max_iters, queue_capacity)),
        # default elementwise-max merge: lab is a single monotone-max plane
        example_state=example_state,
        bytes_per_pixel=4.0, round_cost_weight=1.0,
        calibration_states=_calibration_states_label,
        doc="connected-component labeling as monotone max-label flood fill"))


def ensure_builtin_ops() -> None:
    """Register the built-in op catalog (idempotent)."""
    global _REGISTERED
    if _REGISTERED:
        return
    _REGISTERED = True
    _register_morph()
    _register_edt()
    _register_fill_holes()
    _register_label()
