"""Canonical workload builders shared by benchmarks, calibration and the
selection-regression tests.

These used to live in ``benchmarks/common.py``; they moved into the package
so that (a) ``core/calibrate.py`` can measure per-op profiles on the same
inputs the benchmarks time, and (b) ``tests/test_calibration.py`` can replay
committed ``BENCH_*.json`` records by rebuilding the exact workload each
record named.  ``benchmarks/common.py`` re-exports them, so bench scripts
are unchanged.

Every builder returns ``(op, state)`` for :func:`repro.solve.solve`.
Determinism matters more than realism here: the same ``(size, seed)`` must
rebuild the same input on every machine, or the replay harness would test
a different problem than the committed record measured.
"""

from __future__ import annotations

import numpy as np


def morph_state(size: int, coverage: float, seed: int = 0, n_sweeps: int = 0,
                marker_kind: str = "seeded"):
    """marker_kind: "seeded" (paper Fig. 1 markers-in-objects; sparse ring
    wavefront) or "dense" (mask - h dome filling; dense wavefront)."""
    import jax.numpy as jnp
    from repro.data.images import tissue_image
    from repro.morph.ops import MorphReconstructOp
    marker, mask = tissue_image(size, size, coverage, seed)
    if marker_kind == "seeded":
        from repro.data.images import seeded_marker
        marker = seeded_marker(mask, n_seeds=max(8, size // 20), seed=seed)
    op = MorphReconstructOp(connectivity=8)
    J = jnp.asarray(marker.astype(np.int32))
    I = jnp.asarray(mask.astype(np.int32))
    if n_sweeps:
        from repro.morph.ops import fh_init
        J = fh_init(J, I, n_sweeps=n_sweeps)
    return op, op.make_state(J, I)


def edt_state(size: int, coverage: float, seed: int = 0):
    """Few concentrated background disks -> distances of O(size): the
    long-propagation regime of the paper's whole-slide images."""
    import jax.numpy as jnp
    from repro.data.images import bg_disks
    from repro.edt.ops import EdtOp
    fg = bg_disks(size, size, min(coverage, 0.97), n_disks=6, seed=seed)
    op = EdtOp(connectivity=8)
    return op, op.make_state(jnp.asarray(fg))


def fill_state(size: int, coverage: float = 0.5, seed: int = 0):
    """Blob image whose background splits into border-reachable sea plus
    enclosed holes — the fill-holes regime (border flood depth O(size))."""
    import jax.numpy as jnp
    from repro.data.images import binary_blobs
    from repro.fill.ops import FillHolesOp
    img = binary_blobs(size, size, coverage, seed)
    op = FillHolesOp()
    return op, op.make_state(jnp.asarray(img))


def label_state(size: int, coverage: float = 0.55, seed: int = 0):
    """Blob foreground with many components of mixed scales — the labeling
    regime (per-component flood depth ~ component diameter)."""
    import jax.numpy as jnp
    from repro.data.images import binary_blobs
    from repro.label.ops import LabelPropagationOp
    fg = binary_blobs(size, size, coverage, seed)
    op = LabelPropagationOp(connectivity=8)
    return op, op.make_state(jnp.asarray(fg))


def _blob_volume(size: int, seed: int = 0, scale: int = 8) -> np.ndarray:
    """Blocky random blob field in [0, 1): a low-res random volume
    upsampled by ``scale`` — cheap 3-D structure at O(size/scale) feature
    scale (no scipy, same spirit as ``binary_blobs``)."""
    rng = np.random.default_rng(seed)
    lo = rng.random((max(2, -(-size // scale)),) * 3)
    vol = lo
    for ax in range(3):
        vol = np.repeat(vol, scale, axis=ax)
    return vol[:size, :size, :size]


def morph_state3d(size: int, seed: int = 0, connectivity: str = "conn26"):
    """3-D reconstruction workload (DESIGN.md §2.7): blob intensity volume
    with sparse seeded markers — the volumetric analogue of the seeded
    2-D regime (wavefronts climb whole blobs)."""
    import jax.numpy as jnp
    from repro.morph.ops import MorphReconstructOp
    vol = _blob_volume(size, seed)
    mask = (vol * 200).astype(np.int32)
    rng = np.random.default_rng(seed + 1)
    marker = np.where(rng.random(mask.shape) < 1e-3, mask, 0).astype(np.int32)
    op = MorphReconstructOp(connectivity=connectivity)
    return op, op.make_state(jnp.asarray(marker), jnp.asarray(mask))


def edt_state3d(size: int, seed: int = 0, connectivity: str = "conn26"):
    """Few background balls in a foreground volume -> distances of
    O(size): the long-propagation regime, volumetric."""
    import jax.numpy as jnp
    from repro.edt.ops import EdtOp
    rng = np.random.default_rng(seed)
    z, y, x = np.ogrid[:size, :size, :size]
    fg = np.ones((size, size, size), bool)
    r = max(2, size // 8)
    for _ in range(4):
        c = rng.integers(0, size, 3)
        fg &= ((z - c[0]) ** 2 + (y - c[1]) ** 2 + (x - c[2]) ** 2) > r * r
    op = EdtOp(connectivity=connectivity)
    return op, op.make_state(jnp.asarray(fg))
