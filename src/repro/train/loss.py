"""LM loss.  `xent_from_hidden` never materializes the full (B, S, V) fp32
logit tensor: the sequence is scanned in chunks, each chunk's logits are
formed in compute dtype and reduced to fp32 log-probs immediately.  For the
roofline this trades nothing in FLOPs but caps the live-memory term of the
loss layer at (B, chunk, V)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import logits_from_hidden

IGNORE = -1   # label value excluded from the loss


def _chunk_xent(params, cfg, h_chunk, labels_chunk):
    logits = logits_from_hidden(params, cfg, h_chunk).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels_chunk, 0)[..., None], axis=-1)[..., 0]
    valid = labels_chunk != IGNORE
    return jnp.where(valid, lse - ll, 0.0).sum(), valid.sum()


def xent_from_hidden(params, cfg, hidden, labels, seq_chunk: int = 1024):
    """Mean cross entropy over valid tokens.  hidden: (B, S, D)."""
    B, S, D = hidden.shape
    c = min(seq_chunk, S)
    if S % c:
        c = S
    n = S // c
    hc = hidden.reshape(B, n, c, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, c).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        h, l = xs
        t, k = _chunk_xent(params, cfg, h, l)
        return (tot + t, cnt + k), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)), (hc, lc))
    return tot / jnp.maximum(cnt, 1)
