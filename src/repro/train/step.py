"""Train / serve step factories.

`make_train_step` builds the jit-able step: microbatched gradient
accumulation (scan over microbatches keeps one live activation set),
AdamW update, metrics.  `make_serve_step` builds the one-token decode step
used by the decode_* dry-run cells and the serving engine.

Both are pure (params, state, batch) -> ... functions; sharding comes from
in_shardings at jit time (launch/dryrun.py, launch/train.py).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import decode_step, forward, prefill
from repro.train.loss import xent_from_hidden
from repro.train.optim import OptConfig, adamw_update


def _stack_microbatches(batch, k: int):
    """Reshape the batch to a leading (k, ...) microbatch axis for lax.scan.

    A *static* reshape, not a dynamic slice: slicing a sharded batch dim at
    a traced offset defeats GSPMD (it replicates the whole batch on every
    data shard — a 16x compute bug caught by the HLO cost model; see
    EXPERIMENTS.md §Perf).  The split is *strided* (microbatch i takes rows
    i, i+k, i+2k, ...): reshaping (B,) -> (B/k, k) keeps each device's
    contiguous row block aligned to the leading dim, so after the transpose
    every microbatch is still sharded across the FULL data axis (a
    contiguous split would land each microbatch on 1/k of the devices).
    Gradient accumulation is permutation-invariant, so the assignment does
    not change the update.
    """
    def one(key, x):
        if key == "positions":                 # (3, B, S) -> (k, 3, B/k, S)
            B = x.shape[1]
            return x.reshape(x.shape[0], B // k, k, *x.shape[2:]) \
                    .transpose(2, 0, 1, *range(3, x.ndim + 1))
        B = x.shape[0]
        return x.reshape(B // k, k, *x.shape[1:]).swapaxes(0, 1)
    return {key: one(key, v) for key, v in batch.items()}


def _cast_params(params, dtype):
    """Cast fp32 master weights to the compute dtype BEFORE the layer scan.

    Under FSDP the per-layer weights are all-gathered at use; casting the
    stacked arrays first means the gathers move bf16, not fp32 — half the
    collective bytes (§Perf cell B).  Norm scales and other small vectors
    stay fp32 (their consumers upcast anyway).
    """
    dt = jnp.dtype(dtype)
    return jax.tree_util.tree_map(
        lambda p: p.astype(dt) if (p.dtype == jnp.float32 and p.ndim >= 2)
        else p, params)


def make_loss_fn(cfg: ArchConfig):
    def loss_fn(params, batch):
        params = _cast_params(params, cfg.dtype)
        hidden, aux = forward(params, cfg, batch)
        loss = xent_from_hidden(params, cfg, hidden, batch["labels"])
        return loss + aux, {"xent": loss, "aux": aux}
    return loss_fn


def make_train_step(cfg: ArchConfig, opt_cfg: Optional[OptConfig] = None,
                    microbatches: int = 1):
    opt_cfg = opt_cfg or OptConfig()
    loss_fn = make_loss_fn(cfg)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            stacked = _stack_microbatches(batch, microbatches)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc, (g0, jnp.float32(0.0)), stacked)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            parts = {}
        new_params, new_opt, om = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **om, **parts}
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(cfg: ArchConfig):
    """One-token decode: (params, cache, tokens, cache_len) -> (cache, logits)."""
    def serve_step(params, cache, tokens, cache_len):
        return decode_step(params, cfg, cache, tokens, cache_len)
    return serve_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return prefill(params, cfg, batch)
    return prefill_step
