"""AdamW with fp32 master params, global-norm clipping, and a linear-warmup
cosine schedule.  Optimizer state is a pytree shaped like the params, so it
inherits the params' sharding (FSDP shards optimizer state for free)."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.int32(0)}


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(cfg: OptConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(*t) for t in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
