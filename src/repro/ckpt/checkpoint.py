"""Checkpointing: per-leaf .npy files + a JSON manifest, atomic directory
rename, keep-last-k retention, and an async background writer.

Checkpoints are *mesh-agnostic*: leaves are stored as full (unsharded)
arrays keyed by their pytree path, so a restore may target a different
mesh/axis size (elastic re-shard; see ckpt/elastic.py).  Writes go to
``<dir>/step_<n>.tmp`` and are os.replace'd into place — a crash mid-write
never corrupts the latest checkpoint (restart-from-latest just skips
.tmp dirs).
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None) -> str:
    """Synchronous save.  Returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for i, (key, leaf) in enumerate(_flatten(tree).items()):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {"file": fname, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None,
            like=None) -> Tuple[int, Any, Dict]:
    """Load (step, tree, extra).  If `like` is given, the result has its
    pytree structure (leaves matched by path); otherwise a flat dict keyed
    by path is returned."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {k: np.load(os.path.join(path, v["file"]))
            for k, v in manifest["leaves"].items()}
    if like is None:
        return step, flat, manifest["extra"]
    paths_leaves, tdef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths_leaves:
        key = jax.tree_util.keystr(p)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    return step, jax.tree_util.tree_unflatten(tdef, leaves), manifest["extra"]


def retain_last_k(ckpt_dir: str, k: int):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(m.group(1)) for d in os.listdir(ckpt_dir)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-k] if k > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Background writer: `save` returns immediately; device_get happens on
    the caller thread (cheap snapshot), serialization on the worker."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, extra = item
            try:
                save(self.ckpt_dir, step, host_tree, extra)
                retain_last_k(self.ckpt_dir, self.keep_last)
            except BaseException as e:       # surfaced on wait()
                self._err = e

    def save(self, step: int, tree, extra: Optional[Dict] = None):
        host = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host, extra))

    def wait(self):
        self._q.put(None)
        self._t.join()
        if self._err is not None:
            raise self._err
