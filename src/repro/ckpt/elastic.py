"""Elastic re-sharding: restore a mesh-agnostic checkpoint under a different
mesh (grown/shrunk data axis, added pod axis).

Checkpoints store full arrays, so elasticity is just `jax.device_put` with
the new NamedSharding — plus a divisibility check that reports exactly
which leaves force replication on the new mesh (e.g. a global batch that no
longer divides the data axis).  This is the restart path after losing a
slice of the fleet: rebuild the mesh from surviving hosts, restore, go.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.ckpt.checkpoint import restore


def reshard(tree, mesh: Mesh, spec_tree) -> Any:
    """Place `tree` (host arrays) on `mesh` with `spec_tree` PartitionSpecs."""
    def one(leaf, spec):
        return jax.device_put(leaf, NamedSharding(mesh, spec))
    return jax.tree_util.tree_map(one, tree, spec_tree)


def restore_elastic(ckpt_dir: str, like, mesh: Mesh, spec_tree,
                    step=None):
    """Restore + reshard in one move.  Returns (step, sharded_tree, extra)."""
    step, tree, extra = restore(ckpt_dir, step=step, like=like)
    return step, reshard(tree, mesh, spec_tree), extra
