"""IWPP serving layer (DESIGN.md §2.9, docs/SERVING.md).

:class:`IwppService` is the multi-tenant batched front door over the
engine stack; :mod:`repro.serve.engine` holds the unrelated token-decode
``ServeEngine`` for the LM substrate (import it from its module).
"""

from repro.serve.batching import (Coalescer, PendingRequest,
                                  content_fingerprint, request_key,
                                  shape_bucket)
from repro.serve.metrics import LatencyReservoir, MetricsRecorder, ServeStats
from repro.serve.service import IwppService, Rejected

__all__ = [
    "Coalescer", "IwppService", "LatencyReservoir", "MetricsRecorder",
    "PendingRequest", "Rejected", "ServeStats", "content_fingerprint",
    "request_key", "shape_bucket",
]
