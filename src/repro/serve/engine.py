"""Slot-based serving engine (continuous-batching-lite).

A fixed pool of B slots shares one decode cache; requests are admitted into
free slots (prefill writes the slot's cache region), every engine step runs
one batched `decode_step` for all active slots with per-slot cache lengths,
and finished slots are recycled without stalling the others — the
continuous-batching idea at its smallest useful size.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.transformer import (decode_step, init_decode_cache, prefill)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    out: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, params, cfg: ArchConfig, n_slots: int = 4,
                 max_len: int = 512, temperature: float = 0.0, seed: int = 0):
        self.params, self.cfg = params, cfg
        self.B, self.max_len = n_slots, max_len
        self.temperature = temperature
        self.cache = init_decode_cache(cfg, n_slots, max_len)
        self.lens = np.zeros(n_slots, np.int32)        # valid cache length
        self.remaining = np.zeros(n_slots, np.int32)   # tokens left to emit
        self.active: Dict[int, Request] = {}           # slot -> request
        self.last_tok = np.zeros(n_slots, np.int32)
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, c, t, l: decode_step(p, cfg, c, t, l))
        self._prefill = jax.jit(lambda p, b: prefill(p, cfg, b))

    # -- admission -----------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [s for s in range(self.B) if s not in self.active]

    def add_request(self, req: Request) -> bool:
        slots = self.free_slots()
        if not slots:
            return False
        s = slots[0]
        S = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        if self.cfg.encdec is not None:
            batch["frames"] = jnp.zeros(
                (1, self.cfg.encdec.n_frames, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        pcache, logits = self._prefill(self.params, batch)
        self._write_slot(s, pcache, S)
        self.lens[s] = S
        self.remaining[s] = req.max_new
        req.out = []
        self.active[s] = req
        self.last_tok[s] = int(jnp.argmax(logits[0, -1]))
        req.out.append(int(self.last_tok[s]))
        return True

    def _write_slot(self, slot: int, pcache, S: int):
        """Copy a prefill cache (batch 1, exact length S) into slot's region.

        Prefill entries mirror the decode-cache structure; kv-like leaves
        differ in the sequence dim (S vs max_len), recurrent state leaves
        differ only in the batch dim (1 vs B).
        """
        new_cache = {}
        for gname, ent in self.cache.items():
            if gname == "enc_out":
                new_cache[gname] = ent.at[slot].set(
                    pcache[gname][0].astype(ent.dtype))
                continue
            src = pcache[gname]
            out_ent = {}
            for k, dst in ent.items():
                s_ = src[k]
                # batch axis: where dst has B and src has 1
                bax = next(i for i in range(dst.ndim)
                           if dst.shape[i] == self.B and s_.shape[i] == 1)
                idx = [slice(None)] * dst.ndim
                idx[bax] = slice(slot, slot + 1)
                if k in ("k", "v", "c_kv", "k_pe"):   # seq dim follows batch
                    idx[bax + 1] = slice(0, s_.shape[bax + 1])
                out_ent[k] = dst.at[tuple(idx)].set(s_.astype(dst.dtype))
            new_cache[gname] = out_ent
        self.cache = new_cache

    # -- one decode step for all active slots ---------------------------------
    def step(self) -> List[Request]:
        if not self.active:
            return []
        toks = jnp.asarray(self.last_tok, jnp.int32)
        lens = jnp.asarray(self.lens, jnp.int32)
        self.cache, logits = self._decode(self.params, self.cache, toks, lens)
        if self.temperature > 0:
            self.key, k = jax.random.split(self.key)
            nxt = jax.random.categorical(k, logits / self.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = np.asarray(nxt, np.int32)
        done = []
        for s in list(self.active):
            self.lens[s] += 1
            self.remaining[s] -= 1
            self.last_tok[s] = nxt[s]
            self.active[s].out.append(int(nxt[s]))
            full = self.lens[s] >= self.max_len - 1
            if self.remaining[s] <= 0 or full:
                done.append(self.active.pop(s))
        return done

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.active:
                return
            self.step()
