"""Observability surface of the IWPP serving layer (DESIGN.md §2.9).

One thread-safe :class:`MetricsRecorder` collects every counter the service
mutates on its hot paths (submissions, admissions, cache traffic, batch
sizes, per-request latency), and :meth:`MetricsRecorder.snapshot` freezes
them into an immutable :class:`ServeStats` — the record docs/SERVING.md
defines the SLO metrics against and ``benchmarks/bench_serve.py`` reports.

Latency is measured submit-to-result on the monotonic clock and kept in a
bounded reservoir (newest-wins ring), so percentile queries stay O(cap log
cap) and memory stays flat under sustained load.  Percentiles use the
nearest-rank method: ``p99`` is the smallest observed latency ≥ 99% of the
sample — never an interpolated value that no request actually experienced.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional


class LatencyReservoir:
    """Bounded sample of request latencies (seconds), newest-wins ring."""

    def __init__(self, capacity: int = 8192):
        if capacity <= 0:
            raise ValueError("reservoir capacity must be >= 1")
        self.capacity = capacity
        self._ring = [0.0] * capacity
        self._n = 0          # total ever recorded

    def record(self, latency_s: float) -> None:
        self._ring[self._n % self.capacity] = float(latency_s)
        self._n += 1

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the retained sample (0 if empty)."""
        n = len(self)
        if n == 0:
            return 0.0
        if not 0.0 <= p <= 100.0:
            raise ValueError(f"percentile must be in [0, 100], got {p}")
        ordered = sorted(self._ring[:n])
        rank = max(1, -(-int(p * n) // 100))      # ceil(p/100 * n), >= 1
        return ordered[min(rank, n) - 1]


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """Frozen service-level SLO snapshot (docs/SERVING.md #slo-metrics).

    Counter semantics: ``submitted`` counts every ``submit()`` that was not
    rejected (cache hits included); ``rejected`` counts admission-control
    refusals (they never enter the queue, so they appear in no other
    counter); ``completed``/``failed`` partition the finished requests.
    ``cache_hits`` includes in-flight single-flight joins — a request that
    attached to an identical pending request never cost a solve, which is
    what the hit rate is meant to capture.
    """

    submitted: int = 0
    completed: int = 0
    failed: int = 0
    rejected: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    batches: int = 0                               # coalesced solves issued
    batch_size_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    queue_depth: int = 0                           # pending, not yet claimed
    inflight: int = 0                              # claimed, not yet resolved
    uptime_s: float = 0.0
    requests_per_sec: float = 0.0                  # completed / uptime
    latency_p50_s: float = 0.0
    latency_p95_s: float = 0.0
    latency_p99_s: float = 0.0
    latency_count: int = 0                         # reservoir sample size

    @property
    def cache_hit_rate(self) -> float:
        looked = self.cache_hits + self.cache_misses
        return self.cache_hits / looked if looked else 0.0

    @property
    def mean_batch_size(self) -> float:
        n = sum(self.batch_size_hist.values())
        total = sum(k * v for k, v in self.batch_size_hist.items())
        return total / n if n else 0.0


class MetricsRecorder:
    """The mutable side of :class:`ServeStats`; every method is
    thread-safe (one lock — the service's hot path is dominated by solves,
    not counter updates)."""

    def __init__(self, reservoir_capacity: int = 8192,
                 clock=time.monotonic):
        self._lock = threading.Lock()
        self._clock = clock
        self._t0 = clock()
        self._latency = LatencyReservoir(reservoir_capacity)
        self._counts = {k: 0 for k in
                        ("submitted", "completed", "failed", "rejected",
                         "cache_hits", "cache_misses", "batches")}
        self._batch_hist: Dict[int, int] = {}
        # EWMA of seconds of service time per completed request — the
        # admission controller's retry-after estimator.
        self._ewma_request_s: Optional[float] = None

    def count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counts[name] += n

    def record_batch(self, size: int, wall_s: float) -> None:
        with self._lock:
            self._counts["batches"] += 1
            self._batch_hist[size] = self._batch_hist.get(size, 0) + 1
            per_req = wall_s / max(1, size)
            self._ewma_request_s = (
                per_req if self._ewma_request_s is None
                else 0.7 * self._ewma_request_s + 0.3 * per_req)

    def record_latency(self, latency_s: float) -> None:
        with self._lock:
            self._latency.record(latency_s)

    def ewma_request_s(self, default: float = 0.05) -> float:
        """Recent seconds of service time per request (retry-after unit)."""
        with self._lock:
            return (self._ewma_request_s
                    if self._ewma_request_s is not None else default)

    def snapshot(self, queue_depth: int = 0, inflight: int = 0) -> ServeStats:
        with self._lock:
            uptime = max(self._clock() - self._t0, 1e-9)
            return ServeStats(
                queue_depth=queue_depth, inflight=inflight,
                uptime_s=uptime,
                requests_per_sec=self._counts["completed"] / uptime,
                latency_p50_s=self._latency.percentile(50),
                latency_p95_s=self._latency.percentile(95),
                latency_p99_s=self._latency.percentile(99),
                latency_count=len(self._latency),
                batch_size_hist=dict(self._batch_hist),
                **self._counts)
