"""Multi-tenant batched IWPP serving front door (DESIGN.md §2.9,
docs/SERVING.md).

``IwppService`` turns a stream of independent ``submit(op_name, inputs)``
requests into saturated batched solves — the ROADMAP's "millions of users"
front door over the whole engine stack:

* **Async queue + futures** — ``submit`` returns a
  ``concurrent.futures.Future`` immediately; one daemon drain thread
  claims batches and resolves them.
* **Coalescing** — compatible pending requests (same op, bucketed spatial
  shape, dtypes, connectivity, engine signature —
  :func:`repro.serve.batching.request_key`) ride ONE
  :func:`repro.solve.solve_batch` call; near-miss shapes join a batch via
  the pad-to-bucket policy (state-level neutral padding, bit-identical
  results after crop).
* **Engine selection per batch** — ``engine="auto"`` ranks candidates with
  :func:`repro.solve.default_cost_model` (the calibrated profile when one
  is installed, DESIGN.md §2.8); the autotune process + disk caches are
  shared across requests, so one tenant's measured winner serves every
  later tenant of the same signature.
* **Result cache + single-flight** — finalized results are cached
  content-addressed (:func:`repro.serve.batching.content_fingerprint`);
  an identical in-flight request attaches to the pending future instead of
  solving twice.
* **Admission control** — bounded queue depth and per-tenant in-flight
  caps; over-limit submits raise :class:`Rejected` carrying a
  ``retry_after_s`` backoff hint instead of growing memory without bound.
* **Observability** — :meth:`IwppService.stats` returns a
  :class:`~repro.serve.metrics.ServeStats` snapshot (requests/sec, batch
  histogram, cache hit rate, queue depth, p50/p95/p99 latency).

The token-decode :class:`~repro.serve.engine.ServeEngine` (the LM
substrate's continuous-batching slot pool) lives beside this module and is
unrelated plumbing.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.ops import get_op
from repro.serve.batching import (Coalescer, PendingRequest, content_fingerprint,
                                  crop_state, padded_state, request_key)
from repro.serve.metrics import MetricsRecorder, ServeStats


class Rejected(RuntimeError):
    """Admission-control refusal (backpressure, never silent queue growth).

    ``retry_after_s`` is the service's backoff hint: roughly the time the
    current backlog needs to drain at the recent per-request service rate.
    """

    def __init__(self, reason: str, retry_after_s: float):
        super().__init__(f"{reason}; retry after ~{retry_after_s:.3f}s")
        self.reason = reason
        self.retry_after_s = retry_after_s


class IwppService:
    """The batched multi-tenant ``solve()`` service (module docstring).

    Parameters
    ----------
    engine, interpret, autotune, cost_model, **solve_kw :
        forwarded to :func:`repro.solve.solve_batch` for every batch —
        ``engine="auto"`` (default) re-ranks per batch with
        :func:`~repro.solve.default_cost_model`; ``solve_kw`` takes the
        per-engine knobs (``tile``, ``drain_batch``, ...).
    max_batch : most requests coalesced into one solve.
    batch_window_s : how long the drain thread holds an under-full batch
        open for compatible followers (0 = drain immediately).
    max_queue_depth : pending-request bound; past it ``submit`` raises
        :class:`Rejected`.
    max_inflight_per_tenant : per-tenant cap on submitted-but-unresolved
        requests (single-flight joins and cache hits are free).
    cache_capacity : content-addressed result cache entries (LRU; 0
        disables caching *and* single-flight dedup).
    bucket_multiple : pad-to-bucket granularity for coalescing near-miss
        shapes (1 = exact-shape grouping only).
    start : spawn the drain thread now; ``start=False`` lets tests and
        benches queue a deterministic backlog first, then call
        :meth:`start`.
    """

    def __init__(self, *, engine: str = "auto", interpret: bool = True,
                 autotune: bool = False, cost_model=None,
                 max_batch: int = 8, batch_window_s: float = 0.002,
                 max_queue_depth: int = 64,
                 max_inflight_per_tenant: int = 16,
                 cache_capacity: int = 128, bucket_multiple: int = 64,
                 metrics: Optional[MetricsRecorder] = None,
                 start: bool = True, **solve_kw):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1, got {max_queue_depth}")
        self._engine = engine
        self._interpret = interpret
        self._autotune = autotune
        self._cost_model = cost_model
        self._solve_kw = dict(solve_kw)
        self.max_batch = max_batch
        self.batch_window_s = batch_window_s
        self.max_queue_depth = max_queue_depth
        self.max_inflight_per_tenant = max_inflight_per_tenant
        self.bucket_multiple = bucket_multiple
        self.metrics = metrics if metrics is not None else MetricsRecorder()
        # Engine signature: part of the coalescing key so batches formed
        # under one config can never be replayed under another (matters
        # once per-request overrides exist; today it is service-constant).
        self._engine_sig = (engine, interpret, autotune,
                            tuple(sorted(self._solve_kw.items())))

        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._coalescer = Coalescer()
        self._cache: "Dict[str, Any]" = {}        # fingerprint -> result
        self._cache_lru: List[str] = []
        self.cache_capacity = cache_capacity
        # fingerprint -> primary PendingRequest with live joiner list
        self._inflight_by_fp: Dict[str, PendingRequest] = {}
        self._joiners: Dict[int, List[float]] = {}   # rid -> join t_submits
        self._tenant_inflight: Dict[str, int] = {}
        self._tenant_of: Dict[int, str] = {}
        self._inflight = 0
        self._rid = 0
        self._closing = False
        # Test hook (tests/test_serve.py failure injection): a predicate
        # over the claimed batch; True makes the batch solve raise, which
        # must reject only that batch's futures and keep the queue
        # draining.
        self.fail_injector: Optional[
            Callable[[List[PendingRequest]], bool]] = None

        self._thread: Optional[threading.Thread] = None
        if start:
            self.start()

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "IwppService":
        with self._lock:
            if self._closing:
                raise RuntimeError("service is closed")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._drain_loop, name="iwpp-serve", daemon=True)
                self._thread.start()
        return self

    def close(self, drain: bool = True) -> None:
        """Stop the service.  ``drain=True`` (default) serves every pending
        request first; ``drain=False`` rejects them with :class:`Rejected`.
        """
        if drain:
            with self._lock:
                need_start = (self._thread is None and not self._closing
                              and len(self._coalescer) > 0)
            if need_start:
                self.start()           # never-started service with a backlog
        with self._cond:
            self._closing = True
            if not drain:
                for req in self._coalescer.take_batch(10 ** 9):
                    self._resolve_failure(
                        [req], Rejected("service closed", 0.0))
                # keep draining whatever is already claimed
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()

    def __enter__(self) -> "IwppService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission --------------------------------------------------------
    def submit(self, op_name: str, inputs, *,
               connectivity: Optional[Union[int, str]] = None,
               tenant: str = "default") -> Future:
        """Queue one request; returns a Future resolving to the op's
        *finalized* result (``OpSpec.finalize`` semantics, the same array
        :func:`repro.ops.run_op` returns).

        ``inputs`` is the op's natural raw input(s) — an array, or a tuple
        of arrays for multi-input ops (morph: ``(marker, mask)``); the
        first input's shape is the request's spatial shape.  Raises
        :class:`Rejected` when admission control refuses (full queue /
        tenant cap), ``ValueError`` for an unknown op.
        """
        get_op(op_name)                       # unknown op: raise before queue
        inputs = inputs if isinstance(inputs, tuple) else (inputs,)
        inputs = tuple(np.asarray(x) for x in inputs)
        fp = content_fingerprint(op_name, inputs, connectivity)
        key = request_key(op_name, inputs[0].shape,
                          [str(x.dtype) for x in inputs], connectivity,
                          self._engine_sig, self.bucket_multiple)
        now = time.monotonic()
        with self._cond:
            if self._closing:
                raise RuntimeError("service is closed")
            hit = self._cache_get(fp)
            if hit is not None:
                self.metrics.count("submitted")
                self.metrics.count("cache_hits")
                self.metrics.count("completed")
                self.metrics.record_latency(time.monotonic() - now)
                fut: Future = Future()
                fut.set_result(hit)
                return fut
            primary = self._inflight_by_fp.get(fp)
            if primary is not None:
                # Single-flight: identical request already queued/solving —
                # share its future, count as a cache hit (it costs nothing).
                self.metrics.count("submitted")
                self.metrics.count("cache_hits")
                self._joiners[primary.rid].append(now)
                return primary.future
            # -- admission control ----------------------------------------
            if len(self._coalescer) >= self.max_queue_depth:
                self.metrics.count("rejected")
                raise Rejected(
                    f"queue full ({len(self._coalescer)} pending >= "
                    f"max_queue_depth={self.max_queue_depth})",
                    self._retry_after())
            if (self._tenant_inflight.get(tenant, 0)
                    >= self.max_inflight_per_tenant):
                self.metrics.count("rejected")
                raise Rejected(
                    f"tenant {tenant!r} at max_inflight_per_tenant="
                    f"{self.max_inflight_per_tenant}", self._retry_after())
            self._rid += 1
            req = PendingRequest(rid=self._rid, op_name=op_name,
                                 inputs=inputs, connectivity=connectivity,
                                 tenant=tenant, key=key, fingerprint=fp,
                                 future=Future(), t_submit=now)
            self._coalescer.push(req)
            if self.cache_capacity > 0:
                self._inflight_by_fp[fp] = req
            self._joiners[req.rid] = []
            self._tenant_inflight[tenant] = \
                self._tenant_inflight.get(tenant, 0) + 1
            self._tenant_of[req.rid] = tenant
            self.metrics.count("submitted")
            self.metrics.count("cache_misses")
            self._cond.notify_all()
            return req.future

    def _retry_after(self) -> float:
        backlog = len(self._coalescer) + self._inflight + 1
        return max(1e-3, self.metrics.ewma_request_s()
                   * backlog / max(1, self.max_batch))

    # -- result cache ------------------------------------------------------
    def _cache_get(self, fp: str):
        val = self._cache.get(fp)
        if val is not None:
            self._cache_lru.remove(fp)
            self._cache_lru.append(fp)
        return val

    def _cache_put(self, fp: str, val) -> None:
        if self.cache_capacity <= 0:
            return
        if fp not in self._cache:
            self._cache_lru.append(fp)
        self._cache[fp] = val
        while len(self._cache_lru) > self.cache_capacity:
            evict = self._cache_lru.pop(0)
            del self._cache[evict]

    # -- drain loop --------------------------------------------------------
    def _drain_loop(self) -> None:
        while True:
            with self._cond:
                while not self._closing and len(self._coalescer) == 0:
                    self._cond.wait()
                if self._closing and len(self._coalescer) == 0:
                    return
                head = self._coalescer.peek_oldest()
                if (self.batch_window_s > 0
                        and self._coalescer.compatible_pending(head.key)
                        < self.max_batch):
                    # Hold the batch open one window for compatible
                    # followers (re-checked once; bounded added latency).
                    self._cond.wait(self.batch_window_s)
                batch = self._coalescer.take_batch(self.max_batch)
                self._inflight += len(batch)
            if batch:
                self._execute(batch)

    def _execute(self, batch: List[PendingRequest]) -> None:
        import jax.numpy as jnp
        from repro.solve import solve_batch
        t0 = time.monotonic()
        try:
            if self.fail_injector is not None and self.fail_injector(batch):
                raise RuntimeError("injected batch failure (serve test hook)")
            spec = get_op(batch[0].op_name)
            op = spec.make_op(batch[0].connectivity)
            target = batch[0].key[1]          # the bucketed spatial shape
            states, origs = [], []
            for r in batch:
                st = spec.build_state(op, *(jnp.asarray(x) for x in r.inputs))
                p, orig = padded_state(op, st, target)
                states.append(p)
                origs.append(orig)
            results = solve_batch(op, states, engine=self._engine,
                                  interpret=self._interpret,
                                  autotune=self._autotune,
                                  cost_model=self._cost_model,
                                  **self._solve_kw)
        except BaseException as e:  # noqa: BLE001 — isolate to this batch
            self._resolve_failure(batch, e)
            return
        wall = time.monotonic() - t0
        self.metrics.record_batch(len(batch), wall)
        now = time.monotonic()
        with self._cond:
            for r, orig, (out, _st) in zip(batch, origs, results):
                res = spec.extract(op, crop_state(out, orig))
                self._cache_put(r.fingerprint, res)
                joins = self._release(r)
                self.metrics.count("completed", 1 + len(joins))
                self.metrics.record_latency(now - r.t_submit)
                for tj in joins:
                    self.metrics.record_latency(now - tj)
                r.future.set_result(res)

    def _resolve_failure(self, batch: List[PendingRequest],
                         exc: BaseException) -> None:
        """Reject exactly this batch's futures; the queue keeps draining."""
        with self._cond:
            for r in batch:
                joins = self._release(r)
                self.metrics.count("failed", 1 + len(joins))
                if not r.future.done():
                    r.future.set_exception(exc)

    def _release(self, r: PendingRequest) -> List[float]:
        """Drop one claimed request's accounting; returns joiner stamps."""
        self._inflight = max(0, self._inflight - 1)
        tenant = self._tenant_of.pop(r.rid, None)
        if tenant is not None:
            left = self._tenant_inflight.get(tenant, 1) - 1
            if left > 0:
                self._tenant_inflight[tenant] = left
            else:
                self._tenant_inflight.pop(tenant, None)
        if self._inflight_by_fp.get(r.fingerprint) is r:
            del self._inflight_by_fp[r.fingerprint]
        return self._joiners.pop(r.rid, [])

    # -- observability -----------------------------------------------------
    def stats(self) -> ServeStats:
        with self._lock:
            return self.metrics.snapshot(queue_depth=len(self._coalescer),
                                         inflight=self._inflight)
