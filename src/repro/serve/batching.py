"""Request coalescing for the IWPP serving layer (DESIGN.md §2.9).

The throughput story of the paper's motivating deployment — many
independent slide-analysis requests sharing one hybrid machine — is
batching: compatible requests must ride one solve so devices stay
saturated.  This module owns the *grouping* half of that story:

* :func:`request_key` — the compatibility signature.  Two requests
  coalesce iff they share ``(op, bucketed spatial shape, input dtypes,
  connectivity, engine signature)``; anything else would either change
  results (different op/connectivity), fail to stack (different
  shape/dtype), or solve under the wrong engine config.
* :func:`shape_bucket` — the pad-to-bucket policy for near-miss shapes:
  each spatial axis rounds up to the next multiple of
  ``bucket_multiple``, so a 1000×1010 request shares a batch with a
  1024×1024 one instead of stranding alone.  Padding happens at the
  *state* level with the op's neutral values (:func:`padded_state`), so
  padded cells are invalid, can never source a propagation, and the
  cropped result is bit-identical to the unpadded solo solve — the same
  invariant the tiled engines' grid padding rests on.
* :class:`Coalescer` — the pending queue: FIFO across keys (the oldest
  request always leads the next batch), with up to ``max_batch - 1``
  compatible followers pulled out of arrival order.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np


def canonical_connectivity(connectivity: Optional[Union[int, str]]) -> str:
    """Canonical neighborhood name for a request's connectivity knob
    (``""`` = the op's own default, which is part of the op identity)."""
    if connectivity is None:
        return ""
    from repro.core.geometry import connectivity_name
    return connectivity_name(connectivity)


def content_fingerprint(op_name: str, inputs: Sequence[Any],
                        connectivity: Optional[Union[int, str]] = None) -> str:
    """Content address of one request: sha256 over the op name, canonical
    connectivity, and every input's shape/dtype/bytes.

    Two requests with equal fingerprints ask for the same deterministic
    fixed point, so the result cache and the in-flight single-flight
    dedup key on this.  The *finalized* result is what gets cached —
    engine-independent for every registered op (even EDT, whose Voronoi
    pointers may tie-differ per engine, finalizes to the unique distance
    map — paper §3.4).
    """
    h = hashlib.sha256()
    h.update(op_name.encode())
    h.update(b"\x00")
    h.update(canonical_connectivity(connectivity).encode())
    for x in inputs:
        a = np.ascontiguousarray(np.asarray(x))
        h.update(b"\x00")
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def shape_bucket(spatial: Sequence[int], bucket_multiple: int) -> Tuple[int, ...]:
    """Round each spatial axis up to the next ``bucket_multiple`` — the
    pad-to-bucket policy (``1`` = exact-shape grouping only)."""
    if bucket_multiple < 1:
        raise ValueError(f"bucket_multiple must be >= 1, got {bucket_multiple}")
    return tuple(-(-s // bucket_multiple) * bucket_multiple for s in spatial)


def request_key(op_name: str, spatial: Sequence[int],
                dtypes: Sequence[str],
                connectivity: Optional[Union[int, str]],
                engine_sig: tuple, bucket_multiple: int) -> tuple:
    """The coalescing compatibility key (see module docstring)."""
    return (op_name, shape_bucket(spatial, bucket_multiple), tuple(dtypes),
            canonical_connectivity(connectivity), engine_sig)


def padded_state(op, state, target_spatial: Sequence[int]):
    """State padded to the bucket target with neutral/invalid fill;
    returns ``(padded, orig_spatial)`` (delegates to
    :func:`repro.solve.pad_state_to`)."""
    from repro.solve import pad_state_to
    return pad_state_to(op, state, target_spatial)


def crop_state(state, orig_spatial: Sequence[int]):
    """Undo :func:`padded_state` on a result state."""
    idx = (Ellipsis,) + tuple(slice(0, s) for s in orig_spatial)
    import jax
    return jax.tree_util.tree_map(lambda x: x[idx], state)


@dataclasses.dataclass
class PendingRequest:
    """One queued request (the service fills every field at submit)."""

    rid: int
    op_name: str
    inputs: tuple
    connectivity: Optional[Union[int, str]]
    tenant: str
    key: tuple                     # request_key(...) compatibility signature
    fingerprint: str               # content_fingerprint(...)
    future: Any                    # concurrent.futures.Future
    t_submit: float                # monotonic submit timestamp


class Coalescer:
    """FIFO pending queue with compatibility-keyed batch extraction.

    ``push`` appends; ``take_batch`` pops the oldest request and up to
    ``max_batch - 1`` later requests sharing its key (relative order
    preserved).  Not thread-safe on its own — the service serializes
    access under its lock.
    """

    def __init__(self):
        self._pending: "OrderedDict[int, PendingRequest]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._pending)

    def push(self, req: PendingRequest) -> None:
        self._pending[req.rid] = req

    def peek_oldest(self) -> Optional[PendingRequest]:
        return next(iter(self._pending.values()), None)

    def compatible_pending(self, key: tuple) -> int:
        return sum(1 for r in self._pending.values() if r.key == key)

    def take_batch(self, max_batch: int) -> List[PendingRequest]:
        """Extract the next batch (empty list when nothing is pending)."""
        if not self._pending:
            return []
        head = self.peek_oldest()
        batch = []
        for rid in [r.rid for r in self._pending.values()
                    if r.key == head.key][:max(1, max_batch)]:
            batch.append(self._pending.pop(rid))
        return batch
