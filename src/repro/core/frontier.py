"""Dense-round IWPP engines (E0 `sweep`, E1 `frontier`).

E0 recomputes every pixel each round — the analogue of the raster-sweep
baselines (SR_GPU) and of a queue-less formulation.
E1 tracks the wavefront as a boolean plane: only frontier pixels act as
propagation sources, which is the paper's queue semantics expressed as a
mask.  Both run under one `lax.while_loop` to the fixed point.

Both also report *work counters* (rounds, source-pixels processed) so the
benchmarks can reproduce the paper's queue-size/work analysis (Table 1)
without GPU timers.  The source counter is an exact 64-bit total kept as a
(lo, hi) pair of uint32 words — float32 (the obvious x64-off fallback)
silently rounds past 2^24 sources, which a long run on a large grid reaches
easily.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.pattern import PropagationOp, restore_invalid


def accumulate_u64(lo: jnp.ndarray, hi: jnp.ndarray,
                   n: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact 64-bit accumulate in two uint32 words (x64-off safe).

    ``n`` must be < 2^32 (one round can at most touch every pixel); uint32
    addition wraps mod 2^32, and a wrapped sum is detectable as lo' < lo.
    """
    n = n.astype(jnp.uint32)
    new_lo = lo + n
    new_hi = hi + (new_lo < lo).astype(jnp.uint32)
    return new_lo, new_hi


class RunStats(NamedTuple):
    rounds: jnp.ndarray       # int32
    sources_lo: jnp.ndarray   # uint32 — low word of the exact source count
    sources_hi: jnp.ndarray   # uint32 — high word

    @property
    def sources_processed(self) -> int:
        """Exact total frontier pixels acted on (host-side int)."""
        return (int(self.sources_hi) << 32) | int(self.sources_lo)


@partial(jax.jit, static_argnums=(0, 2, 3))
def run_dense(op: PropagationOp, state, engine: str = "frontier",
              max_rounds: int = 1_000_000):
    """Run `op` to its fixed point with dense rounds.

    engine: "frontier" (E1) or "sweep" (E0: frontier forced to all-valid
    every round, i.e. zero wavefront tracking).
    Returns (state, RunStats).
    """
    frontier0 = op.init_frontier(state)
    stats0 = RunStats(jnp.int32(0), jnp.uint32(0), jnp.uint32(0))

    def cond(carry):
        _, frontier, stats = carry
        return jnp.any(frontier) & (stats.rounds < max_rounds)

    def body(carry):
        state, frontier, stats = carry
        if engine == "sweep":
            # E0: ignore tracking; every valid pixel is a source.
            frontier = state["valid"]
        n_src = jnp.sum(frontier, dtype=jnp.uint32)
        state, new_frontier = op.round(state, frontier)
        lo, hi = accumulate_u64(stats.sources_lo, stats.sources_hi, n_src)
        stats = RunStats(stats.rounds + 1, lo, hi)
        if engine == "sweep":
            # Terminate on no-change rather than frontier emptiness.
            new_frontier = jnp.broadcast_to(jnp.any(new_frontier), new_frontier.shape) & state["valid"]
        return state, new_frontier, stats

    out, _, stats = jax.lax.while_loop(cond, body, (state, frontier0, stats0))
    # Engine output contract: invalid cells hold their input values (the
    # dense rounds can grow an invalid *receiver* one step toward the mask).
    return restore_invalid(op, state, out), stats


def run_to_stability(op: PropagationOp, state, max_rounds: int = 1_000_000):
    """Non-jit convenience wrapper (engine E1)."""
    return run_dense(op, state, "frontier", max_rounds)
