"""Dense-round IWPP engines (E0 `sweep`, E1 `frontier`).

E0 recomputes every pixel each round — the analogue of the raster-sweep
baselines (SR_GPU) and of a queue-less formulation.
E1 tracks the wavefront as a boolean plane: only frontier pixels act as
propagation sources, which is the paper's queue semantics expressed as a
mask.  Both run under one `lax.while_loop` to the fixed point.

Both also report *work counters* (rounds, source-pixels processed) so the
benchmarks can reproduce the paper's queue-size/work analysis (Table 1)
without GPU timers.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.pattern import PropagationOp


class RunStats(NamedTuple):
    rounds: jnp.ndarray          # int32
    sources_processed: jnp.ndarray  # int64-ish float to avoid overflow


@partial(jax.jit, static_argnums=(0, 2, 3))
def run_dense(op: PropagationOp, state, engine: str = "frontier",
              max_rounds: int = 1_000_000):
    """Run `op` to its fixed point with dense rounds.

    engine: "frontier" (E1) or "sweep" (E0: frontier forced to all-valid
    every round, i.e. zero wavefront tracking).
    Returns (state, RunStats).
    """
    frontier0 = op.init_frontier(state)
    stats0 = RunStats(jnp.int32(0), jnp.float64(0.0) if jax.config.jax_enable_x64
                      else jnp.float32(0.0))

    def cond(carry):
        _, frontier, stats = carry
        return jnp.any(frontier) & (stats.rounds < max_rounds)

    def body(carry):
        state, frontier, stats = carry
        if engine == "sweep":
            # E0: ignore tracking; every valid pixel is a source.
            frontier = state["valid"]
        n_src = jnp.sum(frontier).astype(stats.sources_processed.dtype)
        state, new_frontier = op.round(state, frontier)
        stats = RunStats(stats.rounds + 1, stats.sources_processed + n_src)
        if engine == "sweep":
            # Terminate on no-change rather than frontier emptiness.
            new_frontier = jnp.broadcast_to(jnp.any(new_frontier), new_frontier.shape) & state["valid"]
        return state, new_frontier, stats

    state, _, stats = jax.lax.while_loop(cond, body, (state, frontier0, stats0))
    return state, stats


def run_to_stability(op: PropagationOp, state, max_rounds: int = 1_000_000):
    """Non-jit convenience wrapper (engine E1)."""
    return run_dense(op, state, "frontier", max_rounds)
