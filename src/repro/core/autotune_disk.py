"""Disk persistence for autotune winners (DESIGN.md §2.6).

The in-process ``_AUTOTUNE_CACHE`` dies with the interpreter, so every new
process re-pays the micro-benchmark sweep (seconds per (op, shape) pair) even
when nothing changed.  This module persists winners to one JSON file —
``~/.cache/repro-iwpp/autotune.json`` by default, ``$REPRO_IWPP_CACHE_DIR``
to relocate — keyed by everything that can change the answer:

  * the accelerator (``jax.devices()[0]`` platform + device kind),
  * the op class name,
  * the input signature (:func:`repro.solve.autotune_signature`),
  * a code version: a hash over the engine/kernel sources, so ANY edit to
    the propagation code orphans every stale winner at once instead of
    trusting callers to remember a manual bump.

Entries are plain dicts (the ``EngineConfig`` fields + measured seconds);
writes go through a same-directory temp file + ``os.replace`` so a crashed
writer can never leave a torn JSON behind.  Concurrent writers last-win per
whole file, which is acceptable for a cache: the loser's entries get re-
measured next run.  All I/O failures degrade to "no disk cache" — a
read-only HOME must never break a solve.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

_SCHEMA = 1

# Hash these sources into the key: an edit to any engine/kernel layer can
# flip which candidate wins, so it must orphan the persisted winners.
_VERSIONED_SOURCES = (
    "solve.py",
    os.path.join("core", "tiles.py"),
    os.path.join("core", "distributed.py"),
    os.path.join("core", "scheduler.py"),
    os.path.join("kernels", "queue.py"),
    os.path.join("kernels", "morph_tile.py"),
    os.path.join("kernels", "edt_tile.py"),
    os.path.join("kernels", "ops.py"),
)

_code_version_memo: Optional[str] = None


def cache_dir() -> str:
    env = os.environ.get("REPRO_IWPP_CACHE_DIR")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-iwpp")


def cache_path() -> str:
    return os.path.join(cache_dir(), "autotune.json")


def code_version() -> str:
    """Short digest of the engine/kernel sources (memoized per process)."""
    global _code_version_memo
    if _code_version_memo is None:
        pkg = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        h = hashlib.sha256()
        for rel in _VERSIONED_SOURCES:
            path = os.path.join(pkg, rel)
            try:
                with open(path, "rb") as f:
                    h.update(f.read())
            except OSError:
                h.update(rel.encode())       # missing file still keys stably
        _code_version_memo = h.hexdigest()[:16]
    return _code_version_memo


def _device_kind() -> str:
    try:
        import jax
        d = jax.devices()[0]
        return f"{d.platform}/{getattr(d, 'device_kind', '?')}"
    except Exception:
        return "unknown"


def entry_key(op_name: str, signature: tuple) -> str:
    """The flat JSON key: device kind + op name + signature + code version.

    ``signature`` is the :func:`repro.solve.autotune_signature` tuple (its
    position 0 repeats ``op_name``; keeping the explicit field makes
    :func:`invalidate_op` robust to signature-layout changes).
    """
    return "|".join((_device_kind(), op_name, repr(signature), code_version()))


def _load_raw() -> Dict[str, Any]:
    try:
        with open(cache_path()) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(data, dict) or data.get("schema") != _SCHEMA:
        return {}
    entries = data.get("entries")
    return entries if isinstance(entries, dict) else {}


def _store_raw(entries: Dict[str, Any]) -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   prefix=".autotune-", suffix=".json")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"schema": _SCHEMA, "entries": entries}, f, indent=2)
            os.replace(tmp, path)            # atomic on POSIX
        except BaseException:
            os.unlink(tmp)
            raise
    except OSError:
        pass                                  # read-only FS: stay in-memory


def load(op_name: str, signature: tuple,
         config_cls) -> Optional[Tuple[Any, float]]:
    """Return ``(EngineConfig, seconds)`` for a persisted winner, else None."""
    entry = _load_raw().get(entry_key(op_name, signature))
    if not isinstance(entry, dict):
        return None
    cfg_dict = entry.get("config")
    seconds = entry.get("seconds")
    if not isinstance(cfg_dict, dict) or not isinstance(seconds, (int, float)):
        return None
    fields = {f.name for f in dataclasses.fields(config_cls)}
    if not set(cfg_dict) <= fields or "engine" not in cfg_dict:
        return None                           # written by a different version
    try:
        return config_cls(**cfg_dict), float(seconds)
    except TypeError:
        return None


def store(op_name: str, signature: tuple, config, seconds: float) -> None:
    """Persist one measured winner (read-modify-write of the whole file)."""
    entries = _load_raw()
    entries[entry_key(op_name, signature)] = {
        "op": op_name,
        "config": dataclasses.asdict(config),
        "seconds": seconds,
    }
    _store_raw(entries)


def invalidate_op(op_names) -> int:
    """Drop every persisted entry for the named ops (spec-change hook).

    Matches on the entry's recorded ``op`` field, so it catches entries
    written under older code versions too — a re-registered solver must not
    resurface through ANY stale winner.  Returns the number dropped.
    """
    names = set(op_names)
    entries = _load_raw()
    doomed = [k for k, v in entries.items()
              if isinstance(v, dict) and v.get("op") in names]
    if not doomed:
        return 0
    for k in doomed:
        del entries[k]
    _store_raw(entries)
    return len(doomed)


def clear() -> None:
    try:
        os.unlink(cache_path())
    except OSError:
        pass
