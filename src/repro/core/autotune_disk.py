"""Disk persistence for autotune winners and calibration profiles
(DESIGN.md §2.6/§2.8).

The in-process ``_AUTOTUNE_CACHE`` dies with the interpreter, so every new
process re-pays the micro-benchmark sweep (seconds per (op, shape) pair) even
when nothing changed.  This module persists winners to one JSON file —
``~/.cache/repro-iwpp/autotune.json`` by default, ``$REPRO_IWPP_CACHE_DIR``
to relocate — keyed by everything that can change the answer:

  * the accelerator (``jax.devices()[0]`` platform + device kind),
  * the op class name,
  * the input signature (:func:`repro.solve.autotune_signature`),
  * a code version: a hash over the engine/kernel sources, so ANY edit to
    the propagation code orphans every stale winner at once instead of
    trusting callers to remember a manual bump.

The same file carries a second section, ``profiles``: the measured
calibration profiles behind :class:`repro.solve.MeasuredCostModel`
(DESIGN.md §2.8), keyed by (device kind, code version) only — a profile is
per-machine, not per-input.

Entries are plain dicts (the ``EngineConfig`` fields + measured seconds);
writes go through a same-directory temp file + ``os.replace`` so a crashed
writer can never leave a torn JSON behind, and every read-modify-write holds
an ``fcntl`` lock on a sidecar ``.lock`` file so two concurrent writers
serialize instead of silently dropping each other's entries.  A corrupt or
truncated file degrades to an empty cache with a warning; a schema-version
mismatch silently invalidates everything (stale winners AND stale profiles
must not outlive a format change).  All I/O failures degrade to "no disk
cache" — a read-only HOME must never break a solve.
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import tempfile
import warnings
from typing import Any, Dict, Iterator, Optional, Tuple

_SCHEMA = 2

# Hash these sources into the key: an edit to any engine/kernel layer can
# flip which candidate wins, so it must orphan the persisted winners.
_VERSIONED_SOURCES = (
    "solve.py",
    os.path.join("core", "tiles.py"),
    os.path.join("core", "distributed.py"),
    os.path.join("core", "scheduler.py"),
    os.path.join("core", "calibrate.py"),
    os.path.join("kernels", "queue.py"),
    os.path.join("kernels", "morph_tile.py"),
    os.path.join("kernels", "edt_tile.py"),
    os.path.join("kernels", "ops.py"),
)

_code_version_memo: Optional[str] = None


def cache_dir() -> str:
    env = os.environ.get("REPRO_IWPP_CACHE_DIR")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-iwpp")


def cache_path() -> str:
    return os.path.join(cache_dir(), "autotune.json")


def code_version() -> str:
    """Short digest of the engine/kernel sources (memoized per process)."""
    global _code_version_memo
    if _code_version_memo is None:
        pkg = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        h = hashlib.sha256()
        for rel in _VERSIONED_SOURCES:
            path = os.path.join(pkg, rel)
            try:
                with open(path, "rb") as f:
                    h.update(f.read())
            except OSError:
                h.update(rel.encode())       # missing file still keys stably
        _code_version_memo = h.hexdigest()[:16]
    return _code_version_memo


def _device_kind() -> str:
    try:
        import jax
        d = jax.devices()[0]
        return f"{d.platform}/{getattr(d, 'device_kind', '?')}"
    except Exception:
        return "unknown"


def entry_key(op_name: str, signature: tuple) -> str:
    """The flat JSON key: device kind + op name + signature + code version.

    ``signature`` is the :func:`repro.solve.autotune_signature` tuple (its
    position 0 repeats ``op_name``; keeping the explicit field makes
    :func:`invalidate_op` robust to signature-layout changes).
    """
    return "|".join((_device_kind(), op_name, repr(signature), code_version()))


def profile_key() -> str:
    """Calibration profiles key on (device kind, code version) only."""
    return "|".join((_device_kind(), code_version()))


@contextlib.contextmanager
def _locked() -> Iterator[None]:
    """Serialize read-modify-write cycles across processes/threads.

    Uses ``fcntl.flock`` on a sidecar ``.lock`` file (each entrant opens its
    own descriptor, so the lock also serializes threads in one process).
    Degrades to unlocked best-effort where flock or the directory is
    unavailable — same policy as every other I/O failure here.
    """
    try:
        import fcntl
    except ImportError:                       # non-POSIX: best effort
        yield
        return
    try:
        os.makedirs(cache_dir(), exist_ok=True)
        f = open(cache_path() + ".lock", "a+")
    except OSError:
        yield
        return
    try:
        fcntl.flock(f.fileno(), fcntl.LOCK_EX)
        yield
    finally:
        f.close()                             # closing drops the flock


def _load_doc() -> Dict[str, Any]:
    """The whole persisted document: ``{"entries": ..., "profiles": ...}``.

    Corrupt/truncated JSON warns and degrades to empty; a schema mismatch
    (older or newer writer) silently invalidates — stale profiles must not
    survive a format change.
    """
    try:
        with open(cache_path()) as f:
            data = json.load(f)
    except OSError:
        return {"entries": {}, "profiles": {}}
    except ValueError:
        warnings.warn(
            f"corrupt autotune cache at {cache_path()}; starting empty",
            RuntimeWarning, stacklevel=3)
        return {"entries": {}, "profiles": {}}
    if not isinstance(data, dict) or data.get("schema") != _SCHEMA:
        return {"entries": {}, "profiles": {}}
    entries = data.get("entries")
    profiles = data.get("profiles")
    return {"entries": entries if isinstance(entries, dict) else {},
            "profiles": profiles if isinstance(profiles, dict) else {}}


def _load_raw() -> Dict[str, Any]:
    return _load_doc()["entries"]


def _store_doc(doc: Dict[str, Any]) -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   prefix=".autotune-", suffix=".json")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"schema": _SCHEMA,
                           "entries": doc.get("entries", {}),
                           "profiles": doc.get("profiles", {})}, f, indent=2)
            os.replace(tmp, path)            # atomic on POSIX
        except BaseException:
            os.unlink(tmp)
            raise
    except OSError:
        pass                                  # read-only FS: stay in-memory


def load(op_name: str, signature: tuple,
         config_cls) -> Optional[Tuple[Any, float]]:
    """Return ``(EngineConfig, seconds)`` for a persisted winner, else None."""
    entry = _load_raw().get(entry_key(op_name, signature))
    if not isinstance(entry, dict):
        return None
    cfg_dict = entry.get("config")
    seconds = entry.get("seconds")
    if not isinstance(cfg_dict, dict) or not isinstance(seconds, (int, float)):
        return None
    fields = {f.name for f in dataclasses.fields(config_cls)}
    if not set(cfg_dict) <= fields or "engine" not in cfg_dict:
        return None                           # written by a different version
    try:
        return config_cls(**cfg_dict), float(seconds)
    except TypeError:
        return None


def store(op_name: str, signature: tuple, config, seconds: float) -> None:
    """Persist one measured winner (locked read-modify-write)."""
    with _locked():
        doc = _load_doc()
        doc["entries"][entry_key(op_name, signature)] = {
            "op": op_name,
            "config": dataclasses.asdict(config),
            "seconds": seconds,
        }
        _store_doc(doc)


def load_profile() -> Optional[Dict[str, Any]]:
    """The persisted calibration profile for this (device, code version)."""
    prof = _load_doc()["profiles"].get(profile_key())
    return prof if isinstance(prof, dict) else None


def store_profile(profile: Dict[str, Any]) -> None:
    """Persist one calibration profile (locked read-modify-write)."""
    with _locked():
        doc = _load_doc()
        doc["profiles"][profile_key()] = profile
        _store_doc(doc)


def invalidate_op(op_names) -> int:
    """Drop every persisted entry for the named ops (spec-change hook).

    Matches on the entry's recorded ``op`` field, so it catches entries
    written under older code versions too — a re-registered solver must not
    resurface through ANY stale winner.  Returns the number dropped.
    """
    names = set(op_names)
    with _locked():
        doc = _load_doc()
        entries = doc["entries"]
        doomed = [k for k, v in entries.items()
                  if isinstance(v, dict) and v.get("op") in names]
        if not doomed:
            return 0
        for k in doomed:
            del entries[k]
        _store_doc(doc)
    return len(doomed)


def clear() -> None:
    try:
        os.unlink(cache_path())
    except OSError:
        pass
    try:
        os.unlink(cache_path() + ".lock")
    except OSError:
        pass
