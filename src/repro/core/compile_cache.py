"""One keyed compile cache for every engine's jitted step functions.

Before this module each layer kept its own memo (``_SOLVER_MEMO`` /
``_DRAIN_MEMO`` / ``_BP_ROUND_MEMO`` in solve.py) — and the layers that kept
*none* (``run_sharded`` re-wrapped a fresh closure in ``jax.jit`` per call)
recompiled their whole program on every invocation, which is exactly the
per-round cost the composed engines were drowning in (ISSUE 7 /
BENCH_multidevice.json ``compose/*``).  Centralizing the memo does three
things the scattered dicts could not:

* one *miss counter* — ``SolveStats.recompiles`` is a before/after snapshot
  of :func:`misses` around an engine run, so "no recompiles across BP
  rounds" is a testable contract (tests/test_runstate.py);
* one invalidation seam — ``repro.ops.on_spec_change`` drops every entry
  built from a replaced op spec, regardless of which layer built it;
* one place to express the build-once-reuse-forever rule that the
  persistent RunState carrier (DESIGN.md §2.6) depends on.

Keys are plain hashable tuples.  By convention the first element is a short
string naming the builder site (``"tiled-drain"``, ``"sharded-fn"``, ...)
and the second the op class, so invalidation by op never has to guess at
key layouts — but any hashable tuple works.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Optional, Tuple

_LOCK = threading.RLock()
_CACHE: Dict[tuple, Any] = {}
_MISSES: int = 0
_HITS: int = 0


def get(key: tuple, build: Callable[[], Any]) -> Any:
    """Return the cached value for ``key``, building (and counting a miss)
    on first use.  ``build`` runs under the cache lock: concurrent workers
    asking for the same compiled step share one trace instead of racing
    (the scheduler/hybrid claim loops hit this from N threads at once)."""
    global _MISSES, _HITS
    with _LOCK:
        if key in _CACHE:
            _HITS += 1
            return _CACHE[key]
        _MISSES += 1
        value = build()
        _CACHE[key] = value
        return value


def misses() -> int:
    """Total cache misses (= compiled-step builds) so far in this process."""
    with _LOCK:
        return _MISSES


def hits() -> int:
    with _LOCK:
        return _HITS


def contains(key: tuple) -> bool:
    with _LOCK:
        return key in _CACHE


def invalidate(pred: Callable[[tuple], bool]) -> int:
    """Drop every entry whose key satisfies ``pred``; returns the count."""
    with _LOCK:
        dead = [k for k in _CACHE if pred(k)]
        for k in dead:
            del _CACHE[k]
        return len(dead)


def invalidate_op_class(op_cls: type) -> int:
    """Drop entries built for ``op_cls`` or any subclass (keys carry the op
    class — or an op *instance* — as their second element by convention)."""
    def pred(key: tuple) -> bool:
        if len(key) < 2:
            return False
        tagged = key[1]
        cls = tagged if isinstance(tagged, type) else type(tagged)
        return isinstance(cls, type) and issubclass(cls, op_cls)
    return invalidate(pred)


def clear() -> None:
    """Drop everything (counters included) — test isolation only."""
    global _MISSES, _HITS
    with _LOCK:
        _CACHE.clear()
        _MISSES = 0
        _HITS = 0


class MissSnapshot:
    """Context helper: ``recompiles`` = misses that happened inside.

    >>> with MissSnapshot() as snap:
    ...     run_engine(...)
    >>> stats = dataclasses.replace(stats, recompiles=snap.count)
    """

    def __enter__(self) -> "MissSnapshot":
        self._before = misses()
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        self.count = misses() - self._before
        return None
