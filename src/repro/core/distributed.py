"""E3: multi-device IWPP via shard_map — the paper's §4 strategy on a mesh.

The grid is partitioned into one block per device over a 2-D device grid
(rows over the first mesh axis, columns over the second).  Each global round
is exactly the paper's TP/BP pipeline:

  TP (Tile Propagation)  -> every device drains its local block to stability
                            (dense frontier rounds — E1 — or the tiled E2);
  BP (Border Propagation)-> halo exchange of the 1-px border ring with the
                            4 mesh neighbors via `lax.ppermute` (two-step:
                            columns first, then rows of the column-extended
                            block, so corners arrive transitively);
  convergence            -> `lax.psum` of per-device "changed" flags; the
                            outer `while_loop` stops when no device changed
                            (paper: "until no more intra- and inter-tile
                            propagations").

Restarting local propagation from received halos is seeded only at the
border ring — the frontier of the next TP stage is the set of pixels the
halo actually improved, which is the paper's "propagations initiated from
the borders".
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.pattern import PropagationOp, tree_shape


def shard_map_compat(f, mesh, in_specs, out_specs):
    """Version-compat wrapper: jax.shard_map (new) vs jax.experimental (old)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _shift_axis(x, axis_name: str, direction: int, fill, mesh_axis_size: int):
    """ppermute x to the neighbor `direction` steps along `axis_name`.

    Device i receives from device i - direction; edge devices receive
    `fill` (non-periodic boundary).
    """
    n = mesh_axis_size
    perm = [(i, i + direction) for i in range(n) if 0 <= i + direction < n]
    y = jax.lax.ppermute(x, axis_name, perm)
    idx = jax.lax.axis_index(axis_name)
    # Devices with no sender hold garbage/zeros -> overwrite with fill.
    no_sender = (idx == 0) if direction > 0 else (idx == n - 1)
    return jnp.where(no_sender, jnp.full_like(y, fill), y)


def _exchange_halo(block, pad_vals, axes: Tuple[str, str], mesh_shape):
    """Build the (h+2, w+2) halo-extended block from mesh neighbors."""
    row_ax, col_ax = axes
    nrows, ncols = mesh_shape

    def extend(x, fill):
        h, w = x.shape[-2:]
        # columns: my left edge goes right, so I receive neighbor's right edge
        left_halo = _shift_axis(x[..., :, w - 1 : w], col_ax, +1, fill, ncols)
        right_halo = _shift_axis(x[..., :, 0:1], col_ax, -1, fill, ncols)
        xe = jnp.concatenate([left_halo, x, right_halo], axis=-1)
        top_halo = _shift_axis(xe[..., h - 1 : h, :], row_ax, +1, fill, nrows)
        bot_halo = _shift_axis(xe[..., 0:1, :], row_ax, -1, fill, nrows)
        return jnp.concatenate([top_halo, xe, bot_halo], axis=-2)

    return jax.tree_util.tree_map(extend, block, pad_vals)


def _local_drain(op: PropagationOp, block, frontier, max_iters: int = 1_000_000):
    def cond(c):
        _, f, it = c
        return jnp.any(f) & (it < max_iters)

    def body(c):
        blk, f, it = c
        blk, f = op.round(blk, f)
        return blk, f, it + 1

    block, _, iters = jax.lax.while_loop(cond, body, (block, frontier, jnp.int32(0)))
    return block, iters


def run_sharded(op: PropagationOp, state, mesh: Mesh,
                axes: Tuple[str, str] = ("data", "model")):
    """Run `op` to the global fixed point on `mesh`.

    `state` leaves are (..., H, W) with H divisible by mesh.shape[axes[0]]
    and W by mesh.shape[axes[1]].
    """
    row_ax, col_ax = axes
    nrows, ncols = mesh.shape[row_ax], mesh.shape[col_ax]
    H, W = tree_shape(state)
    assert H % nrows == 0 and W % ncols == 0, (H, W, nrows, ncols)
    pad_vals = op.pad_value(state)

    spec = jax.tree_util.tree_map(
        lambda x: P(*([None] * (x.ndim - 2) + [row_ax, col_ax])), state)

    def device_fn(block):
        # TP round 0: local drain from the op's own init frontier.
        f0 = op.init_frontier(block)
        block, _ = _local_drain(op, block, f0)

        def cond(carry):
            _, changed, it = carry
            return changed & (it < 10_000)

        def body(carry):
            block, _, it = carry
            # BP: halo exchange, then one masked round sourcing only from the
            # halo ring, to find which border pixels the neighbors improved.
            ext = _exchange_halo(block, pad_vals, (row_ax, col_ax), (nrows, ncols))
            h, w = tree_shape(block)
            halo_frontier = jnp.zeros((h + 2, w + 2), dtype=bool)
            halo_frontier = halo_frontier.at[0, :].set(True).at[-1, :].set(True)
            halo_frontier = halo_frontier.at[:, 0].set(True).at[:, -1].set(True)
            ext_new, f_ext = op.round(ext, halo_frontier)
            inner = lambda x: x[..., 1:-1, 1:-1]
            block = jax.tree_util.tree_map(lambda _, b: inner(b), block, ext_new)
            f_in = inner(f_ext)
            # TP: drain local propagation seeded by improved border pixels.
            block, _ = _local_drain(op, block, f_in)
            changed_local = jnp.any(f_in)
            changed = jax.lax.psum(changed_local.astype(jnp.int32), (row_ax, col_ax)) > 0
            return block, changed, it + 1

        block, _, rounds = jax.lax.while_loop(cond, body, (block, jnp.bool_(True), jnp.int32(0)))
        return block, rounds

    fn = shard_map_compat(device_fn, mesh, (spec,), (spec, P()))
    out, rounds = jax.jit(fn)(state)
    return out, rounds
