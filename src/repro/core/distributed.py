"""E3: multi-device IWPP via shard_map — the paper's §4 strategy on a mesh.

The grid is partitioned into one block per device over a 2-D device grid
(rows over the first mesh axis, columns over the second).  For N-D inputs
(DESIGN.md §2.7) the mesh still shards the *trailing two* spatial axes;
leading spatial axes (e.g. a 3-D volume's depth) stay device-local, so the
halo exchange below is exactly the 2-D ring carrying full-depth strips and
conn26's depth-diagonal reaches never cross a device boundary mid-axis.
Each global round is exactly the paper's TP/BP pipeline:

  TP (Tile Propagation)  -> every device drains its local block to stability
                            — the drain is *pluggable*: dense frontier
                            rounds (E1 `_local_drain`) or a per-shard
                            active-tile queue (E2, plain or Pallas-backed,
                            with `drain_batch`), composing the paper's §4
                            inter-device pipeline with its §3.2 multi-level
                            queue *within* each device;
  BP (Border Propagation)-> halo exchange of the 1-px border ring with the
                            4 mesh neighbors via `lax.ppermute` (two-step:
                            columns first, then rows carrying the fresh ring
                            corners, so corners arrive transitively);
  convergence            -> `lax.psum` of per-device "changed" flags; the
                            outer `while_loop` stops when no device changed
                            (paper: "until no more intra- and inter-tile
                            propagations").

Persistent round state (DESIGN.md §2.6): with the tiled TP drain, each
device builds its padded-layout :class:`~repro.core.tiles.TiledRunState`
**once** (`tiles.prepare`) and threads it through the outer BP
`while_loop` — the per-shard active-tile queue, the padded planes, and the
tile stats all persist across BP rounds.  The halo exchange moves only the
O(perimeter) border ring (column/row strips written straight into the
carrier's pad ring), replacing the old O(area) concatenate-rebuild of the
halo-extended block, and each BP round is pipelined the way the paper's §4
overlaps border communication with tile computation:

  (1) one queue `step` over the tiles the previous exchange activated (all
      border tiles by construction) — freshens the outgoing borders;
  (2) the two-step `ppermute` ring exchange is *issued* — it has no data
      dependency on anything after it, so XLA may overlap the collective
      with (3);
  (3) the interior `drain` of the remaining active tiles runs;
  (4) received ring segments are applied to the carrier, compared against
      the previously-received ring (O(perimeter), monotone, so the
      comparison cannot oscillate even when a local drain raced past a ring
      cell), and the changed segments seed the next round's active tiles.

Borders improved *after* the send in (2) are caught by a sent-vs-current
border compare folded into the convergence flag, so the loop never exits
with an unsent improvement.  The jitted shard_map program itself is built
once per (op, mesh, signature, knobs) through the shared compile cache —
repeat solves (autotune probes, benchmark iterations, BP re-entries from
the hybrid engine) reuse the compiled executable instead of re-tracing.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import compile_cache
from repro.core import tiles as _tiles
from repro.core.pattern import PropagationOp, restore_invalid, tree_shape


class ShardStats(NamedTuple):
    """Work record of one sharded run (per-device counters psum-aggregated).

    ``per_device_tiles`` keeps the *unreduced* (nrows, ncols) per-device
    drain counts next to the psum'd total, so the aggregation itself is a
    testable invariant: ``per_device_tiles.sum() == tiles_processed``.
    All tile counters are zero under the dense TP drain.
    """
    bp_rounds: jnp.ndarray         # outer TP/BP rounds (replicated scalar)
    tiles_processed: jnp.ndarray   # psum over devices (tiled TP drain only)
    overflow_events: jnp.ndarray   # psum over devices
    tiles_requeued: jnp.ndarray    # psum over devices (unconverged re-drains)
    per_device_tiles: jnp.ndarray  # (nrows, ncols) per-device drain counts


def shard_map_compat(f, mesh, in_specs, out_specs):
    """Version-compat wrapper: jax.shard_map (new) vs jax.experimental (old)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _shift_axis(x, axis_name: str, direction: int, fill, mesh_axis_size: int):
    """ppermute x to the neighbor `direction` steps along `axis_name`.

    Device i receives from device i - direction; edge devices receive
    `fill` (non-periodic boundary).
    """
    n = mesh_axis_size
    perm = [(i, i + direction) for i in range(n) if 0 <= i + direction < n]
    y = jax.lax.ppermute(x, axis_name, perm)
    idx = jax.lax.axis_index(axis_name)
    # Devices with no sender hold garbage/zeros -> overwrite with fill.
    no_sender = (idx == 0) if direction > 0 else (idx == n - 1)
    return jnp.where(no_sender, jnp.full_like(y, fill), y)


def _exchange_halo(block, pad_vals, axes: Tuple[str, str], mesh_shape):
    """Build the (h+2, w+2) halo-extended block from mesh neighbors.

    O(area) concatenate — the *dense* TP path only; the tiled path writes
    the received ring straight into its persistent padded carrier instead.
    """
    row_ax, col_ax = axes
    nrows, ncols = mesh_shape

    def extend(x, fill):
        h, w = x.shape[-2:]
        # columns: my left edge goes right, so I receive neighbor's right edge
        left_halo = _shift_axis(x[..., :, w - 1 : w], col_ax, +1, fill, ncols)
        right_halo = _shift_axis(x[..., :, 0:1], col_ax, -1, fill, ncols)
        xe = jnp.concatenate([left_halo, x, right_halo], axis=-1)
        top_halo = _shift_axis(xe[..., h - 1 : h, :], row_ax, +1, fill, nrows)
        bot_halo = _shift_axis(xe[..., 0:1, :], row_ax, -1, fill, nrows)
        return jnp.concatenate([top_halo, xe, bot_halo], axis=-2)

    return jax.tree_util.tree_map(extend, block, pad_vals)


def _local_drain(op: PropagationOp, block, frontier, max_iters: int = 1_000_000):
    def cond(c):
        _, f, it = c
        return jnp.any(f) & (it < max_iters)

    def body(c):
        blk, f, it = c
        blk, f = op.round(blk, f)
        return blk, f, it + 1

    block, _, iters = jax.lax.while_loop(cond, body, (block, frontier, jnp.int32(0)))
    return block, iters


def _shift_bool_1d(v, d: int):
    """Shift a 1-D bool vector by d with False fill (no wraparound)."""
    if d > 0:
        return jnp.concatenate([jnp.zeros((d,), bool), v[:-d]])
    return jnp.concatenate([v[-d:], jnp.zeros((-d,), bool)])


def _dilate_1d(v):
    return v | _shift_bool_1d(v, 1) | _shift_bool_1d(v, -1)


def _tiles_touched_1d(changed, tile: int, n_tiles: int):
    """Map a changed-border-cell vector to the tile indices it can affect
    (±1-cell dilation, then per-tile any)."""
    d = _dilate_1d(changed)
    d = jnp.pad(d, (0, n_tiles * tile - d.shape[0]))
    return d.reshape(n_tiles, tile).any(axis=1)


def _mesh_fingerprint(mesh: Mesh) -> tuple:
    return (tuple(mesh.devices.flatten().tolist()), tuple(mesh.axis_names),
            tuple(mesh.devices.shape))


def _state_signature(state) -> tuple:
    return (jax.tree_util.tree_structure(state),
            tuple((tuple(l.shape), str(l.dtype))
                  for l in jax.tree_util.tree_leaves(state)))


def run_sharded(op: PropagationOp, state, mesh: Mesh,
                axes: Tuple[str, str] = ("data", "model"), *,
                tile: Optional[int] = None,
                queue_capacity: int = 256,
                drain_batch: int = 1,
                tile_solver: Optional[Callable] = None,
                batched_tile_solver: Optional[Callable] = None,
                max_bp_rounds: int = 10_000,
                donate: bool = False):
    """Run `op` to the global fixed point on `mesh`.

    `state` leaves are (..., H, W) with H divisible by mesh.shape[axes[0]]
    and W by mesh.shape[axes[1]].  Returns ``(state, ShardStats)``.

    ``tile=None`` drains each device's block densely (E1 rounds) per TP
    stage — the flat `shard_map` engine.  With ``tile`` set, each TP stage
    drains a *persistent* per-shard active-tile queue (the composed
    `shard_map-tiled` engine; see the module docstring for the BP round
    structure): the first TP drains from the op's own initial frontier;
    every later TP is seeded with *only the tiles the halo exchange
    improved* — monotone commutative updates make re-draining any superset
    of those tiles reach the same fixed point, so the compaction is free of
    correctness risk and skips the (typically vast) stable interior of each
    shard.  ``tile_solver`` / ``batched_tile_solver`` plug the Pallas VMEM
    drains in, exactly as in `run_tiled`; solvers must honor the
    ``(block, unconverged)`` contract so partial drains self-requeue.

    The compiled program is memoized in the shared compile cache — calling
    again with the same (op, mesh, state signature, knobs) reuses the
    executable.  ``donate=True`` additionally donates the input buffers to
    the compiled call (pass it only when the caller owns a private copy,
    e.g. after padding to a mesh multiple); donation is skipped on CPU,
    which does not implement it.
    """
    row_ax, col_ax = axes
    nrows, ncols = mesh.shape[row_ax], mesh.shape[col_ax]
    spatial = tree_shape(state, op.ndim)
    H, W = spatial[-2:]
    assert H % nrows == 0 and W % ncols == 0, (H, W, nrows, ncols)
    if tile is not None and op.ndim != 2:
        raise NotImplementedError(
            "the composed shard_map-tiled TP drain is 2-D only; "
            f"op has ndim={op.ndim} — use tile=None (dense TP) or the "
            "single-device tiled engines for volumes")
    pad_vals = op.pad_value(state)
    bh, bw = H // nrows, W // ncols

    spec = jax.tree_util.tree_map(
        lambda x: P(*([None] * (x.ndim - 2) + [row_ax, col_ax])), state)

    zero = jnp.int32(0)

    def device_fn_dense(block):
        block, _ = _local_drain(op, block, op.init_frontier(block))

        def cond(carry):
            _, changed, it = carry
            return changed & (it < max_bp_rounds)

        def body(carry):
            block, _, it = carry
            # BP: halo exchange, then one masked round sourcing only from the
            # halo ring, to find which border pixels the neighbors improved.
            ext = _exchange_halo(block, pad_vals, (row_ax, col_ax), (nrows, ncols))
            sp = tree_shape(block, op.ndim)
            # Ring frontier on the trailing-2 halo only: leading spatial axes
            # are device-local, so their boundaries are *global* boundaries
            # (op.round's neutral shift fill handles them, no exchange).
            halo_frontier = jnp.zeros(sp[:-2] + (sp[-2] + 2, sp[-1] + 2),
                                      dtype=bool)
            halo_frontier = (halo_frontier.at[..., 0, :].set(True)
                             .at[..., -1, :].set(True)
                             .at[..., :, 0].set(True)
                             .at[..., :, -1].set(True))
            # Only *valid* halo cells may source: an invalid border pixel of
            # the neighbor shard holds arbitrary input values (the invalid-
            # pixel contract preserves them), and an unmasked seed would let
            # it propagate into this shard's valid region.
            if "valid" in ext:
                halo_frontier = halo_frontier & ext["valid"]
            ext_new, f_ext = op.round(ext, halo_frontier)
            inner = lambda x: x[..., 1:-1, 1:-1]
            block = jax.tree_util.tree_map(lambda _, b: inner(b), block, ext_new)
            f_in = inner(f_ext)
            # TP: drain local propagation seeded by improved border pixels.
            block, _ = _local_drain(op, block, f_in)
            changed_local = jnp.any(f_in)
            changed = jax.lax.psum(changed_local.astype(jnp.int32), (row_ax, col_ax)) > 0
            return block, changed, it + 1

        block, _, rounds = jax.lax.while_loop(
            cond, body, (block, jnp.bool_(True), jnp.int32(0)))
        totals = (zero, zero, zero)
        return block, rounds, tuple(jax.lax.psum(c, (row_ax, col_ax)) for c in totals), \
            zero.reshape(1, 1)

    def device_fn_tiled(block):
        # Build the persistent carrier ONCE; it survives every BP round.
        plan, rs = _tiles.prepare(
            op, block, tile=tile, queue_capacity=queue_capacity,
            tile_solver=tile_solver, drain_batch=drain_batch,
            batched_tile_solver=batched_tile_solver)
        # TP round 0: drain from the op's own init frontier.
        rs = _tiles.drain(plan, rs)
        nty, ntx = plan.nty, plan.ntx
        mutable = [k for k in rs.padded if k not in op.static_leaves]

        def fill_rings():
            """What the ring 'received' before any exchange: the pad fill."""
            out = {}
            for k in mutable:
                x = rs.padded[k]
                lead = x.shape[:-2]
                f = pad_vals[k]
                mk = lambda shp: jnp.full(lead + shp, f, x.dtype)
                out[k] = (mk((bh, 1)), mk((bh, 1)),
                          mk((1, 2 + bw)), mk((1, 2 + bw)))
            return out

        def exchange(padded, keys):
            """Issue the two-step ring exchange for ``keys`` (reads only —
            the received segments are applied to the carrier later, after
            the interior drain, so the collective can overlap it).

            Returns ``(recv, sent)``: per-leaf received
            (left, right, top, bottom) ring segments, and the *domain*
            border values that were sent (mutable leaves only — for the
            sent-vs-current convergence compare).
            """
            recv, sent = {}, {}
            for k in keys:
                x = padded[k]
                f = pad_vals[k]
                send_l = x[..., 1:1 + bh, 1:2]         # my left domain col
                send_r = x[..., 1:1 + bh, bw:bw + 1]   # my right domain col
                left = _shift_axis(send_r, col_ax, +1, f, ncols)
                right = _shift_axis(send_l, col_ax, -1, f, ncols)
                # Row sends span the full padded width and carry the ring
                # corners *just received* in the column step (set without
                # writing the plane), so diagonal values arrive transitively.
                send_t = x[..., 1:2, 0:2 + bw]
                send_b = x[..., bh:bh + 1, 0:2 + bw]
                send_t = send_t.at[..., :, 0:1].set(left[..., 0:1, :])
                send_t = send_t.at[..., :, 1 + bw:2 + bw].set(right[..., 0:1, :])
                send_b = send_b.at[..., :, 0:1].set(left[..., bh - 1:bh, :])
                send_b = send_b.at[..., :, 1 + bw:2 + bw].set(right[..., bh - 1:bh, :])
                top = _shift_axis(send_b, row_ax, +1, f, nrows)
                bot = _shift_axis(send_t, row_ax, -1, f, nrows)
                recv[k] = (left, right, top, bot)
                if k in mutable:
                    sent[k] = (send_l, send_r,
                               send_t[..., :, 1:1 + bw], send_b[..., :, 1:1 + bw])
            return recv, sent

        def apply_recv(padded, recv, keys):
            """Write the received ring segments into the carrier's pad ring.

            The bottom/right ring rows sit *inside* the last tile's interior
            when the shard is not a tile multiple, so a local drain may have
            raced past them — overwriting with the (possibly older) received
            value is still sound: ring cells are conduits, never part of the
            stripped output, and the improvement travels the proper BP path
            (our border was sent; the neighbor drains and sends it back).
            """
            new = dict(padded)
            for k in keys:
                x = padded[k]
                l, r, t, b = recv[k]
                x = x.at[..., 1:1 + bh, 0:1].set(l)
                x = x.at[..., 1:1 + bh, 1 + bw:2 + bw].set(r)
                x = x.at[..., 0:1, 0:2 + bw].set(t)
                x = x.at[..., 1 + bh:2 + bh, 0:2 + bw].set(b)
                new[k] = x
            return new

        def ring_changes(recv, prev):
            """Per-cell received-vs-previously-received compare (monotone in
            the sender's own timeline, so this cannot oscillate)."""
            ch_l = jnp.zeros((bh,), bool)
            ch_r = jnp.zeros((bh,), bool)
            ch_t = jnp.zeros((2 + bw,), bool)
            ch_b = jnp.zeros((2 + bw,), bool)
            for k in mutable:
                l, r, t, b = recv[k]
                pl, pr, pt, pb = prev[k]
                col_red = tuple(range(l.ndim - 2)) + (-1,)
                row_red = tuple(range(t.ndim - 2)) + (-2,)
                ch_l = ch_l | jnp.any(l != pl, axis=col_red)
                ch_r = ch_r | jnp.any(r != pr, axis=col_red)
                ch_t = ch_t | jnp.any(t != pt, axis=row_red)
                ch_b = ch_b | jnp.any(b != pb, axis=row_red)
            return ch_l, ch_r, ch_t, ch_b

        def ring_activation(ch_l, ch_r, ch_t, ch_b):
            """Changed ring cells -> the border tiles they can affect."""
            act = jnp.zeros((nty, ntx), bool)
            act = act.at[:, 0].max(_tiles_touched_1d(ch_l, tile, nty))
            act = act.at[:, ntx - 1].max(_tiles_touched_1d(ch_r, tile, nty))
            act = act.at[0, :].max(_tiles_touched_1d(ch_t[1:1 + bw], tile, ntx))
            act = act.at[nty - 1, :].max(_tiles_touched_1d(ch_b[1:1 + bw], tile, ntx))
            return act

        def border_dirty(padded, sent):
            """Did a drain improve a domain border *after* it was sent?
            Keeps the loop alive until every improvement has been shipped."""
            dirty = jnp.bool_(False)
            for k in mutable:
                x = padded[k]
                sl, sr, st, sb = sent[k]
                dirty = dirty | jnp.any(x[..., 1:1 + bh, 1:2] != sl)
                dirty = dirty | jnp.any(x[..., 1:1 + bh, bw:bw + 1] != sr)
                dirty = dirty | jnp.any(x[..., 1:2, 1:1 + bw] != st)
                dirty = dirty | jnp.any(x[..., bh:bh + 1, 1:1 + bw] != sb)
            return dirty

        def cond(carry):
            _, _, changed, it = carry
            return changed & (it < max_bp_rounds)

        def body(carry):
            rs, prev, _, it = carry
            # (1) Freshen outgoing borders: one queue step over the tiles the
            # previous exchange activated (all border tiles by construction).
            rs = jax.lax.cond(jnp.any(rs.active),
                              lambda r: _tiles.step(plan, r), lambda r: r, rs)
            # (2) Issue the ring exchange — no dependency on (3).  Only the
            # mutable leaves travel: the static rings (masks, valid planes,
            # coordinate grids) were exchanged once before the loop.
            recv, sent = exchange(rs.padded, mutable)
            # (3) Interior drain of whatever the step left active.
            rs = _tiles.drain(plan, rs)
            # (4) Apply received rings; seed next round from what changed.
            ch = ring_changes(recv, prev)
            rs = _tiles.TiledRunState(apply_recv(rs.padded, recv, mutable),
                                      rs.active | ring_activation(*ch),
                                      rs.stats)
            prev = {k: recv[k] for k in mutable}
            changed_local = (jnp.any(ch[0]) | jnp.any(ch[1]) | jnp.any(ch[2])
                             | jnp.any(ch[3]) | border_dirty(rs.padded, sent))
            changed = jax.lax.psum(
                changed_local.astype(jnp.int32), (row_ax, col_ax)) > 0
            return rs, prev, changed, it + 1

        # One-time exchange of the static rings: the neighbor's mask/valid/
        # coordinate border cells never change, so they need not ride the
        # per-round collective.
        static_keys = [k for k in rs.padded if k in op.static_leaves]
        recv_static, _ = exchange(rs.padded, static_keys)
        rs = rs._replace(padded=apply_recv(rs.padded, recv_static, static_keys))
        rs, _, _, rounds = jax.lax.while_loop(
            cond, body, (rs, fill_rings(), jnp.bool_(True), jnp.int32(0)))
        # One final drain: the last exchange may have activated tiles.
        rs = _tiles.drain(plan, rs)
        st = rs.stats
        counters = (st.tiles_processed, st.overflow_events, st.tiles_requeued)
        # Per-device counters + psum totals: stats aggregation is itself a
        # collective (the record is replicated; the per-device plane is not).
        totals = tuple(jax.lax.psum(c, (row_ax, col_ax)) for c in counters)
        block = _tiles.finalize(plan, rs, None, restore=False)
        return block, rounds, totals, st.tiles_processed.reshape(1, 1)

    device_fn = device_fn_dense if tile is None else device_fn_tiled

    def build():
        fn = shard_map_compat(device_fn, mesh, (spec,),
                              (spec, P(), (P(), P(), P()), P(row_ax, col_ax)))
        dn = (0,) if donate and jax.default_backend() != "cpu" else ()
        return jax.jit(fn, donate_argnums=dn)

    key = ("sharded-fn", op, _mesh_fingerprint(mesh), axes,
           _state_signature(state), tile, queue_capacity, drain_batch,
           tile_solver, batched_tile_solver, max_bp_rounds, donate)
    out, rounds, (tiles, ovf, req), per_dev = compile_cache.get(key, build)(state)
    # Engine output contract: invalid cells hold their input values.
    out = restore_invalid(op, state, out)
    return out, ShardStats(rounds, tiles, ovf, req, per_dev)
