"""E3: multi-device IWPP via shard_map — the paper's §4 strategy on a mesh.

The grid is partitioned into one block per device over a 2-D device grid
(rows over the first mesh axis, columns over the second).  Each global round
is exactly the paper's TP/BP pipeline:

  TP (Tile Propagation)  -> every device drains its local block to stability
                            — the drain is *pluggable*: dense frontier
                            rounds (E1 `_local_drain`) or a per-shard
                            `run_tiled` active-tile queue (E2, plain or
                            Pallas-backed, with `drain_batch`), composing
                            the paper's §4 inter-device pipeline with its
                            §3.2 multi-level queue *within* each device;
  BP (Border Propagation)-> halo exchange of the 1-px border ring with the
                            4 mesh neighbors via `lax.ppermute` (two-step:
                            columns first, then rows of the column-extended
                            block, so corners arrive transitively);
  convergence            -> `lax.psum` of per-device "changed" flags; the
                            outer `while_loop` stops when no device changed
                            (paper: "until no more intra- and inter-tile
                            propagations").

Restarting local propagation from received halos is seeded only at the
border ring — the frontier of the next TP stage is the set of pixels the
halo actually improved, which is the paper's "propagations initiated from
the borders".  With the tiled TP drain, that frontier is further compacted
to the set of *tiles* it touches (`active_tiles_from_frontier`), so a BP
round re-drains only the halo-improved corner of each shard instead of the
whole block (DESIGN.md §2.2).
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.pattern import PropagationOp, restore_invalid, tree_shape
from repro.core.tiles import active_tiles_from_frontier, run_tiled


class ShardStats(NamedTuple):
    """Work record of one sharded run (per-device counters psum-aggregated).

    ``per_device_tiles`` keeps the *unreduced* (nrows, ncols) per-device
    drain counts next to the psum'd total, so the aggregation itself is a
    testable invariant: ``per_device_tiles.sum() == tiles_processed``.
    All tile counters are zero under the dense TP drain.
    """
    bp_rounds: jnp.ndarray         # outer TP/BP rounds (replicated scalar)
    tiles_processed: jnp.ndarray   # psum over devices (tiled TP drain only)
    overflow_events: jnp.ndarray   # psum over devices
    tiles_requeued: jnp.ndarray    # psum over devices (unconverged re-drains)
    per_device_tiles: jnp.ndarray  # (nrows, ncols) per-device drain counts


def shard_map_compat(f, mesh, in_specs, out_specs):
    """Version-compat wrapper: jax.shard_map (new) vs jax.experimental (old)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _shift_axis(x, axis_name: str, direction: int, fill, mesh_axis_size: int):
    """ppermute x to the neighbor `direction` steps along `axis_name`.

    Device i receives from device i - direction; edge devices receive
    `fill` (non-periodic boundary).
    """
    n = mesh_axis_size
    perm = [(i, i + direction) for i in range(n) if 0 <= i + direction < n]
    y = jax.lax.ppermute(x, axis_name, perm)
    idx = jax.lax.axis_index(axis_name)
    # Devices with no sender hold garbage/zeros -> overwrite with fill.
    no_sender = (idx == 0) if direction > 0 else (idx == n - 1)
    return jnp.where(no_sender, jnp.full_like(y, fill), y)


def _exchange_halo(block, pad_vals, axes: Tuple[str, str], mesh_shape):
    """Build the (h+2, w+2) halo-extended block from mesh neighbors."""
    row_ax, col_ax = axes
    nrows, ncols = mesh_shape

    def extend(x, fill):
        h, w = x.shape[-2:]
        # columns: my left edge goes right, so I receive neighbor's right edge
        left_halo = _shift_axis(x[..., :, w - 1 : w], col_ax, +1, fill, ncols)
        right_halo = _shift_axis(x[..., :, 0:1], col_ax, -1, fill, ncols)
        xe = jnp.concatenate([left_halo, x, right_halo], axis=-1)
        top_halo = _shift_axis(xe[..., h - 1 : h, :], row_ax, +1, fill, nrows)
        bot_halo = _shift_axis(xe[..., 0:1, :], row_ax, -1, fill, nrows)
        return jnp.concatenate([top_halo, xe, bot_halo], axis=-2)

    return jax.tree_util.tree_map(extend, block, pad_vals)


def _local_drain(op: PropagationOp, block, frontier, max_iters: int = 1_000_000):
    def cond(c):
        _, f, it = c
        return jnp.any(f) & (it < max_iters)

    def body(c):
        blk, f, it = c
        blk, f = op.round(blk, f)
        return blk, f, it + 1

    block, _, iters = jax.lax.while_loop(cond, body, (block, frontier, jnp.int32(0)))
    return block, iters


def run_sharded(op: PropagationOp, state, mesh: Mesh,
                axes: Tuple[str, str] = ("data", "model"), *,
                tile: Optional[int] = None,
                queue_capacity: int = 256,
                drain_batch: int = 1,
                tile_solver: Optional[Callable] = None,
                batched_tile_solver: Optional[Callable] = None,
                max_bp_rounds: int = 10_000):
    """Run `op` to the global fixed point on `mesh`.

    `state` leaves are (..., H, W) with H divisible by mesh.shape[axes[0]]
    and W by mesh.shape[axes[1]].  Returns ``(state, ShardStats)``.

    ``tile=None`` drains each device's block densely (E1 rounds) per TP
    stage — the flat `shard_map` engine.  With ``tile`` set, each TP stage
    is a per-shard `run_tiled` active-tile queue (the composed
    `shard_map-tiled` engine): the first TP drains from the op's own
    initial frontier; every later TP is seeded with *only the tiles the
    halo exchange improved* (``initial_active`` over the halo-improved
    frontier) — monotone commutative updates make re-draining any superset
    of those tiles reach the same fixed point, so the compaction is free of
    correctness risk and skips the (typically vast) stable interior of each
    shard.  ``tile_solver`` / ``batched_tile_solver`` plug the Pallas VMEM
    drains in, exactly as in `run_tiled`; solvers must honor the
    ``(block, unconverged)`` contract so partial drains self-requeue.
    """
    row_ax, col_ax = axes
    nrows, ncols = mesh.shape[row_ax], mesh.shape[col_ax]
    H, W = tree_shape(state)
    assert H % nrows == 0 and W % ncols == 0, (H, W, nrows, ncols)
    pad_vals = op.pad_value(state)
    bh, bw = H // nrows, W // ncols
    if tile is not None:
        nty, ntx = -(-bh // tile), -(-bw // tile)

    spec = jax.tree_util.tree_map(
        lambda x: P(*([None] * (x.ndim - 2) + [row_ax, col_ax])), state)

    zero = jnp.int32(0)

    def _tp_drain(block, frontier, active):
        """One TP stage; returns (block, (tiles, overflows, requeues)).

        ``frontier``/``active``: the seed — exactly one is non-None (the
        dense drain takes a pixel frontier, the tiled drain a tile bitmap).
        """
        if tile is None:
            block, _ = _local_drain(op, block, frontier)
            return block, (zero, zero, zero)
        # restore=False: the invalid-pixel contract is applied once at this
        # engine's own boundary, not per TP stage inside the BP loop.
        # Each nested call still pays run_tiled's O(shard-area) pad/strip —
        # the drain work is active-tiles-only, the layout copies are not;
        # keeping shards in padded layout across the BP loop would remove
        # them but needs a padded-layout run_tiled entry point (follow-up).
        block, st = run_tiled(op, block, tile=tile,
                              queue_capacity=queue_capacity,
                              tile_solver=tile_solver,
                              drain_batch=drain_batch,
                              batched_tile_solver=batched_tile_solver,
                              initial_active=active, restore=False)
        return block, (st.tiles_processed, st.overflow_events,
                       st.tiles_requeued)

    def device_fn(block):
        # TP round 0: local drain from the op's own init frontier.
        if tile is None:
            block, counters = _tp_drain(block, op.init_frontier(block), None)
        else:
            block, counters = _tp_drain(block, None, None)

        def cond(carry):
            _, changed, it, _ = carry
            return changed & (it < max_bp_rounds)

        def body(carry):
            block, _, it, (tiles, ovf, req) = carry
            # BP: halo exchange, then one masked round sourcing only from the
            # halo ring, to find which border pixels the neighbors improved.
            ext = _exchange_halo(block, pad_vals, (row_ax, col_ax), (nrows, ncols))
            h, w = tree_shape(block)
            halo_frontier = jnp.zeros((h + 2, w + 2), dtype=bool)
            halo_frontier = halo_frontier.at[0, :].set(True).at[-1, :].set(True)
            halo_frontier = halo_frontier.at[:, 0].set(True).at[:, -1].set(True)
            # Only *valid* halo cells may source: an invalid border pixel of
            # the neighbor shard holds arbitrary input values (the invalid-
            # pixel contract preserves them), and an unmasked seed would let
            # it propagate into this shard's valid region.
            if "valid" in ext:
                halo_frontier = halo_frontier & ext["valid"]
            ext_new, f_ext = op.round(ext, halo_frontier)
            inner = lambda x: x[..., 1:-1, 1:-1]
            block = jax.tree_util.tree_map(lambda _, b: inner(b), block, ext_new)
            f_in = inner(f_ext)
            # TP: drain local propagation seeded by improved border pixels
            # (tiled drain: compacted to the tiles those pixels touch).
            if tile is None:
                block, (t, o, r) = _tp_drain(block, f_in, None)
            else:
                active = active_tiles_from_frontier(op, f_in, tile, nty, ntx)
                block, (t, o, r) = _tp_drain(block, None, active)
            changed_local = jnp.any(f_in)
            changed = jax.lax.psum(changed_local.astype(jnp.int32), (row_ax, col_ax)) > 0
            return block, changed, it + 1, (tiles + t, ovf + o, req + r)

        block, _, rounds, (tiles, ovf, req) = jax.lax.while_loop(
            cond, body, (block, jnp.bool_(True), jnp.int32(0), counters))
        # Per-device counters + psum totals: stats aggregation is itself a
        # collective (the record is replicated; the per-device plane is not).
        totals = tuple(jax.lax.psum(c, (row_ax, col_ax)) for c in (tiles, ovf, req))
        return block, rounds, totals, tiles.reshape(1, 1)

    fn = shard_map_compat(device_fn, mesh, (spec,),
                          (spec, P(), (P(), P(), P()), P(row_ax, col_ax)))
    out, rounds, (tiles, ovf, req), per_dev = jax.jit(fn)(state)
    # Engine output contract: invalid cells hold their input values.
    out = restore_invalid(op, state, out)
    return out, ShardStats(rounds, tiles, ovf, req, per_dev)
