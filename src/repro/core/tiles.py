"""E2: the tiled active-set engine — TPU analogue of the paper's
multi-level queue (§3.2).

Hierarchy mapping (DESIGN.md §2):
  * within a tile, propagation is dense vector work in VMEM (BQ analogue);
    the tile iterates *locally to stability* before returning — one "queue
    drain" per activation, amortizing HBM traffic exactly like the paper
    amortizes shared-memory traffic;
  * across tiles, a fixed-capacity **active-tile queue** lives at the outer
    level (GBQ analogue).  Each outer round compacts the active bitmap into
    at most ``queue_capacity`` tile ids (`jnp.where(..., size=)` — the
    prefix-sum of the paper, done by XLA) and drains them — **in parallel
    batches of ``drain_batch`` blocks** (the paper's concurrent consumption
    of the global queue across SMs, §3.2) or sequentially under `lax.scan`
    when ``drain_batch <= 1`` — then marks neighbor tiles whose halo became
    stale.  Monotone commutative updates make any order (and any degree of
    concurrency) reach the same fixed point; interior writes of distinct
    tiles are disjoint, and a stale halo read at worst re-queues a tile via
    the dirty-neighbor marks.
  * overflow: tiles beyond capacity are simply *retained* in the bitmap for
    the next round — the same re-execution-from-partial-output semantics as
    the paper's §5.2.4 GBQ overflow, without ever dropping information.

The engine is rank-generic (DESIGN.md §2.7): tiles are ``tile``-sized boxes
over the op's trailing ``ndim`` spatial axes (2D images, 3D volumes), the
tile grid and active bitmap have one axis per spatial axis, and dirty marks
cover the full Moore neighborhood of a tile — every face, edge and (in 3D)
corner ghost a conn26 update can stale.  All blocking math comes from
:class:`repro.core.geometry.Geometry`.

Persistent round state (DESIGN.md §2.6): the engine is split into
``prepare`` (build the padded planes + active-tile queue once — a
:class:`TiledRunState` carrier), a pure ``step``/``drain`` that advances the
carrier, and ``finalize`` (strip the padding, apply the invalid-pixel
contract once).  Re-entry — the composed `shard_map-tiled` engine's BP
rounds, truncation re-drains — goes through :func:`reseed` on the *same*
carrier instead of re-padding and re-building the queue from scratch.  The
jitted drain is compiled once per :class:`TiledPlan` through the shared
compile cache (``repro.core.compile_cache``) and donates the carrier, so
repeated entries update the padded buffers in place on backends that
support donation.  :func:`run_tiled` stays as the thin
prepare→drain→finalize wrapper with the historical signature.

The engine is fully jittable; the per-tile inner solver can be swapped for
the Pallas kernel (`repro.kernels.ops`) via ``tile_solver`` (and its
grid-over-batch form via ``batched_tile_solver``).
"""

from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compile_cache
from repro.core.geometry import (Geometry, _moore_offsets, tree_spatial_shape,
                                 unravel_index)
from repro.core.pattern import PropagationOp, restore_invalid, shiftnd


class TileStats(NamedTuple):
    outer_rounds: jnp.ndarray
    tiles_processed: jnp.ndarray
    overflow_events: jnp.ndarray   # rounds where active > capacity (paper §5.2.4)
    tiles_requeued: jnp.ndarray    # drains cut off at max_iters -> self-requeued


class TiledPlan(NamedTuple):
    """Static (hashable) description of one tiled run — the jit key.

    Everything that shapes the compiled drain lives here: the op, the
    blocking, the queue geometry, and the (optional) solver callables.
    Two solves with equal plans share one compiled step through the
    compile cache; the dynamic data rides in :class:`TiledRunState`.
    """
    op: PropagationOp
    tile: int
    shape: Tuple[int, ...]  # original (unpadded) spatial domain
    grid: Tuple[int, ...]   # tiles per spatial axis of the padded layout
    queue_capacity: int    # clipped to the tile-grid size
    K: int                 # blocks drained concurrently per dispatch
    n_chunks: int          # queue slots = n_chunks * K
    max_outer_rounds: int
    tile_solver: Optional[Callable]
    batched_tile_solver: Optional[Callable]

    @property
    def n_slots(self) -> int:
        return self.n_chunks * self.K

    # 2D-compat spellings (the composed shard_map-tiled engine is 2D-only)
    @property
    def H(self) -> int:
        return self.shape[0]

    @property
    def W(self) -> int:
        return self.shape[1]

    @property
    def nty(self) -> int:
        return self.grid[0]

    @property
    def ntx(self) -> int:
        return self.grid[1]


class TiledRunState(NamedTuple):
    """The persistent device-resident carrier (DESIGN.md §2.6).

    ``padded``: the op state in padded layout — a +1 halo ring plus
    padding up to a tile multiple (`_pad_state`), built once by
    :func:`prepare` and updated in place by the donated drain.
    ``active``: the tile-grid active-tile queue bitmap.
    ``stats``: cumulative :class:`TileStats` across every (re-)entry.
    """
    padded: dict
    active: jnp.ndarray
    stats: TileStats


def _geom(op: PropagationOp, tile: int) -> Geometry:
    return Geometry.of(op.ndim, tile)


def _pad_state(op, state, tile: int):
    """Pad spatially: +1 halo ring plus padding up to a tile multiple.

    Extra padding area is marked invalid; neutral fill values guarantee the
    padding can never propagate (see PropagationOp.pad_value contract).
    """
    geom = _geom(op, tile)
    shape = geom.spatial(state)
    padded = geom.pad_state(state, op.pad_value(state))
    return padded, (shape, geom.grid(shape))


def _tile_local_solve(op: PropagationOp, block, max_iters: int):
    """Drain one tile: dense rounds on the (T+2, ...) halo block until stable.

    Seeded with an all-*valid* frontier (halo included) so incoming halo
    values propagate inward on the first round.  Invalid cells are excluded
    from the seed: `op.round` masks sources by the frontier, so seeding them
    would let invalid pixels (non-rectangular masks, engine padding) source
    one round of propagation.

    Returns ``(block, unconverged)``: ``unconverged`` is True iff the loop
    was cut off at ``max_iters`` with a non-empty frontier — the caller must
    treat the result as a *partial* drain and re-queue the tile, never as a
    fixed point.
    """
    frontier0 = jnp.ones(tree_spatial_shape(block, op.ndim), dtype=bool)
    if "valid" in block:
        frontier0 = frontier0 & block["valid"]

    def cond(c):
        _, f, it = c
        return jnp.any(f) & (it < max_iters)

    def body(c):
        blk, f, it = c
        blk, f = op.round(blk, f)
        return blk, f, it + 1

    block, f, _ = jax.lax.while_loop(cond, body, (block, frontier0, jnp.int32(0)))
    return block, jnp.any(f)


def active_tiles_from_frontier(op: PropagationOp, frontier, tile: int,
                               grid: Optional[Tuple[int, ...]] = None):
    """Tiles containing (or *adjacent to*) a frontier pixel.

    The frontier marks *source* pixels; a source on a tile border must also
    activate the receiving tile (its own tile may drain without any interior
    change, producing no neighbor marks).  Hence the 1-px dilation before
    the per-tile reduction.  This is also the BP->TP seam of the composed
    `shard_map-tiled` engine: each BP round seeds the per-device queue with
    exactly the tiles the halo exchange improved (core/distributed.py).
    """
    ndim = op.ndim
    spatial = frontier.shape[-ndim:]
    if grid is None:
        grid = tuple(-(-s // tile) for s in spatial)
    dil = frontier
    for off in op.offsets:
        dil = dil | shiftnd(frontier, off, False)
    fp = jnp.pad(dil, [(0, g * tile - s) for g, s in zip(grid, spatial)])
    inter = []
    for g in grid:
        inter += [g, tile]
    return fp.reshape(tuple(inter)).any(
        axis=tuple(range(1, 2 * ndim, 2)))


def initial_active_tiles(op: PropagationOp, state, tile: int,
                         grid: Optional[Tuple[int, ...]] = None):
    """Tiles activated by the op's own initial frontier (see
    :func:`active_tiles_from_frontier` for the dilation argument)."""
    return active_tiles_from_frontier(op, op.init_frontier(state), tile, grid)


def default_tile_solver(op: PropagationOp, tile: int) -> Callable:
    """The plain dense drain at the engine's prod(T+2) geodesic bound.

    This is `run_tiled`'s default per-tile solver, exposed so other queue
    consumers (the host scheduler's jitted drain, the hybrid engine's
    device workers — DESIGN.md §2.3) run the *same* solver under the same
    truncation contract: returns ``(block, unconverged)``.
    """
    bound = _geom(op, tile).geodesic_bound
    return lambda blk: _tile_local_solve(op, blk, max_iters=bound)


def default_batched_solver(op: PropagationOp, tile: int) -> Callable:
    """`jax.vmap` of :func:`default_tile_solver` over a leading (K,) batch
    dim — the `batched_tile_solver` contract (blocks, unconverged[K])."""
    return jax.vmap(default_tile_solver(op, tile))


def _gather_block(padded, tco, tile: int):
    """Slice one (T+2, ...) halo block at tile coords ``tco`` (one scalar
    per spatial axis)."""
    ndim = len(tco)
    start = tuple(t * tile for t in tco)
    return jax.tree_util.tree_map(
        lambda x: jax.lax.dynamic_slice(
            x, (0,) * (x.ndim - ndim) + start,
            x.shape[:-ndim] + (tile + 2,) * ndim),
        padded)


def _interior_writeback(padded, block, tco, tile: int, mutable):
    """Write one block's interior back into the padded state (disjoint)."""
    ndim = len(tco)

    def wb(x, b):
        inner = jax.lax.slice(b, (0,) * (b.ndim - ndim) + (1,) * ndim,
                              b.shape[:-ndim] + (tile + 1,) * ndim)
        start = (0,) * (x.ndim - ndim) + tuple(t * tile + 1 for t in tco)
        return jax.lax.dynamic_update_slice(x, inner, start)

    new_padded = dict(padded)
    for k in mutable:
        new_padded[k] = wb(padded[k], block[k])
    return new_padded


def _faces_changed(pre, post, tile: int, mutable, ndim: int):
    """Did the block's interior face planes change?  (drives marking)

    Returns 2*ndim flags in (axis0-lo, axis0-hi, axis1-lo, axis1-hi, ...)
    order — the 2D spelling was (top, bot, lef, rig).
    """
    i0, i1 = 1, tile + 1

    def ch(sel):
        return jnp.array([jnp.any(pre[k][sel] != post[k][sel]) for k in mutable]).any()

    interior = tuple(slice(i0, i1) for _ in range(ndim))
    flags = []
    for a in range(ndim):
        lo = (Ellipsis,) + interior[:a] + (slice(i0, i0 + 1),) + interior[a + 1:]
        hi = (Ellipsis,) + interior[:a] + (slice(i1 - 1, i1),) + interior[a + 1:]
        flags.append(ch(lo))
        flags.append(ch(hi))
    return tuple(flags)


def _mark_neighbors(marks, tco, faces, grid):
    """Scatter-max dirty marks onto the full Moore neighborhood of tiles
    (8 in 2D, 26 in 3D — an edge/corner ghost is stale iff *any* of the
    faces it projects onto changed).  ``tco`` entries and the face flags
    may be scalars (sequential path) or (K,) vectors (batched)."""
    ndim = len(grid)
    for d in _moore_offsets(ndim, ndim):
        flag = None
        for a, da in enumerate(d):
            if da == 0:
                continue
            f = faces[2 * a + (0 if da < 0 else 1)]
            flag = f if flag is None else (flag | f)
        idx, inb = [], None
        for c, da, g in zip(tco, d, grid):
            nc = c + da
            idx.append(jnp.clip(nc, 0, g - 1))
            ib = (nc >= 0) & (nc < g)
            inb = ib if inb is None else (inb & ib)
        marks = marks.at[tuple(idx)].max(flag & inb)
    return marks


# ---------------------------------------------------------------------------
# Persistent round state: prepare / step / drain / reseed / finalize.
# ---------------------------------------------------------------------------

def _mutable_keys(plan: TiledPlan, padded) -> list:
    return [k for k in padded.keys() if k not in plan.op.static_leaves]


def prepare(op: PropagationOp, state, tile: int = 128,
            queue_capacity: int = 256, max_outer_rounds: int = 100_000,
            tile_solver: Optional[Callable] = None, drain_batch: int = 1,
            batched_tile_solver: Optional[Callable] = None,
            initial_active: Optional[jnp.ndarray] = None):
    """Build the run once: ``(TiledPlan, TiledRunState)``.

    The plan is hashable (the jit key); the run state carries the padded
    planes, the active-tile bitmap and zeroed stats.  Works both eagerly
    and under an outer trace (the composed engine calls it inside
    ``shard_map``).
    """
    padded, (shape, grid) = _pad_state(op, state, tile)
    # a queue longer than the tile grid only adds dead scan slots
    queue_capacity = min(queue_capacity, math.prod(grid))
    K = max(1, min(drain_batch, queue_capacity))
    # queue slots rounded up to whole batches (a dead slot drains a
    # neutralized block — cheap, and its writeback is the identity)
    n_chunks = -(-queue_capacity // K)
    plan = TiledPlan(op, tile, shape, grid, queue_capacity, K, n_chunks,
                     max_outer_rounds, tile_solver, batched_tile_solver)
    active0 = (initial_active if initial_active is not None
               else initial_active_tiles(op, state, tile, grid))
    stats0 = TileStats(jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0))
    return plan, TiledRunState(padded, active0, stats0)


def reseed(plan: TiledPlan, run_state: TiledRunState,
           active: Optional[jnp.ndarray] = None,
           frontier: Optional[jnp.ndarray] = None) -> TiledRunState:
    """Re-enter the carrier: OR new activations into the resident queue.

    ``active`` is a tile-grid bitmap; ``frontier`` a pixel plane in
    *padded* layout (compacted to tiles via
    :func:`active_tiles_from_frontier`).  The padded buffers and stats are
    untouched — this is the BP→TP seam that used to re-pad the whole shard.
    """
    add = jnp.zeros(plan.grid, dtype=bool)
    if active is not None:
        add = add | active
    if frontier is not None:
        add = add | active_tiles_from_frontier(
            plan.op, frontier, plan.tile, plan.grid)
    return run_state._replace(active=run_state.active | add)


def step(plan: TiledPlan, run_state: TiledRunState) -> TiledRunState:
    """One outer queue round: compact the bitmap, drain ≤ capacity tiles,
    re-mark dirty neighbors.  Pure/traceable — usable inside `shard_map`
    traces and `while_loop` bodies alike."""
    op, tile = plan.op, plan.tile
    grid, K, n_chunks = plan.grid, plan.K, plan.n_chunks
    ndim = op.ndim
    n_slots = plan.n_slots
    padded, active, stats = run_state
    mutable = _mutable_keys(plan, padded)
    solver = plan.tile_solver or default_tile_solver(op, tile)
    pv = op.pad_value(padded)

    def process_tile(padded, tid):
        """Sequential path: drain one live queue slot (the dynamic chunk
        loop below never hands this a dead slot)."""
        tco = unravel_index(tid, grid)
        block = _gather_block(padded, tco, tile)
        pre = {k: block[k] for k in mutable}
        block, unconv = solver(block)
        post = {k: block[k] for k in mutable}
        new_padded = _interior_writeback(padded, post, tco, tile, mutable)
        faces = _faces_changed(pre, post, tile, mutable, ndim)
        marks = jnp.zeros(grid, dtype=bool)
        marks = _mark_neighbors(marks, tco, faces, grid)
        # Partial drain: the tile is NOT at a fixed point — self-mark it
        # so it stays in the queue (the truncation self-requeue).
        marks = marks.at[tuple(tco)].max(unconv)
        return new_padded, (marks, unconv.astype(jnp.int32))

    def process_chunk(padded, ids_k):
        """Drain one (K,)-batch of queue slots concurrently.  Only the last
        live chunk can carry dead slots (live count not a K multiple)."""
        live = ids_k >= 0
        safe = jnp.maximum(ids_k, 0)
        tcos = unravel_index(safe, grid)   # tuple of (K,) per-axis coords
        blocks = jax.vmap(
            lambda *tco: _gather_block(padded, tco, tile))(*tcos)
        # Dead slots alias tile 0; neutralize them so they converge
        # immediately and mark nothing.
        blocks = jax.tree_util.tree_map(
            lambda x, v: jnp.where(
                live.reshape((-1,) + (1,) * (x.ndim - 1)), x, jnp.asarray(v, x.dtype)),
            blocks, pv)
        pre = {k: blocks[k] for k in mutable}
        batched_solver = plan.batched_tile_solver or jax.vmap(solver)
        post, unconv = batched_solver(blocks)
        faces = jax.vmap(
            lambda p, q: _faces_changed(p, q, tile, mutable, ndim)
        )(pre, {k: post[k] for k in mutable})
        marks = jnp.zeros(grid, dtype=bool)
        marks = _mark_neighbors(marks, tcos, tuple(f & live for f in faces),
                                grid)
        # Partial drains self-requeue (dead slots never do: unconv & live).
        unconv = unconv & live
        marks = marks.at[tcos].max(unconv)

        def scatter(padded, slot):
            """Per-slot interior write.  A dead slot (aliasing tile 0) must
            not regress a live write of the same tile earlier in this scan,
            so the dead branch re-reads the *current* interior at scatter
            time instead of writing the neutralized drain result."""
            tco, block, live_i = slot

            def wb(x, b):
                inner = jax.lax.slice(b, (0,) * (b.ndim - ndim) + (1,) * ndim,
                                      b.shape[:-ndim] + (tile + 1,) * ndim)
                start = (0,) * (x.ndim - ndim) + tuple(t * tile + 1 for t in tco)
                cur = jax.lax.dynamic_slice(x, start, x.shape[:-ndim] + (tile,) * ndim)
                return jax.lax.dynamic_update_slice(
                    x, jnp.where(live_i, inner, cur), start)

            new = dict(padded)
            for k in mutable:
                new[k] = wb(padded[k], block[k])
            return new, None

        padded, _ = jax.lax.scan(
            scatter, padded, (tcos, {k: post[k] for k in mutable}, live))
        return padded, (marks, jnp.sum(unconv, dtype=jnp.int32))

    flat = active.reshape(-1)
    (ids,) = jnp.where(flat, size=n_slots, fill_value=-1)
    n_active = jnp.sum(flat)
    n_live = jnp.minimum(n_active, n_slots).astype(jnp.int32)
    processed = jnp.zeros_like(flat).at[jnp.maximum(ids, 0)].max(ids >= 0).reshape(grid)
    marks0 = jnp.zeros(grid, dtype=bool)
    # Dynamic trip count: only *live* chunks run.  A mostly-empty queue
    # (sparse wavefronts, BP re-entries touching a few border tiles) costs
    # its live tiles, not the full slot count — the fixed per-round overhead
    # the composed engines used to pay on every nearly-idle round.
    if K > 1:
        n_live_chunks = -(-n_live // K)

        def chunk_body(c):
            i, padded, marks, req = c
            ids_k = jax.lax.dynamic_slice(ids, (i * K,), (K,))
            padded, (m, rq) = process_chunk(padded, ids_k)
            return i + 1, padded, marks | m, req + rq

        _, padded, marks, requeued = jax.lax.while_loop(
            lambda c: c[0] < n_live_chunks, chunk_body,
            (jnp.int32(0), padded, marks0, jnp.int32(0)))
    else:
        def slot_body(c):
            i, padded, marks, req = c
            padded, (m, rq) = process_tile(padded, ids[i])
            return i + 1, padded, marks | m, req + rq

        _, padded, marks, requeued = jax.lax.while_loop(
            lambda c: c[0] < n_live, slot_body,
            (jnp.int32(0), padded, marks0, jnp.int32(0)))
    # Retain overflowed (unprocessed) tiles; add freshly-dirtied ones
    # (including unconverged self-marks — partial drains re-queue).
    active = (active & ~processed) | marks
    stats = TileStats(
        stats.outer_rounds + 1,
        stats.tiles_processed + jnp.sum(ids >= 0),
        stats.overflow_events + (n_active > n_slots).astype(jnp.int32),
        stats.tiles_requeued + jnp.sum(requeued))
    return TiledRunState(padded, active, stats)


def drain(plan: TiledPlan, run_state: TiledRunState) -> TiledRunState:
    """Run :func:`step` until the active queue empties (or the round bound).
    Pure/traceable; the eager entry point is :func:`drain_fn`."""
    def cond(rs):
        return jnp.any(rs.active) & (rs.stats.outer_rounds < plan.max_outer_rounds)
    return jax.lax.while_loop(cond, lambda rs: step(plan, rs), run_state)


def _donate_argnums() -> tuple:
    # CPU XLA has no buffer donation — requesting it only produces a
    # "donated buffers were not usable" warning per call.
    return () if jax.default_backend() == "cpu" else (0,)


def drain_fn(plan: TiledPlan) -> Callable:
    """The compiled re-entrant drain for ``plan``: one build per plan via
    the shared compile cache, carrier donated on backends that support it.
    ``drain_fn(plan)(run_state) -> run_state``."""
    return compile_cache.get(
        ("tiled-drain", plan.op, plan),
        lambda: jax.jit(lambda rs: drain(plan, rs),
                        donate_argnums=_donate_argnums()))


def finalize(plan: TiledPlan, run_state: TiledRunState, ref_state,
             restore: bool = True):
    """Strip the padding back to the domain; apply the invalid-pixel
    contract against ``ref_state`` (the original input) unless the caller
    owns that boundary (``restore=False`` — nested engine use)."""
    ndim = plan.op.ndim

    def run(rs, ref):
        out = jax.tree_util.tree_map(
            lambda x: jax.lax.slice(
                x, (0,) * (x.ndim - ndim) + (1,) * ndim,
                x.shape[:-ndim] + tuple(1 + s for s in plan.shape)), rs.padded)
        return restore_invalid(plan.op, ref, out) if restore else out
    leaves = jax.tree_util.tree_leaves((run_state, ref_state))
    if any(isinstance(l, jax.core.Tracer) for l in leaves):
        return run(run_state, ref_state)
    fn = compile_cache.get(("tiled-finalize", plan.op, plan, restore),
                           lambda: jax.jit(run))
    return fn(run_state, ref_state)


def run_tiled(op: PropagationOp, state, tile: int = 128, queue_capacity: int = 256,
              max_outer_rounds: int = 100_000,
              tile_solver: Optional[Callable] = None,
              drain_batch: int = 1,
              batched_tile_solver: Optional[Callable] = None,
              initial_active: Optional[jnp.ndarray] = None,
              restore: bool = True):
    """Run `op` to the global fixed point with the tiled active-set engine.

    Thin wrapper: ``prepare`` → compiled ``drain`` → ``finalize``
    (DESIGN.md §2.6).  Callers that re-enter the drain (BP rounds) should
    hold the ``(plan, run_state)`` pair themselves via
    :func:`prepare`/:func:`reseed`/:func:`step` instead of paying the
    pad/strip round trip per entry.

    ``drain_batch`` > 1 drains the compacted queue in parallel batches of
    (up to) that many (T+2, ...) halo blocks per dispatch: blocks are
    gathered into a (K, T+2, ...) batch, drained concurrently by
    ``batched_tile_solver`` (default: ``jax.vmap`` of the per-tile solver),
    and their interiors scattered back.  Interior writes are disjoint;
    halo values a concurrent neighbor would have refreshed are handled by
    the dirty-neighbor re-marking, and monotone-commutative updates make
    the result exact either way.  ``drain_batch <= 1`` keeps the sequential
    ``lax.scan`` drain.

    Tile solvers map a halo-block pytree to ``(drained block, unconverged)``
    — an ``unconverged`` drain (cut off at the solver's iteration bound) is
    a *partial* result, so the engine re-queues that tile (self-mark) until
    a drain reaches stability.  Without this, a tile whose internal geodesic
    exceeds the bound would be dequeued with a silently-wrong fixed point.

    ``initial_active``: optional tile-grid bool plane overriding the
    op-derived initial queue — the seam the composed `shard_map-tiled`
    engine uses to seed each BP round from only the halo-improved tiles.

    ``restore=False`` skips the final invalid-pixel restore (an O(area)
    `where` over every mutable leaf) — for *nested* use only, where the
    outer engine applies the contract once at its own boundary.
    """
    plan, rs = prepare(op, state, tile=tile, queue_capacity=queue_capacity,
                       max_outer_rounds=max_outer_rounds,
                       tile_solver=tile_solver, drain_batch=drain_batch,
                       batched_tile_solver=batched_tile_solver,
                       initial_active=initial_active)
    if any(isinstance(l, jax.core.Tracer) for l in jax.tree_util.tree_leaves(state)):
        rs = drain(plan, rs)           # inline into the caller's trace
    else:
        rs = drain_fn(plan)(rs)        # compiled once per plan, donated
    return finalize(plan, rs, state, restore), rs.stats
