"""E2: the tiled active-set engine — TPU analogue of the paper's
multi-level queue (§3.2).

Hierarchy mapping (DESIGN.md §2):
  * within a tile, propagation is dense vector work in VMEM (BQ analogue);
    the tile iterates *locally to stability* before returning — one "queue
    drain" per activation, amortizing HBM traffic exactly like the paper
    amortizes shared-memory traffic;
  * across tiles, a fixed-capacity **active-tile queue** lives at the outer
    level (GBQ analogue).  Each outer round compacts the active bitmap into
    at most ``queue_capacity`` tile ids (`jnp.where(..., size=)` — the
    prefix-sum of the paper, done by XLA), processes them sequentially under
    `lax.scan` (monotone commutative updates make any order valid), and
    marks neighbor tiles whose halo became stale.
  * overflow: tiles beyond capacity are simply *retained* in the bitmap for
    the next round — the same re-execution-from-partial-output semantics as
    the paper's §5.2.4 GBQ overflow, without ever dropping information.

The engine is fully jittable; the per-tile inner solver can be swapped for
the Pallas kernel (`repro.kernels.ops`) via ``tile_solver``.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.pattern import PropagationOp, tree_shape


class TileStats(NamedTuple):
    outer_rounds: jnp.ndarray
    tiles_processed: jnp.ndarray
    overflow_events: jnp.ndarray   # rounds where active > capacity (paper §5.2.4)


def _pad_state(op, state, tile: int):
    """Pad spatially: +1 halo ring plus padding up to a tile multiple.

    Extra padding area is marked invalid; neutral fill values guarantee the
    padding can never propagate (see PropagationOp.pad_value contract).
    """
    H, W = tree_shape(state)
    Ht = -(-H // tile) * tile
    Wt = -(-W // tile) * tile
    pads = ((1, Ht - H + 1), (1, Wt - W + 1))
    pv = op.pad_value(state)
    padded = jax.tree_util.tree_map(
        lambda x, v: jnp.pad(x, [(0, 0)] * (x.ndim - 2) + list(pads), constant_values=v),
        state, pv)
    return padded, (H, W, Ht // tile, Wt // tile)


def _tile_local_solve(op: PropagationOp, block, max_iters: int):
    """Drain one tile: dense rounds on the (T+2, T+2) halo block until stable.

    Seeded with an all-true frontier (halo included) so incoming halo values
    propagate inward on the first round.
    """
    frontier0 = jnp.ones(tree_shape(block), dtype=bool)

    def cond(c):
        _, f, it = c
        return jnp.any(f) & (it < max_iters)

    def body(c):
        blk, f, it = c
        blk, f = op.round(blk, f)
        return blk, f, it + 1

    block, _, _ = jax.lax.while_loop(cond, body, (block, frontier0, jnp.int32(0)))
    return block


def initial_active_tiles(op: PropagationOp, state, tile: int,
                         nty: int = None, ntx: int = None):
    """Tiles containing (or *adjacent to*) an initial-frontier pixel.

    The frontier condition marks *source* pixels; a source on a tile border
    must also activate the receiving tile (its own tile may drain without
    any interior change, producing no neighbor marks).  Hence the 1-px
    dilation before the per-tile reduction.
    """
    H, W = tree_shape(state)
    if nty is None:
        nty, ntx = -(-H // tile), -(-W // tile)
    f0 = op.init_frontier(state)
    dil = f0
    for dr, dc in op.offsets:
        from repro.core.pattern import shift2d
        dil = dil | shift2d(f0, dr, dc, False)
    fp = jnp.pad(dil, ((0, nty * tile - H), (0, ntx * tile - W)))
    return fp.reshape(nty, tile, ntx, tile).any(axis=(1, 3))


@partial(jax.jit, static_argnums=(0, 2, 3, 4, 5))
def run_tiled(op: PropagationOp, state, tile: int = 128, queue_capacity: int = 256,
              max_outer_rounds: int = 100_000,
              tile_solver: Optional[Callable] = None):
    """Run `op` to the global fixed point with the tiled active-set engine."""
    # (T+2)^2 bounds the longest geodesic inside one halo block (a spiral
    # path); the while_loop exits at stability so the bound is free normally.
    solver = tile_solver or (lambda blk: _tile_local_solve(op, blk,
                                                           max_iters=(tile + 2) ** 2))
    padded, (H, W, nty, ntx) = _pad_state(op, state, tile)
    # a queue longer than the tile grid only adds dead scan slots
    queue_capacity = min(queue_capacity, nty * ntx)

    active0 = initial_active_tiles(op, state, tile, nty, ntx)

    mutable = [k for k in padded.keys() if k not in op.static_leaves]

    def process_tile(carry, tid):
        padded = carry
        ty = tid // ntx
        tx = tid % ntx

        def do(padded):
            start = (ty * tile, tx * tile)
            block = jax.tree_util.tree_map(
                lambda x: jax.lax.dynamic_slice(
                    x, (0,) * (x.ndim - 2) + start,
                    x.shape[:-2] + (tile + 2, tile + 2)),
                padded)
            pre = {k: block[k] for k in mutable}
            block = solver(block)
            # Write back interior only.
            def wb(x, b):
                inner = jax.lax.slice(b, (0,) * (b.ndim - 2) + (1, 1),
                                      b.shape[:-2] + (tile + 1, tile + 1))
                return jax.lax.dynamic_update_slice(
                    x, inner, (0,) * (x.ndim - 2) + (start[0] + 1, start[1] + 1))
            new_padded = dict(padded)
            for k in mutable:
                new_padded[k] = wb(padded[k], block[k])

            # Which edges of the interior changed?  (drives neighbor marking)
            def edge_changed(sel):
                return jnp.array([jnp.any(pre[k][sel] != block[k][sel]) for k in mutable]).any()
            i0, i1 = 1, tile + 1
            top = edge_changed((Ellipsis, slice(i0, i0 + 1), slice(i0, i1)))
            bot = edge_changed((Ellipsis, slice(i1 - 1, i1), slice(i0, i1)))
            lef = edge_changed((Ellipsis, slice(i0, i1), slice(i0, i0 + 1)))
            rig = edge_changed((Ellipsis, slice(i0, i1), slice(i1 - 1, i1)))
            marks = jnp.zeros((nty, ntx), dtype=bool)
            def mark(m, dy, dx, flag):
                yy = jnp.clip(ty + dy, 0, nty - 1)
                xx = jnp.clip(tx + dx, 0, ntx - 1)
                inb = ((ty + dy) >= 0) & ((ty + dy) < nty) & ((tx + dx) >= 0) & ((tx + dx) < ntx)
                return m.at[yy, xx].max(flag & inb)
            marks = mark(marks, -1, 0, top); marks = mark(marks, -1, -1, top | lef)
            marks = mark(marks, -1, 1, top | rig); marks = mark(marks, 1, 0, bot)
            marks = mark(marks, 1, -1, bot | lef); marks = mark(marks, 1, 1, bot | rig)
            marks = mark(marks, 0, -1, lef); marks = mark(marks, 0, 1, rig)
            return new_padded, marks

        def skip(padded):
            return padded, jnp.zeros((nty, ntx), dtype=bool)

        padded, marks = jax.lax.cond(tid >= 0, do, skip, padded)
        return padded, marks

    def outer_cond(carry):
        padded, active, stats = carry
        return jnp.any(active) & (stats.outer_rounds < max_outer_rounds)

    def outer_body(carry):
        padded, active, stats = carry
        flat = active.reshape(-1)
        (ids,) = jnp.where(flat, size=queue_capacity, fill_value=-1)
        n_active = jnp.sum(flat)
        processed = jnp.zeros_like(flat).at[jnp.maximum(ids, 0)].max(ids >= 0).reshape(nty, ntx)
        padded, marks = jax.lax.scan(process_tile, padded, ids)
        dirty = jnp.any(marks, axis=0)
        # Retain overflowed (unprocessed) tiles; add freshly-dirtied ones.
        active = (active & ~processed) | dirty
        stats = TileStats(
            stats.outer_rounds + 1,
            stats.tiles_processed + jnp.sum(ids >= 0),
            stats.overflow_events + (n_active > queue_capacity).astype(jnp.int32))
        return padded, active, stats

    stats0 = TileStats(jnp.int32(0), jnp.int32(0), jnp.int32(0))
    padded, _, stats = jax.lax.while_loop(outer_cond, outer_body, (padded, active0, stats0))

    # Strip padding back to the original domain.
    out = jax.tree_util.tree_map(
        lambda x: jax.lax.slice(x, (0,) * (x.ndim - 2) + (1, 1),
                                x.shape[:-2] + (1 + H, 1 + W)), padded)
    return out, stats
