"""E2: the tiled active-set engine — TPU analogue of the paper's
multi-level queue (§3.2).

Hierarchy mapping (DESIGN.md §2):
  * within a tile, propagation is dense vector work in VMEM (BQ analogue);
    the tile iterates *locally to stability* before returning — one "queue
    drain" per activation, amortizing HBM traffic exactly like the paper
    amortizes shared-memory traffic;
  * across tiles, a fixed-capacity **active-tile queue** lives at the outer
    level (GBQ analogue).  Each outer round compacts the active bitmap into
    at most ``queue_capacity`` tile ids (`jnp.where(..., size=)` — the
    prefix-sum of the paper, done by XLA) and drains them — **in parallel
    batches of ``drain_batch`` blocks** (the paper's concurrent consumption
    of the global queue across SMs, §3.2) or sequentially under `lax.scan`
    when ``drain_batch <= 1`` — then marks neighbor tiles whose halo became
    stale.  Monotone commutative updates make any order (and any degree of
    concurrency) reach the same fixed point; interior writes of distinct
    tiles are disjoint, and a stale halo read at worst re-queues a tile via
    the dirty-neighbor marks.
  * overflow: tiles beyond capacity are simply *retained* in the bitmap for
    the next round — the same re-execution-from-partial-output semantics as
    the paper's §5.2.4 GBQ overflow, without ever dropping information.

The engine is fully jittable; the per-tile inner solver can be swapped for
the Pallas kernel (`repro.kernels.ops`) via ``tile_solver`` (and its
grid-over-batch form via ``batched_tile_solver``).
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.pattern import PropagationOp, restore_invalid, tree_shape


class TileStats(NamedTuple):
    outer_rounds: jnp.ndarray
    tiles_processed: jnp.ndarray
    overflow_events: jnp.ndarray   # rounds where active > capacity (paper §5.2.4)
    tiles_requeued: jnp.ndarray    # drains cut off at max_iters -> self-requeued


def _pad_state(op, state, tile: int):
    """Pad spatially: +1 halo ring plus padding up to a tile multiple.

    Extra padding area is marked invalid; neutral fill values guarantee the
    padding can never propagate (see PropagationOp.pad_value contract).
    """
    H, W = tree_shape(state)
    Ht = -(-H // tile) * tile
    Wt = -(-W // tile) * tile
    pads = ((1, Ht - H + 1), (1, Wt - W + 1))
    pv = op.pad_value(state)
    padded = jax.tree_util.tree_map(
        lambda x, v: jnp.pad(x, [(0, 0)] * (x.ndim - 2) + list(pads), constant_values=v),
        state, pv)
    return padded, (H, W, Ht // tile, Wt // tile)


def _tile_local_solve(op: PropagationOp, block, max_iters: int):
    """Drain one tile: dense rounds on the (T+2, T+2) halo block until stable.

    Seeded with an all-*valid* frontier (halo included) so incoming halo
    values propagate inward on the first round.  Invalid cells are excluded
    from the seed: `op.round` masks sources by the frontier, so seeding them
    would let invalid pixels (non-rectangular masks, engine padding) source
    one round of propagation.

    Returns ``(block, unconverged)``: ``unconverged`` is True iff the loop
    was cut off at ``max_iters`` with a non-empty frontier — the caller must
    treat the result as a *partial* drain and re-queue the tile, never as a
    fixed point.
    """
    frontier0 = jnp.ones(tree_shape(block), dtype=bool)
    if "valid" in block:
        frontier0 = frontier0 & block["valid"]

    def cond(c):
        _, f, it = c
        return jnp.any(f) & (it < max_iters)

    def body(c):
        blk, f, it = c
        blk, f = op.round(blk, f)
        return blk, f, it + 1

    block, f, _ = jax.lax.while_loop(cond, body, (block, frontier0, jnp.int32(0)))
    return block, jnp.any(f)


def active_tiles_from_frontier(op: PropagationOp, frontier, tile: int,
                               nty: int, ntx: int):
    """Tiles containing (or *adjacent to*) a frontier pixel.

    The frontier marks *source* pixels; a source on a tile border must also
    activate the receiving tile (its own tile may drain without any interior
    change, producing no neighbor marks).  Hence the 1-px dilation before
    the per-tile reduction.  This is also the BP->TP seam of the composed
    `shard_map-tiled` engine: each BP round seeds the per-device queue with
    exactly the tiles the halo exchange improved (core/distributed.py).
    """
    from repro.core.pattern import shift2d
    H, W = frontier.shape[-2:]
    dil = frontier
    for dr, dc in op.offsets:
        dil = dil | shift2d(frontier, dr, dc, False)
    fp = jnp.pad(dil, ((0, nty * tile - H), (0, ntx * tile - W)))
    return fp.reshape(nty, tile, ntx, tile).any(axis=(1, 3))


def initial_active_tiles(op: PropagationOp, state, tile: int,
                         nty: int = None, ntx: int = None):
    """Tiles activated by the op's own initial frontier (see
    :func:`active_tiles_from_frontier` for the dilation argument)."""
    H, W = tree_shape(state)
    if nty is None:
        nty, ntx = -(-H // tile), -(-W // tile)
    return active_tiles_from_frontier(op, op.init_frontier(state), tile, nty, ntx)


def default_tile_solver(op: PropagationOp, tile: int) -> Callable:
    """The plain dense drain at the engine's (T+2)² geodesic bound.

    This is `run_tiled`'s default per-tile solver, exposed so other queue
    consumers (the host scheduler's jitted drain, the hybrid engine's
    device workers — DESIGN.md §2.3) run the *same* solver under the same
    truncation contract: returns ``(block, unconverged)``.
    """
    return lambda blk: _tile_local_solve(op, blk, max_iters=(tile + 2) ** 2)


def default_batched_solver(op: PropagationOp, tile: int) -> Callable:
    """`jax.vmap` of :func:`default_tile_solver` over a leading (K,) batch
    dim — the `batched_tile_solver` contract (blocks, unconverged[K])."""
    return jax.vmap(default_tile_solver(op, tile))


def _gather_block(padded, ty, tx, tile: int):
    start = (ty * tile, tx * tile)
    return jax.tree_util.tree_map(
        lambda x: jax.lax.dynamic_slice(
            x, (0,) * (x.ndim - 2) + start,
            x.shape[:-2] + (tile + 2, tile + 2)),
        padded)


def _interior_writeback(padded, block, ty, tx, tile: int, mutable):
    """Write one block's interior back into the padded state (disjoint)."""
    def wb(x, b):
        inner = jax.lax.slice(b, (0,) * (b.ndim - 2) + (1, 1),
                              b.shape[:-2] + (tile + 1, tile + 1))
        return jax.lax.dynamic_update_slice(
            x, inner, (0,) * (x.ndim - 2) + (ty * tile + 1, tx * tile + 1))
    new_padded = dict(padded)
    for k in mutable:
        new_padded[k] = wb(padded[k], block[k])
    return new_padded


def _edges_changed(pre, post, tile: int, mutable):
    """Did the block's interior edge rows/cols change?  (drives marking)"""
    i0, i1 = 1, tile + 1
    def ch(sel):
        return jnp.array([jnp.any(pre[k][sel] != post[k][sel]) for k in mutable]).any()
    top = ch((Ellipsis, slice(i0, i0 + 1), slice(i0, i1)))
    bot = ch((Ellipsis, slice(i1 - 1, i1), slice(i0, i1)))
    lef = ch((Ellipsis, slice(i0, i1), slice(i0, i0 + 1)))
    rig = ch((Ellipsis, slice(i0, i1), slice(i1 - 1, i1)))
    return top, bot, lef, rig


def _mark_neighbors(marks, ty, tx, top, bot, lef, rig, nty: int, ntx: int):
    """Scatter-max dirty marks onto the 8 neighbors.  ``ty``/``tx`` and the
    edge flags may be scalars (sequential path) or (K,) vectors (batched)."""
    def mark(m, dy, dx, flag):
        yy = jnp.clip(ty + dy, 0, nty - 1)
        xx = jnp.clip(tx + dx, 0, ntx - 1)
        inb = ((ty + dy) >= 0) & ((ty + dy) < nty) & ((tx + dx) >= 0) & ((tx + dx) < ntx)
        return m.at[yy, xx].max(flag & inb)
    marks = mark(marks, -1, 0, top); marks = mark(marks, -1, -1, top | lef)
    marks = mark(marks, -1, 1, top | rig); marks = mark(marks, 1, 0, bot)
    marks = mark(marks, 1, -1, bot | lef); marks = mark(marks, 1, 1, bot | rig)
    marks = mark(marks, 0, -1, lef); marks = mark(marks, 0, 1, rig)
    return marks


@partial(jax.jit, static_argnums=(0, 2, 3, 4, 5, 6, 7, 9))
def run_tiled(op: PropagationOp, state, tile: int = 128, queue_capacity: int = 256,
              max_outer_rounds: int = 100_000,
              tile_solver: Optional[Callable] = None,
              drain_batch: int = 1,
              batched_tile_solver: Optional[Callable] = None,
              initial_active: Optional[jnp.ndarray] = None,
              restore: bool = True):
    """Run `op` to the global fixed point with the tiled active-set engine.

    ``drain_batch`` > 1 drains the compacted queue in parallel batches of
    (up to) that many (T+2, T+2) halo blocks per dispatch: blocks are
    gathered into a (K, T+2, T+2) batch, drained concurrently by
    ``batched_tile_solver`` (default: ``jax.vmap`` of the per-tile solver),
    and their interiors scattered back.  Interior writes are disjoint;
    halo values a concurrent neighbor would have refreshed are handled by
    the dirty-neighbor re-marking, and monotone-commutative updates make
    the result exact either way.  ``drain_batch <= 1`` keeps the sequential
    ``lax.scan`` drain.

    Tile solvers map a halo-block pytree to ``(drained block, unconverged)``
    — an ``unconverged`` drain (cut off at the solver's iteration bound) is
    a *partial* result, so the engine re-queues that tile (self-mark) until
    a drain reaches stability.  Without this, a tile whose internal geodesic
    exceeds the bound would be dequeued with a silently-wrong fixed point.

    ``initial_active``: optional (nty, ntx) bool plane overriding the
    op-derived initial queue — the seam the composed `shard_map-tiled`
    engine uses to seed each BP round from only the halo-improved tiles.

    ``restore=False`` skips the final invalid-pixel restore (an O(area)
    `where` over every mutable leaf) — for *nested* use only, where the
    outer engine applies the contract once at its own boundary
    (`run_sharded` calls run_tiled per TP stage inside the BP loop).
    """
    # (T+2)^2 bounds the longest geodesic inside one halo block (a spiral
    # path); the while_loop exits at stability so the bound is free normally.
    solver = tile_solver or default_tile_solver(op, tile)
    padded, (H, W, nty, ntx) = _pad_state(op, state, tile)
    # a queue longer than the tile grid only adds dead scan slots
    queue_capacity = min(queue_capacity, nty * ntx)
    K = max(1, min(drain_batch, queue_capacity))
    # queue slots rounded up to whole batches (a dead slot drains a
    # neutralized block — cheap, and its writeback is skipped)
    n_chunks = -(-queue_capacity // K)
    n_slots = n_chunks * K

    active0 = (initial_active if initial_active is not None
               else initial_active_tiles(op, state, tile, nty, ntx))

    mutable = [k for k in padded.keys() if k not in op.static_leaves]

    def process_tile(carry, tid):
        padded = carry
        ty = tid // ntx
        tx = tid % ntx

        def do(padded):
            block = _gather_block(padded, ty, tx, tile)
            pre = {k: block[k] for k in mutable}
            block, unconv = solver(block)
            new_padded = _interior_writeback(padded, block, ty, tx, tile, mutable)
            top, bot, lef, rig = _edges_changed(pre, block, tile, mutable)
            marks = jnp.zeros((nty, ntx), dtype=bool)
            marks = _mark_neighbors(marks, ty, tx, top, bot, lef, rig, nty, ntx)
            # Partial drain: the tile is NOT at a fixed point — self-mark it
            # so it stays in the queue (the truncation bugfix).
            marks = marks.at[ty, tx].max(unconv)
            return new_padded, marks, unconv.astype(jnp.int32)

        def skip(padded):
            return padded, jnp.zeros((nty, ntx), dtype=bool), jnp.int32(0)

        padded, marks, requeued = jax.lax.cond(tid >= 0, do, skip, padded)
        return padded, (marks, requeued)

    if K > 1:
        batched_solver = batched_tile_solver or jax.vmap(solver)
        pv = op.pad_value(state)

    def process_chunk(carry, ids_k):
        """Drain one (K,)-batch of queue slots concurrently."""
        padded = carry
        live = ids_k >= 0
        safe = jnp.maximum(ids_k, 0)
        tys, txs = safe // ntx, safe % ntx
        blocks = jax.vmap(lambda ty, tx: _gather_block(padded, ty, tx, tile))(tys, txs)
        # Dead slots (queue shorter than a whole batch) alias tile 0;
        # neutralize them so they converge immediately and mark nothing.
        blocks = jax.tree_util.tree_map(
            lambda x, v: jnp.where(
                live.reshape((-1,) + (1,) * (x.ndim - 1)), x, jnp.asarray(v, x.dtype)),
            blocks, pv)
        pre = {k: blocks[k] for k in mutable}
        post, unconv = batched_solver(blocks)
        top, bot, lef, rig = jax.vmap(
            lambda p, q: _edges_changed(p, q, tile, mutable)
        )(pre, {k: post[k] for k in mutable})
        marks = jnp.zeros((nty, ntx), dtype=bool)
        marks = _mark_neighbors(marks, tys, txs, top & live, bot & live,
                                lef & live, rig & live, nty, ntx)
        # Partial drains self-requeue (dead slots never do: unconv & live).
        unconv = unconv & live
        marks = marks.at[tys, txs].max(unconv)

        def scatter(padded, slot):
            tid, ty, tx, block = slot
            new_padded = jax.lax.cond(
                tid >= 0,
                lambda p: _interior_writeback(p, block, ty, tx, tile, mutable),
                lambda p: p, padded)
            return new_padded, None

        padded, _ = jax.lax.scan(
            scatter, padded, (ids_k, tys, txs, {k: post[k] for k in mutable}))
        return padded, (marks, jnp.sum(unconv, dtype=jnp.int32))

    def outer_cond(carry):
        padded, active, stats = carry
        return jnp.any(active) & (stats.outer_rounds < max_outer_rounds)

    def outer_body(carry):
        padded, active, stats = carry
        flat = active.reshape(-1)
        (ids,) = jnp.where(flat, size=n_slots, fill_value=-1)
        n_active = jnp.sum(flat)
        processed = jnp.zeros_like(flat).at[jnp.maximum(ids, 0)].max(ids >= 0).reshape(nty, ntx)
        if K > 1:
            padded, (marks, requeued) = jax.lax.scan(
                process_chunk, padded, ids.reshape(n_chunks, K))
        else:
            padded, (marks, requeued) = jax.lax.scan(process_tile, padded, ids)
        dirty = jnp.any(marks, axis=0)
        # Retain overflowed (unprocessed) tiles; add freshly-dirtied ones
        # (including unconverged self-marks — partial drains re-queue).
        active = (active & ~processed) | dirty
        stats = TileStats(
            stats.outer_rounds + 1,
            stats.tiles_processed + jnp.sum(ids >= 0),
            stats.overflow_events + (n_active > n_slots).astype(jnp.int32),
            stats.tiles_requeued + jnp.sum(requeued))
        return padded, active, stats

    stats0 = TileStats(jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0))
    padded, _, stats = jax.lax.while_loop(outer_cond, outer_body, (padded, active0, stats0))

    # Strip padding back to the original domain.
    out = jax.tree_util.tree_map(
        lambda x: jax.lax.slice(x, (0,) * (x.ndim - 2) + (1, 1),
                                x.shape[:-2] + (1 + H, 1 + W)), padded)
    # Engine output contract: invalid cells hold their input values.
    return (restore_invalid(op, state, out) if restore else out), stats
