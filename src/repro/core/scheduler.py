"""Demand-driven host tile scheduler — the paper's runtime (§4, Fig. 8).

The paper dispatches Tile-Propagation (TP) task instances to CPU cores and
GPUs demand-driven (FCFS) and re-instantiates the pipeline when Border
Propagation (BP) finds cross-tile waves.  This module reproduces that
runtime at the host level with worker threads over jitted tile tasks.  It
is the *CPU path* of the framework and the substrate of the fault-tolerance
story:

* demand-driven FCFS queue -> natural straggler mitigation (fast workers
  take more tiles, exactly the paper's load-balance argument);
* IWPP updates are monotone + commutative and tiles are re-executable from
  current state, so a worker failure is handled by re-queuing its tile —
  the same §5.2.4 argument that makes queue overflow benign.

Threads genuinely overlap because jitted JAX CPU computations release the
GIL.  Writes are per-tile-interior (disjoint); halos are read under the
array lock, so a stale read at worst re-queues a tile (never corrupts).
"""

from __future__ import annotations

import queue
import threading
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Set, Tuple

import numpy as np


@dataclass
class SchedulerStats:
    tiles_processed: int = 0
    rounds: int = 0
    requeues_from_failures: int = 0
    per_worker: Dict[int, int] = field(default_factory=dict)
    # True iff run() gave up with work still queued (every survivor wave
    # died, max_survivor_waves exhausted): the state is NOT at its fixed
    # point and must not be treated as one.
    incomplete: bool = False


class TileScheduler:
    """FCFS demand-driven scheduler over a shared 2-D state.

    Parameters
    ----------
    state : dict of str -> np.ndarray, all (H, W)-shaped trailing dims.
    tile_fn : callable (block_state, ) -> (new_block_state, border_changed)
        Drains one (T+2, T+2) halo block to local stability.  ``border_changed``
        is a dict with keys 'top','bottom','left','right' of python bools.
    init_active : boolean (nty, ntx) array of initially-active tiles.
    merge_block_fn : optional coordinate-aware merge: called as
        ``merge_block_fn((r0, c0), old_inner, new_inner) -> merged`` with
        dicts of all mutable leaves' tile interiors and the interior's
        global origin.  Needed when the commutative merge couples leaves or
        depends on pixel coordinates (e.g. EDT's Voronoi-pointer distance
        compare); overrides ``merge_fn`` when given.
    pad_values : optional per-leaf scalars for out-of-array halo cells (the
        op's *neutral* fills, ``PropagationOp.pad_value``).  Without them the
        scheduler falls back to dtype-min/``-inf`` (False for bool), which is
        only correct for max-propagating payloads — EDT's coordinate planes,
        for instance, need their far-sentinel fill instead.
    """

    def __init__(self, state: Dict[str, np.ndarray], tile: int,
                 tile_fn: Callable, init_active: np.ndarray,
                 n_workers: int = 4, mutable=("J",),
                 merge_fn: Optional[Callable] = None,
                 merge_block_fn: Optional[Callable] = None,
                 pad_values: Optional[Dict[str, object]] = None,
                 fail_worker: Optional[int] = None, fail_after: int = 3):
        H, W = next(iter(state.values())).shape[-2:]
        assert H % tile == 0 and W % tile == 0, "host scheduler expects tile-aligned grids"
        self.state = state
        self.tile = tile
        self.tile_fn = tile_fn
        self.nty, self.ntx = H // tile, W // tile
        self.n_workers = n_workers
        self.mutable = mutable
        # Commutative merge at write-back — the scheduler analogue of the
        # paper's atomicMax/atomicCAS: a worker that raced with a fresher
        # update must not regress it.  Default: elementwise max (morph).
        self.merge_fn = merge_fn or (lambda key, old, new: np.maximum(old, new))
        self.merge_block_fn = merge_block_fn
        self.pad_values = pad_values or {}
        self.fail_worker = fail_worker
        self.fail_after = fail_after
        self._lock = threading.Lock()
        self._q: "queue.Queue[Tuple[int, int]]" = queue.Queue()
        self._in_queue: Set[Tuple[int, int]] = set()
        self._inflight = 0
        self._done = threading.Condition(self._lock)
        self.stats = SchedulerStats()
        with self._lock:   # _push notifies `_done`, which requires the lock
            for ty in range(self.nty):
                for tx in range(self.ntx):
                    if init_active[ty, tx]:
                        self._push((ty, tx))

    # -- queue ops (lock held) ---------------------------------------------
    def _push(self, tid):
        if tid not in self._in_queue:
            self._in_queue.add(tid)
            self._q.put(tid)
            self._done.notify_all()   # wake idle workers waiting for work

    def _slice_block(self, ty, tx):
        T = self.tile
        H, W = next(iter(self.state.values())).shape[-2:]
        r0, c0 = ty * T, tx * T
        out = {}
        for k, arr in self.state.items():
            pad_val = self.pad_values.get(k)
            if pad_val is None:
                pad_val = 0 if arr.dtype == bool else (np.iinfo(arr.dtype).min
                                                       if arr.dtype.kind in "iu" else -np.inf)
            blk = np.full(arr.shape[:-2] + (T + 2, T + 2), pad_val, dtype=arr.dtype)
            rs, re = max(0, r0 - 1), min(H, r0 + T + 1)
            cs, ce = max(0, c0 - 1), min(W, c0 + T + 1)
            blk[..., rs - (r0 - 1): rs - (r0 - 1) + (re - rs),
                cs - (c0 - 1): cs - (c0 - 1) + (ce - cs)] = arr[..., rs:re, cs:ce]
            out[k] = blk
        return out

    def _write_back(self, ty, tx, block) -> Dict[str, bool]:
        T = self.tile
        r0, c0 = ty * T, tx * T
        changed_edges = {"top": False, "bottom": False, "left": False, "right": False}
        merged_all = None
        if self.merge_block_fn is not None:
            old_all = {k: self.state[k][..., r0:r0 + T, c0:c0 + T]
                       for k in self.mutable}
            new_all = {k: np.asarray(block[k])[..., 1:-1, 1:-1]
                       for k in self.mutable}
            merged_all = self.merge_block_fn((r0, c0), old_all, new_all)
        for k in self.mutable:
            new_inner = np.asarray(block[k])[..., 1:-1, 1:-1]
            old_inner = self.state[k][..., r0:r0 + T, c0:c0 + T]
            merged = (merged_all[k] if merged_all is not None
                      else self.merge_fn(k, old_inner, new_inner))
            diff = merged != old_inner
            if diff.any():
                changed_edges["top"] |= bool(diff[..., 0, :].any())
                changed_edges["bottom"] |= bool(diff[..., -1, :].any())
                changed_edges["left"] |= bool(diff[..., :, 0].any())
                changed_edges["right"] |= bool(diff[..., :, -1].any())
                self.state[k][..., r0:r0 + T, c0:c0 + T] = merged
        return changed_edges

    def _mark_neighbors(self, ty, tx, edges):
        def m(dy, dx):
            yy, xx = ty + dy, tx + dx
            if 0 <= yy < self.nty and 0 <= xx < self.ntx:
                self._push((yy, xx))
        if edges["top"]:
            m(-1, -1); m(-1, 0); m(-1, 1)
        if edges["bottom"]:
            m(1, -1); m(1, 0); m(1, 1)
        if edges["left"]:
            m(-1, -1); m(0, -1); m(1, -1)
        if edges["right"]:
            m(-1, 1); m(0, 1); m(1, 1)

    # -- worker loop ---------------------------------------------------------
    def _worker(self, wid: int):
        n_done = 0
        while True:
            # Atomic claim-then-get: the queue pop and the inflight increment
            # happen under ONE lock acquisition.  The previous unlocked
            # `q.get()` left a window between a successful pop and
            # `_inflight += 1` in which the tile was in a worker's hands but
            # visible nowhere — idle peers observing `inflight == 0 and
            # q.empty()` exited, silently degrading the pool to one worker.
            with self._lock:
                try:
                    tid = self._q.get_nowait()
                except queue.Empty:
                    if self._inflight == 0:
                        return      # genuinely done: nothing queued, nothing claimed
                    # A peer holds a tile; it may mark neighbors (push) or
                    # finish (inflight drop) — both notify `_done`.  The
                    # timeout is only a safety net against a lost wakeup.
                    self._done.wait(timeout=0.05)
                    tid = None
                else:
                    self._inflight += 1
                    self._in_queue.discard(tid)
                    block = self._slice_block(*tid)
            if tid is None:
                continue
            try:
                if self.fail_worker == wid and n_done >= self.fail_after:
                    raise RuntimeError(f"injected failure on worker {wid}")
                new_block, _ = self.tile_fn(block)
                with self._lock:
                    edges = self._write_back(*tid, new_block)
                    self._mark_neighbors(*tid, edges)
                    self.stats.tiles_processed += 1
                    self.stats.per_worker[wid] = self.stats.per_worker.get(wid, 0) + 1
                    n_done += 1
            except Exception:
                # Fault tolerance: re-queue the tile; state untouched (tiles
                # are idempotent under IWPP's monotone commutative updates).
                with self._lock:
                    self._push(tid)
                    self.stats.requeues_from_failures += 1
                    self._inflight -= 1
                    self._done.notify_all()
                return  # worker dies; remaining workers pick up the slack
            with self._lock:
                self._inflight -= 1
                self._done.notify_all()   # idle peers re-check the exit condition

    # Survivor waves after the initial pass (fault tolerance); bounds the
    # pathological case of a tile_fn that fails deterministically forever.
    max_survivor_waves = 32

    def run(self) -> SchedulerStats:
        workers = [threading.Thread(target=self._worker, args=(w,), daemon=True)
                   for w in range(self.n_workers)]
        for t in workers:
            t.start()
        for t in workers:
            t.join()
        # Killed workers re-queue their tile and die, so a wave can end with
        # work still pending — and a survivor wave can *itself* lose workers.
        # Re-check after every wave (the old single survivor pass returned
        # with a non-empty queue if its workers also died).
        next_wid = self.n_workers
        waves = 0
        while not self._q.empty() and waves < self.max_survivor_waves:
            survivors = [threading.Thread(target=self._worker,
                                          args=(next_wid + w,), daemon=True)
                         for w in range(max(1, self.n_workers - 1))]
            for t in survivors:
                t.start()
            for t in survivors:
                t.join()
            next_wid += len(survivors)
            waves += 1
        if not self._q.empty():
            # Every wave died with work still queued (a deterministically
            # failing tile_fn).  Never report this as a fixed point.
            self.stats.incomplete = True
            warnings.warn(
                f"TileScheduler gave up after {waves} survivor waves with "
                f"~{self._q.qsize()} tiles still queued; the state is NOT at "
                "its fixed point (stats.incomplete=True)", RuntimeWarning,
                stacklevel=2)
        return self.stats
