"""Demand-driven host tile scheduler — the paper's runtime (§4, Fig. 8).

The paper dispatches Tile-Propagation (TP) task instances to CPU cores and
GPUs demand-driven (FCFS) and re-instantiates the pipeline when Border
Propagation (BP) finds cross-tile waves.  This module reproduces that
runtime at the host level with worker threads over jitted tile tasks, and
— via :class:`DeviceWorker` — the paper's *cooperative* CPU+GPU execution:
host threads and accelerator drain streams consume the **same** FCFS queue
(DESIGN.md §2.3, the `hybrid` engine's substrate).

* demand-driven FCFS queue -> natural straggler mitigation (fast workers
  take more tiles, exactly the paper's load-balance argument);
* device workers claim variable-size *chunks* of the queue per request —
  the paper's larger-GPU-chunk policy — sized by a measured relative-speed
  estimate (:class:`ChunkPolicy`: cost-model seed, online EWMA refinement);
* IWPP updates are monotone + commutative and tiles are re-executable from
  current state, so a worker failure is handled by re-queuing its tile(s) —
  the same §5.2.4 argument that makes queue overflow benign.

Threads genuinely overlap because jitted JAX CPU computations release the
GIL.  Writes are per-tile-interior (disjoint) and happen under the array
lock; halo *reads* happen outside it (a block slice is O(tile²) numpy copy
— serializing every slice behind the claim lock was the workers=2
regression).  A read torn against a concurrent interior write observes a
per-pixel mix of old and new values, every one of which is a valid
monotone state; the writer's changed edge re-marks this tile, so a stale
or torn read at worst re-queues a tile (never corrupts).
"""

from __future__ import annotations

import queue
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.geometry import _moore_offsets, pad_value_for


@dataclass
class SchedulerStats:
    tiles_processed: int = 0
    rounds: int = 0
    requeues_from_failures: int = 0
    tiles_requeued: int = 0        # unconverged (partial) drains re-queued
    per_worker: Dict[int, int] = field(default_factory=dict)
    # True iff run() gave up with work still queued (every survivor wave
    # died, max_survivor_waves exhausted): the state is NOT at its fixed
    # point and must not be treated as one.
    incomplete: bool = False


class ChunkPolicy:
    """The paper's larger-GPU-chunk policy (§4): how many queue entries a
    device worker claims per FCFS request.

    A device consumer amortizes its dispatch overhead over a whole chunk,
    so it should claim ``rel_speed`` tiles for every single tile a host
    thread claims, where ``rel_speed`` is the device:host throughput ratio.
    The ratio is *seeded* from the cost model — analytically on a cold
    start, from the measured ``hybrid_rel_speed`` once a calibration
    profile is installed (DESIGN.md §2.8; ``seed_kind`` records which) —
    and *refined online*: every worker reports its measured
    seconds-per-tile and the policy keeps one EWMA per worker class —
    demand-driven FCFS then converges the split to the actual relative
    speeds, the paper's load-balance argument made quantitative.
    """

    def __init__(self, rel_speed: float = 4.0, max_chunk: int = 16,
                 alpha: float = 0.25, seed_kind: str = "analytic"):
        self.seed_rel_speed = max(1.0, float(rel_speed))
        self.seed_kind = seed_kind
        self.max_chunk = max(1, int(max_chunk))
        self.alpha = alpha
        self._host_spt: Optional[float] = None    # EWMA host seconds/tile
        self._dev_spt: Optional[float] = None     # EWMA device seconds/tile
        self._lock = threading.Lock()

    def _ewma(self, old: Optional[float], x: float) -> float:
        return x if old is None else (1 - self.alpha) * old + self.alpha * x

    def observe_host(self, seconds_per_tile: float) -> None:
        with self._lock:
            self._host_spt = self._ewma(self._host_spt, seconds_per_tile)

    def observe_device(self, seconds_per_tile: float) -> None:
        with self._lock:
            self._dev_spt = self._ewma(self._dev_spt, seconds_per_tile)

    @property
    def rel_speed(self) -> float:
        """Measured host:device seconds-per-tile ratio (falls back to the
        analytic seed until both classes have been observed)."""
        with self._lock:
            if self._host_spt is None or self._dev_spt is None or \
                    self._dev_spt <= 0.0:
                return self.seed_rel_speed
            return self._host_spt / self._dev_spt

    def chunk(self) -> int:
        """Tiles a device worker should claim per FCFS request.

        Floored at 2: even a speed-parity device stream claims one tile of
        look-ahead, amortizing the per-claim lock/wakeup overhead across
        two dispatches — the same reason ``max_chunk`` allows two batched
        dispatches ahead.  The claim-time half-queue cap still degrades
        the chunk to 1 at the wavefront's end, so look-ahead never
        starves the other consumers of the last tiles.
        """
        return int(np.clip(round(self.rel_speed), 2, self.max_chunk))


@dataclass
class DeviceWorker:
    """One accelerator consumer of the shared FCFS queue (DESIGN.md §2.3).

    ``batch_fn`` is the tiled engine's ``batched_tile_solver`` contract:
    a pytree of halo blocks with a leading (K,) batch dim maps to
    ``(drained blocks, unconverged (K,) bools)`` — the same solvers that
    back ``run_tiled(drain_batch=K)`` (plain ``jax.vmap`` of the per-tile
    solve, or the Pallas grid-over-batch kernels) plug in unchanged.  The
    worker splits its claimed chunk into groups of exactly ``drain_batch``
    blocks (short groups padded with neutral blocks from ``pad_block``),
    so the jitted solver sees a single static batch shape.
    """

    batch_fn: Callable
    drain_batch: int = 4
    name: str = "device"


class TileScheduler:
    """FCFS demand-driven scheduler over a shared N-D state.

    The spatial rank is inferred from ``init_active``: a (nty, ntx) activity
    grid schedules 2-D tiles over the trailing two state axes, a 3-D grid
    schedules (T+2)^3 halo cubes over the trailing three, and so on
    (DESIGN.md §2.7).  Tile ids are grid-coordinate tuples throughout.

    Parameters
    ----------
    state : dict of str -> np.ndarray, all sharing the trailing spatial dims.
    tile_fn : callable (block_state, ) -> (new_block_state, info)
        Drains one (T+2,)^ndim halo block to local stability.  ``info`` may
        be ``True`` to signal an *unconverged* (partial) drain — the
        scheduler then writes the partial progress back (monotone updates
        make that safe) and re-queues the tile, the host-side analogue of
        the tiled engine's truncation self-requeue.  Any other value
        (``None``, a border-changed dict) is ignored.
    init_active : boolean grid-shaped array of initially-active tiles; its
        rank sets the scheduler's spatial ndim.
    merge_block_fn : optional coordinate-aware merge: called as
        ``merge_block_fn(origin, old_inner, new_inner) -> merged`` (origin
        is the interior's global ndim-tuple, e.g. ``(r0, c0)`` in 2-D) with
        dicts of all mutable leaves' tile interiors and the interior's
        global origin.  Needed when the commutative merge couples leaves or
        depends on pixel coordinates (e.g. EDT's Voronoi-pointer distance
        compare); overrides ``merge_fn`` when given.
    pad_values : optional per-leaf scalars for out-of-array halo cells (the
        op's *neutral* fills, ``PropagationOp.pad_value``).  Without them the
        scheduler falls back to dtype-min/``-inf`` (False for bool), which is
        only correct for max-propagating payloads — EDT's coordinate planes,
        for instance, need their far-sentinel fill instead.
    device_workers : optional sequence of :class:`DeviceWorker` — batched
        accelerator consumers sharing this queue with the host threads (the
        cooperative `hybrid` pool).  ``n_workers`` may be 0 for a
        device-only pool; at least one worker of either kind must exist.
    chunk_policy : optional :class:`ChunkPolicy` sizing device claims
        (default: a fresh policy with the seed ratio 4).  Pass a shared
        instance to keep the EWMA learning across scheduler passes.
    """

    def __init__(self, state: Dict[str, np.ndarray], tile: int,
                 tile_fn: Optional[Callable], init_active: np.ndarray,
                 n_workers: int = 4, mutable=("J",),
                 merge_fn: Optional[Callable] = None,
                 merge_block_fn: Optional[Callable] = None,
                 pad_values: Optional[Dict[str, object]] = None,
                 device_workers: Sequence[DeviceWorker] = (),
                 chunk_policy: Optional[ChunkPolicy] = None,
                 fail_worker: Optional[int] = None, fail_after: int = 3):
        init_active = np.asarray(init_active)
        ndim = init_active.ndim
        spatial = next(iter(state.values())).shape[-ndim:]
        assert all(s % tile == 0 for s in spatial), \
            "host scheduler expects tile-aligned grids"
        self.state = state
        self.tile = tile
        self.tile_fn = tile_fn
        self.ndim = ndim
        self.grid = tuple(s // tile for s in spatial)
        assert self.grid == init_active.shape, \
            "init_active grid does not match state shape / tile"
        self.n_workers = n_workers
        self.device_workers = list(device_workers)
        if n_workers <= 0 and not self.device_workers:
            raise ValueError("TileScheduler needs at least one worker "
                             "(n_workers >= 1 or a DeviceWorker)")
        if n_workers > 0 and tile_fn is None:
            raise ValueError("host workers need a tile_fn")
        self.chunk_policy = chunk_policy or ChunkPolicy()
        self.mutable = mutable
        # Commutative merge at write-back — the scheduler analogue of the
        # paper's atomicMax/atomicCAS: a worker that raced with a fresher
        # update must not regress it.  Default: elementwise max (morph).
        self.merge_fn = merge_fn or (lambda key, old, new: np.maximum(old, new))
        self.merge_block_fn = merge_block_fn
        self.pad_values = pad_values or {}
        self.fail_worker = fail_worker     # a worker id, or "all"
        self.fail_after = fail_after
        self._lock = threading.Lock()
        self._q: "queue.Queue[Tuple[int, ...]]" = queue.Queue()
        self._in_queue: Set[Tuple[int, ...]] = set()
        self._inflight = 0
        self._done = threading.Condition(self._lock)
        self.stats = SchedulerStats()
        with self._lock:   # _push notifies `_done`, which requires the lock
            for tid in np.ndindex(*self.grid):
                if init_active[tid]:
                    self._push(tid)

    # 2-D compatibility aliases (grid is the canonical N-D spelling).
    @property
    def nty(self) -> int:
        return self.grid[0]

    @property
    def ntx(self) -> int:
        return self.grid[-1]

    # -- queue ops (lock held) ---------------------------------------------
    def _push(self, tid):
        if tid not in self._in_queue:
            self._in_queue.add(tid)
            self._q.put(tid)
            self._done.notify_all()   # wake idle workers waiting for work

    def _slice_block(self, tid):
        T, nd = self.tile, self.ndim
        spatial = next(iter(self.state.values())).shape[-nd:]
        origin = tuple(t * T for t in tid)
        out = {}
        for k, arr in self.state.items():
            pad_val = pad_value_for(self.pad_values, k, arr.dtype)
            blk = np.full(arr.shape[:-nd] + (T + 2,) * nd, pad_val,
                          dtype=arr.dtype)
            src, dst = [], []
            for o, s in zip(origin, spatial):
                lo, hi = max(0, o - 1), min(s, o + T + 1)
                src.append(slice(lo, hi))
                dst.append(slice(lo - (o - 1), lo - (o - 1) + (hi - lo)))
            blk[(Ellipsis,) + tuple(dst)] = arr[(Ellipsis,) + tuple(src)]
            out[k] = blk
        return out

    def pad_block(self):
        """A fully-neutral halo block: converges immediately, marks nothing.

        Device workers use it to pad short chunks up to their static
        ``drain_batch`` shape (the same dead-slot neutralization as
        `run_tiled`'s batched drain).
        """
        T, nd = self.tile, self.ndim
        return {k: np.full(arr.shape[:-nd] + (T + 2,) * nd,
                           pad_value_for(self.pad_values, k, arr.dtype),
                           dtype=arr.dtype)
                for k, arr in self.state.items()}

    def _write_back(self, tid, block) -> List[bool]:
        """Merge one block's interior; return 2*ndim changed-face flags in
        (axis0-lo, axis0-hi, axis1-lo, axis1-hi, ...) order (2-D: top,
        bottom, left, right)."""
        T, nd = self.tile, self.ndim
        origin = tuple(t * T for t in tid)
        inner = (Ellipsis,) + tuple(slice(o, o + T) for o in origin)
        crop = (Ellipsis,) + (slice(1, -1),) * nd
        faces = [False] * (2 * nd)
        merged_all = None
        if self.merge_block_fn is not None:
            old_all = {k: self.state[k][inner] for k in self.mutable}
            new_all = {k: np.asarray(block[k])[crop] for k in self.mutable}
            merged_all = self.merge_block_fn(origin, old_all, new_all)
        for k in self.mutable:
            new_inner = np.asarray(block[k])[crop]
            old_inner = self.state[k][inner]
            merged = (merged_all[k] if merged_all is not None
                      else self.merge_fn(k, old_inner, new_inner))
            diff = merged != old_inner
            if diff.any():
                for a in range(nd):
                    axis = diff.ndim - nd + a
                    faces[2 * a] |= bool(np.take(diff, 0, axis=axis).any())
                    faces[2 * a + 1] |= bool(np.take(diff, -1, axis=axis).any())
                self.state[k][inner] = merged
        return faces

    def _mark_neighbors(self, tid, faces):
        """Queue every Moore neighbor whose shared boundary saw a change:
        an offset is marked iff some axis it moves along has its matching
        face flag set (a corner/edge ghost is reachable iff one of its
        incident faces changed — conn26's corner semantics, DESIGN.md §2.7).
        """
        nd = self.ndim
        for off in _moore_offsets(nd, nd):
            flag = any(faces[2 * a + (0 if off[a] < 0 else 1)]
                       for a in range(nd) if off[a] != 0)
            if not flag:
                continue
            nb = tuple(t + d for t, d in zip(tid, off))
            if all(0 <= c < g for c, g in zip(nb, self.grid)):
                self._push(nb)

    def _commit(self, tid, block, unconverged: bool, wid: int):
        """Write one drained block back and update marks/stats (lock held)."""
        edges = self._write_back(tid, block)
        self._mark_neighbors(tid, edges)
        if unconverged:
            # Partial drain (cut off at the solver's iteration bound): the
            # written-back progress is monotone-safe, but the tile is NOT at
            # its fixed point — keep it queued (truncation self-requeue).
            self._push(tid)
            self.stats.tiles_requeued += 1
        self.stats.tiles_processed += 1
        self.stats.per_worker[wid] = self.stats.per_worker.get(wid, 0) + 1

    def _should_fail(self, wid: int, n_done: int) -> bool:
        """Fault-injection hook: kill worker ``fail_worker`` (or every
        worker, ``"all"``) after it has processed ``fail_after`` tiles."""
        return (self.fail_worker is not None
                and (self.fail_worker == "all" or self.fail_worker == wid)
                and n_done >= self.fail_after)

    # -- host worker loop ----------------------------------------------------
    def _worker(self, wid: int):
        n_done = 0
        while True:
            # Atomic claim-then-get: the queue pop and the inflight increment
            # happen under ONE lock acquisition.  The previous unlocked
            # `q.get()` left a window between a successful pop and
            # `_inflight += 1` in which the tile was in a worker's hands but
            # visible nowhere — idle peers observing `inflight == 0 and
            # q.empty()` exited, silently degrading the pool to one worker.
            with self._lock:
                try:
                    tid = self._q.get_nowait()
                except queue.Empty:
                    if self._inflight == 0:
                        return      # genuinely done: nothing queued, nothing claimed
                    # A peer holds a tile; it may mark neighbors (push) or
                    # finish (inflight drop) — both notify `_done`.  The
                    # timeout is only a safety net against a lost wakeup.
                    self._done.wait(timeout=0.05)
                    tid = None
                else:
                    self._inflight += 1
                    self._in_queue.discard(tid)
            if tid is None:
                continue
            # Slice outside the lock: the copy is the expensive part of a
            # claim, and a torn read against a concurrent interior write is
            # monotone-safe (module docstring) — the writer's edge change
            # re-marks this tile, so nothing is ever lost.
            block = self._slice_block(tid)
            try:
                if self._should_fail(wid, n_done):
                    raise RuntimeError(f"injected failure on worker {wid}")
                t0 = time.perf_counter()
                new_block, info = self.tile_fn(block)
                self.chunk_policy.observe_host(time.perf_counter() - t0)
                with self._lock:
                    self._commit(tid, new_block, info is True, wid)
                    n_done += 1
            except Exception:
                # Fault tolerance: re-queue the tile; state untouched (tiles
                # are idempotent under IWPP's monotone commutative updates).
                with self._lock:
                    self._push(tid)
                    self.stats.requeues_from_failures += 1
                    self._inflight -= 1
                    self._done.notify_all()
                return  # worker dies; remaining workers pick up the slack
            with self._lock:
                self._inflight -= 1
                self._done.notify_all()   # idle peers re-check the exit condition

    # -- device worker loop --------------------------------------------------
    def _device_worker(self, wid: int, dev: DeviceWorker):
        """Batched accelerator consumer: claim a chunk, drain it, merge back.

        The chunk is claimed under ONE lock acquisition (the same atomic
        claim-then-get invariant as the host loop, generalized to K tiles),
        then drained and committed one ``drain_batch`` group at a time:
        each group is sliced *after* the previous group committed, so
        claim-ahead costs queue ordering only, never halo staleness across
        groups (a chunk-wide pre-claim snapshot measurably inflated the
        cooperative pool's tile count ~3-5% in re-drains).  Tiles *within*
        a group still drain concurrently from each other's pre-group
        snapshots — exactly `run_tiled`'s batched-drain seam: interior
        writes are disjoint, writeback goes through the commutative merge,
        and a changed edge re-marks the neighbor, so a stale read at worst
        costs a re-drain, never a wrong fixed point (DESIGN.md §2.1/§2.3).
        """
        n_done = 0
        K = max(1, dev.drain_batch)
        while True:
            with self._lock:
                # Claim at most half the queue (ceil): a chunk bigger than
                # the device's measured speed advantage starves the other
                # consumers and serializes the wavefront — demand-driven
                # means leaving work for whoever is free.
                want = min(self.chunk_policy.chunk(),
                           max(1, -(-self._q.qsize() // 2)))
                tids: List[Tuple[int, int]] = []
                while len(tids) < want:
                    try:
                        tids.append(self._q.get_nowait())
                    except queue.Empty:
                        break
                if not tids:
                    if self._inflight == 0:
                        return
                    self._done.wait(timeout=0.05)
                    continue
                self._inflight += len(tids)
                for t in tids:
                    self._in_queue.discard(t)
            for g0 in range(0, len(tids), K):
                gtids = tids[g0:g0 + K]
                # Group block copies outside the lock (same torn-read
                # argument as the host loop; the tiles were claimed above).
                blocks = [self._slice_block(t) for t in gtids]
                t0 = time.perf_counter()
                try:
                    if self._should_fail(wid, n_done):
                        raise RuntimeError(
                            f"injected failure on device worker {wid}")
                    results = self._drain_chunk(dev, blocks)
                except Exception:
                    with self._lock:
                        # Re-queue this group and every unstarted one; the
                        # groups already committed stay committed (monotone
                        # updates make partial chunk progress safe).
                        rest = tids[g0:]
                        for t in rest:
                            self._push(t)
                        self.stats.requeues_from_failures += len(rest)
                        self._inflight -= len(rest)
                        self._done.notify_all()
                    return  # device worker dies; survivors take over
                self.chunk_policy.observe_device(
                    (time.perf_counter() - t0) / len(gtids))
                with self._lock:
                    for t, (nb, unconv) in zip(gtids, results):
                        self._commit(t, nb, unconv, wid)
                    n_done += len(gtids)
                    self._inflight -= len(gtids)
                    self._done.notify_all()

    def _drain_chunk(self, dev: DeviceWorker, blocks):
        """Drain a claimed chunk in groups of exactly ``drain_batch`` blocks.

        Short groups are padded with neutral blocks (see :meth:`pad_block`)
        so the jitted batched solver only ever sees one static (K, T+2, T+2)
        shape; pad slots converge immediately and are dropped unmerged.
        """
        K = max(1, dev.drain_batch)
        results = []
        neutral = None
        for g0 in range(0, len(blocks), K):
            group = blocks[g0:g0 + K]
            n_live = len(group)
            if n_live < K:
                if neutral is None:
                    neutral = self.pad_block()
                group = group + [neutral] * (K - n_live)
            if K == 1:
                # Singleton group: a length-1 np.stack would copy the whole
                # block again just to add the batch axis — a view does it.
                stacked = {k: v[None] for k, v in group[0].items()}
            else:
                stacked = {k: np.stack([b[k] for b in group])
                           for k in group[0].keys()}
            out, unconv = dev.batch_fn(stacked)
            out = {k: np.asarray(v) for k, v in out.items()}
            unconv = np.asarray(unconv)
            for i in range(n_live):
                results.append(({k: v[i] for k, v in out.items()},
                                bool(unconv[i])))
        return results

    # -- pool composition ----------------------------------------------------
    def _roles(self):
        """The mixed worker pool: ('host', None) x n_workers + device specs."""
        return ([("host", None)] * self.n_workers
                + [("device", d) for d in self.device_workers])

    def _spawn(self, role, wid: int) -> threading.Thread:
        kind, dev = role
        if kind == "host":
            return threading.Thread(target=self._worker, args=(wid,),
                                    daemon=True)
        return threading.Thread(target=self._device_worker, args=(wid, dev),
                                daemon=True)

    # Survivor waves after the initial pass (fault tolerance); bounds the
    # pathological case of a tile_fn that fails deterministically forever.
    max_survivor_waves = 32

    def run(self) -> SchedulerStats:
        roles = self._roles()
        workers = [self._spawn(role, w) for w, role in enumerate(roles)]
        for t in workers:
            t.start()
        for t in workers:
            t.join()
        # Killed workers re-queue their tile(s) and die, so a wave can end
        # with work still pending — and a survivor wave can *itself* lose
        # workers.  Re-check after every wave (the old single survivor pass
        # returned with a non-empty queue if its workers also died).  Waves
        # respawn from the same mixed role pool, one short of the original
        # (the model: one worker died).  The dropped role is the *first*
        # one — a host thread when any exist (roles list hosts first) — so
        # a hybrid pool keeps its device consumers alive across waves.
        next_wid = len(roles)
        surv_roles = roles[1:] if len(roles) > 1 else roles
        waves = 0
        while not self._q.empty() and waves < self.max_survivor_waves:
            survivors = [self._spawn(role, next_wid + w)
                         for w, role in enumerate(surv_roles)]
            for t in survivors:
                t.start()
            for t in survivors:
                t.join()
            next_wid += len(survivors)
            waves += 1
        if not self._q.empty():
            # Every wave died with work still queued (a deterministically
            # failing tile_fn).  Never report this as a fixed point.
            self.stats.incomplete = True
            warnings.warn(
                f"TileScheduler gave up after {waves} survivor waves with "
                f"~{self._q.qsize()} tiles still queued; the state is NOT at "
                "its fixed point (stats.incomplete=True)", RuntimeWarning,
                stacklevel=2)
        return self.stats
