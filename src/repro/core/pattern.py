"""The Irregular Wavefront Propagation Pattern (IWPP) abstraction.

Paper Algorithm 1, re-expressed for a SIMD/vector machine: instead of a
queue of *pixels* mutated by atomics, the wavefront is a boolean *frontier*
plane and one `round` applies every queued propagation simultaneously:

    state', frontier' = op.round(state, frontier)

The update rule must be commutative + monotone (paper §3.1's atomicity
requirement); under that contract the bulk-synchronous rounds reach the same
fixed point as the sequential queue, in any processing order.  Engines
(`core.frontier`, `core.tiles`, `core.distributed`) drive `round` with
different work-tracking granularities — the TPU analogue of the paper's
Naive / prefix-sum / multi-level-queue designs.

A `PropagationOp` owns:
  * ``state``      — pytree of (H, W) arrays (all leaves same spatial shape).
  * ``pad_value``  — pytree of scalars: *neutral* halo fill per leaf.  A cell
    holding its neutral value can never propagate (morph: dtype-min; EDT:
    far sentinel coords).
  * ``make_state(*inputs)``  — state pytree from the op's raw input(s).
  * ``init_frontier(state)`` — initial wavefront (paper line 3).
  * ``round(state, frontier)`` — one bulk propagation round (lines 5-12).
  * ``stable_leaves``          — names of leaves that never change (masks),
    used by engines to skip writeback work.

Ops become engine-reachable *by name* through the `repro.ops` plugin
registry: an `OpSpec` (DESIGN.md §2.4, docs/OPS.md) bundles the op factory
with its per-engine plug points (Pallas tile solvers, scheduler merge) and
cost-model hints, so `solve("edt", image)` needs no engine edits per op.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

N8_OFFSETS = ((-1, -1), (-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0), (1, 1))
N4_OFFSETS = ((-1, 0), (0, -1), (0, 1), (1, 0))


def offsets_for(connectivity: int):
    if connectivity == 8:
        return N8_OFFSETS
    if connectivity == 4:
        return N4_OFFSETS
    raise ValueError(f"connectivity must be 4 or 8, got {connectivity}")


def shift2d(x: jnp.ndarray, dr: int, dc: int, fill) -> jnp.ndarray:
    """out[r, c] = x[r + dr, c + dc], out-of-bounds cells = ``fill``.

    Static offsets in {-1, 0, 1}; compiles to pad+slice (no gather), which
    is the vector-friendly formulation on TPU.
    """
    H, W = x.shape[-2], x.shape[-1]
    pad = [(0, 0)] * (x.ndim - 2) + [(1, 1), (1, 1)]
    xp = jnp.pad(x, pad, constant_values=fill)
    return jax.lax.slice_in_dim(
        jax.lax.slice_in_dim(xp, 1 + dr, 1 + dr + H, axis=x.ndim - 2),
        1 + dc, 1 + dc + W, axis=x.ndim - 1)


@dataclasses.dataclass(frozen=True)
class PropagationOp:
    """Bundle of the pattern's plug points (duck-typed; subclasses override)."""

    connectivity: int = 8

    @property
    def offsets(self):
        return offsets_for(self.connectivity)

    @property
    def static_leaves(self):
        """State leaves that rounds never modify (skipped at writeback)."""
        return ("valid",)

    # -- interface ---------------------------------------------------------
    def make_state(self, *inputs, **kw):
        """State pytree from the op's natural raw input(s) (op-specific
        signature; the registry's ``OpSpec.build_state`` delegates here
        unless the spec overrides it)."""
        raise NotImplementedError

    def init_frontier(self, state) -> jnp.ndarray:
        raise NotImplementedError

    def round(self, state, frontier) -> Tuple[Any, jnp.ndarray]:
        raise NotImplementedError

    def pad_value(self, state):
        """Pytree (same structure as state) of neutral scalars."""
        raise NotImplementedError

    # -- shared helpers ----------------------------------------------------
    def changed_any(self, frontier) -> jnp.ndarray:
        return jnp.any(frontier)


def tree_shape(state):
    leaf = jax.tree_util.tree_leaves(state)[0]
    return leaf.shape[-2], leaf.shape[-1]


def restore_invalid(op: PropagationOp, original, out):
    """Enforce the engine output contract on invalid pixels.

    Engines differ in what they leave behind outside the valid domain (the
    dense rounds can grow an invalid *receiver*, the Pallas tile drains pin
    invalid cells to the neutral value) — so the uniform contract is:
    **invalid cells of every engine's output hold their input values,
    bit-for-bit**.  Every engine applies this restore on its final state,
    making engine outputs comparable over the whole array, not just the
    valid region (tests/test_masks.py).

    Static leaves are never written by engines, so only mutable leaves are
    restored; ``valid`` broadcasts against leading non-spatial dims (EDT's
    (2, H, W) pointer plane).
    """
    if "valid" not in original:
        return out
    valid = original["valid"]
    static = set(op.static_leaves)
    return {k: (v if k in static else jnp.where(valid, v, original[k]))
            for k, v in out.items()}
