"""The Irregular Wavefront Propagation Pattern (IWPP) abstraction.

Paper Algorithm 1, re-expressed for a SIMD/vector machine: instead of a
queue of *pixels* mutated by atomics, the wavefront is a boolean *frontier*
plane and one `round` applies every queued propagation simultaneously:

    state', frontier' = op.round(state, frontier)

The update rule must be commutative + monotone (paper §3.1's atomicity
requirement); under that contract the bulk-synchronous rounds reach the same
fixed point as the sequential queue, in any processing order.  Engines
(`core.frontier`, `core.tiles`, `core.distributed`) drive `round` with
different work-tracking granularities — the TPU analogue of the paper's
Naive / prefix-sum / multi-level-queue designs.

A `PropagationOp` owns:
  * ``state``      — pytree of arrays whose trailing ``ndim`` axes are the
    spatial grid (2D images or 3D volumes — DESIGN.md §2.7; all leaves
    share the spatial shape, leading axes ride along).
  * ``pad_value``  — pytree of scalars: *neutral* halo fill per leaf.  A cell
    holding its neutral value can never propagate (morph: dtype-min; EDT:
    far sentinel coords).
  * ``make_state(*inputs)``  — state pytree from the op's raw input(s).
  * ``init_frontier(state)`` — initial wavefront (paper line 3).
  * ``round(state, frontier)`` — one bulk propagation round (lines 5-12).
  * ``stable_leaves``          — names of leaves that never change (masks),
    used by engines to skip writeback work.

Ops become engine-reachable *by name* through the `repro.ops` plugin
registry: an `OpSpec` (DESIGN.md §2.4, docs/OPS.md) bundles the op factory
with its per-engine plug points (Pallas tile solvers, scheduler merge) and
cost-model hints, so `solve("edt", image)` needs no engine edits per op.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.geometry import (NEIGHBORHOODS, Neighborhood,
                                 connectivity_name, neighborhood,
                                 tree_spatial_shape)

# The historical 2D tables, now *derived* from the N-D neighborhood
# generator — byte-identical to the old literals (the generator's
# product((-1,0,1)) order is what preserves EDT tie resolution).
N8_OFFSETS = NEIGHBORHOODS["conn8"].offsets
N4_OFFSETS = NEIGHBORHOODS["conn4"].offsets


def offsets_for(connectivity: Union[int, str]):
    """Offset table for a connectivity knob (legacy int 4/8 or a
    ``connN`` neighborhood name — DESIGN.md §2.7)."""
    return neighborhood(connectivity).offsets


def shiftnd(x: jnp.ndarray, offset: Sequence[int], fill) -> jnp.ndarray:
    """out[p] = x[p + offset] over the trailing ``len(offset)`` spatial
    axes; out-of-bounds cells = ``fill``.

    Static per-axis offsets in {-1, 0, 1}; compiles to pad+slice (no
    gather), which is the vector-friendly formulation on TPU.  Leading
    (non-spatial) axes ride along untouched.
    """
    ndim = len(offset)
    lead = x.ndim - ndim
    pad = [(0, 0)] * lead + [(1, 1)] * ndim
    xp = jnp.pad(x, pad, constant_values=fill)
    for a, d in enumerate(offset):
        axis = lead + a
        xp = jax.lax.slice_in_dim(xp, 1 + d, 1 + d + x.shape[axis], axis=axis)
    return xp


def shift2d(x: jnp.ndarray, dr: int, dc: int, fill) -> jnp.ndarray:
    """out[r, c] = x[r + dr, c + dc] — the 2D spelling of :func:`shiftnd`."""
    return shiftnd(x, (dr, dc), fill)


@dataclasses.dataclass(frozen=True)
class PropagationOp:
    """Bundle of the pattern's plug points (duck-typed; subclasses override)."""

    connectivity: Union[int, str] = 8

    @property
    def neighborhood(self) -> Neighborhood:
        """The resolved :class:`Neighborhood` (DESIGN.md §2.7)."""
        return neighborhood(self.connectivity)

    @property
    def ndim(self) -> int:
        """Spatial rank, derived from the neighborhood (conn4/conn8 -> 2,
        conn6/conn18/conn26 -> 3)."""
        return self.neighborhood.ndim

    @property
    def offsets(self):
        return self.neighborhood.offsets

    @property
    def static_leaves(self):
        """State leaves that rounds never modify (skipped at writeback)."""
        return ("valid",)

    # -- interface ---------------------------------------------------------
    def make_state(self, *inputs, **kw):
        """State pytree from the op's natural raw input(s) (op-specific
        signature; the registry's ``OpSpec.build_state`` delegates here
        unless the spec overrides it)."""
        raise NotImplementedError

    def init_frontier(self, state) -> jnp.ndarray:
        raise NotImplementedError

    def round(self, state, frontier) -> Tuple[Any, jnp.ndarray]:
        raise NotImplementedError

    def pad_value(self, state):
        """Pytree (same structure as state) of neutral scalars."""
        raise NotImplementedError

    # -- shared helpers ----------------------------------------------------
    def changed_any(self, frontier) -> jnp.ndarray:
        return jnp.any(frontier)


def tree_shape(state, ndim: int = 2):
    """Trailing-``ndim`` spatial shape of a state pytree (delegates to the
    shared :func:`repro.core.geometry.tree_spatial_shape`)."""
    return tree_spatial_shape(state, ndim)


def restore_invalid(op: PropagationOp, original, out):
    """Enforce the engine output contract on invalid pixels.

    Engines differ in what they leave behind outside the valid domain (the
    dense rounds can grow an invalid *receiver*, the Pallas tile drains pin
    invalid cells to the neutral value) — so the uniform contract is:
    **invalid cells of every engine's output hold their input values,
    bit-for-bit**.  Every engine applies this restore on its final state,
    making engine outputs comparable over the whole array, not just the
    valid region (tests/test_masks.py).

    Static leaves are never written by engines, so only mutable leaves are
    restored; ``valid`` broadcasts against leading non-spatial dims (EDT's
    (2, H, W) pointer plane).
    """
    if "valid" not in original:
        return out
    valid = original["valid"]
    static = set(op.static_leaves)
    return {k: (v if k in static else jnp.where(valid, v, original[k]))
            for k, v in out.items()}
