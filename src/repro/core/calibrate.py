"""One-time-per-device measured calibration profiles for the cost model
(DESIGN.md §2.8).

The analytic :class:`repro.solve.CostModel` prices engines in abstract
pixel-visit units with guessed constants; ROADMAP item 1 documents where
that goes wrong (BENCH_tiled.json: ``auto`` picking ``frontier`` on inputs
where the tiled engine measures 3-5x faster).  Following the MATCH line of
work (SNIPPETS.md §2) and the paper's own measured relative-device-speed
partitioning (Teodoro et al. 2012 §4), this module *measures* the model's
ingredients once per (device kind, code version) and persists them through
:mod:`repro.core.autotune_disk`:

* **transfer profile** — seconds per byte moved through HBM, swept over a
  grid of sizes and dtypes so the interpolation captures the bandwidth
  knee between cache-resident and memory-bound working sets;
* **dense-round profiles** — seconds per dense propagation round, per op
  and per dense engine (``sweep`` vs ``frontier``), over the size sweep;
* **drain profiles** — wall seconds per innermost tile drain for each
  tiled solver family (plain ``tiled``, Pallas dense, Pallas queued, host
  ``scheduler``, cooperative ``hybrid``), over block pixels; plus the
  **drain-grid curves** (per-drain seconds vs full-grid pixels per block
  size — queue compaction and block scatter touch the whole grid, so a
  drain at 1024^2 costs ~10x the same drain at the calibration grid) and
  the per-block-size **batch-factor curves** over ``drain_batch`` (the
  sign flips with block size: batching amortizes dispatch at 32^2 blocks
  and pays padded compute at 128^2 ones);
* **rounds-per-extent** — measured outer rounds divided by the grid
  extent, per op over seed density: the measured replacement for the
  analytic ``depth_est`` guess (rounds track the *spatial extent* of the
  propagation, not the inter-seed spacing — the root cause of the
  frontier-vs-tiled mispredictions);
* **hybrid_rel_speed** — the measured host-vs-device seconds-per-tile
  ratio seeding the hybrid engine's :class:`~repro.core.scheduler.
  ChunkPolicy` (the paper's measured relative-speed work partitioning).

:class:`repro.solve.MeasuredCostModel` interpolates these profiles
(endpoint-clamped *rates*, so extrapolation stays linear in work) and the
analytic model remains the cold-start fallback.  Calibration is explicit
(`benchmarks/calibrate.py`, ``--calibrate``, or :func:`run_calibration`):
a guard asserts it can never run inside a ``solve()`` call path, so
cold-start solves stay cheap.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import threading
import time
import warnings
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

PROFILE_VERSION = 2

# Families a drain profile can carry; names match EngineConfig.engine with
# the queued-kernel variant split out (it is a different innermost loop).
DRAIN_FAMILIES = ("tiled", "tiled-pallas", "tiled-pallas-queued",
                  "scheduler", "hybrid")

# Worker counts the scheduler/hybrid families are measured at (recorded in
# meta; their profiles are wall seconds per tile *at these counts*).
CAL_N_WORKERS = 2
CAL_N_DEVICE_WORKERS = 1


# ---------------------------------------------------------------------------
# Profile: one measured 1-D curve with clamped interpolation.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Profile:
    """Sorted measured points ``(x, y)`` with piecewise-linear lookup.

    Two lookups, both bounded by the measured endpoints:

    * :meth:`interp` — plain clamped interpolation of ``y`` (for bounded
      quantities: batch factors, density factors, rounds-per-extent).
    * :meth:`scaled` — interpolates the per-unit *rate* ``y/x`` (clamped)
      and multiplies back by ``x``: outside the measured range the cost
      keeps growing linearly in the work ``x`` instead of freezing at the
      endpoint ``y`` (a 3-D block is never priced like the biggest 2-D
      block that happened to be measured).
    """

    xs: Tuple[float, ...]
    ys: Tuple[float, ...]

    def __post_init__(self):
        if not self.xs or len(self.xs) != len(self.ys):
            raise ValueError("Profile needs matching non-empty xs/ys")
        if any(b <= a for a, b in zip(self.xs, self.xs[1:])):
            raise ValueError("Profile xs must be strictly increasing")

    @classmethod
    def from_points(cls, points: Sequence[Tuple[float, float]]) -> "Profile":
        """Sort and merge duplicate x (mean of their y)."""
        by_x: Dict[float, List[float]] = {}
        for x, y in points:
            by_x.setdefault(float(x), []).append(float(y))
        xs = sorted(by_x)
        return cls(tuple(xs), tuple(float(np.mean(by_x[x])) for x in xs))

    def interp(self, x: float) -> float:
        xs, ys = self.xs, self.ys
        if x <= xs[0]:
            return ys[0]
        if x >= xs[-1]:
            return ys[-1]
        i = int(np.searchsorted(xs, x)) - 1
        t = (x - xs[i]) / (xs[i + 1] - xs[i])
        return ys[i] + t * (ys[i + 1] - ys[i])

    def scaled(self, x: float) -> float:
        rates = Profile(self.xs, tuple(y / max(x_, 1e-12)
                                       for x_, y in zip(self.xs, self.ys)))
        return rates.interp(x) * x

    def to_list(self) -> List[List[float]]:
        return [[x, y] for x, y in zip(self.xs, self.ys)]

    @classmethod
    def from_list(cls, pts) -> Optional["Profile"]:
        try:
            return cls.from_points([(float(p[0]), float(p[1])) for p in pts])
        except (TypeError, ValueError, IndexError):
            return None


def _nested_to_json(d: Dict) -> Dict:
    return {k: (_nested_to_json(v) if isinstance(v, dict) else v.to_list())
            for k, v in d.items()}


def _nested_from_json(d: Any, depth: int) -> Dict:
    if not isinstance(d, dict):
        return {}
    if depth == 0:
        out = {}
        for k, v in d.items():
            p = Profile.from_list(v)
            if p is not None:
                out[k] = p
        return out
    return {k: _nested_from_json(v, depth - 1) for k, v in d.items()}


# ---------------------------------------------------------------------------
# CalibrationProfile: everything MeasuredCostModel interpolates.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CalibrationProfile:
    """The persisted measurement bundle (see module docstring for the
    meaning of each section).  All maps are keyed by registered op name;
    unprofiled ops fall back to the ``morph`` reference entries scaled by
    their OpSpec cost hints."""

    # op -> engine ("frontier"/"sweep") -> Profile(area px -> sec/round)
    dense_round: Dict[str, Dict[str, Profile]] = dataclasses.field(
        default_factory=dict)
    # op -> Profile(log10 density -> measured rounds / grid extent)
    rounds_per_extent: Dict[str, Profile] = dataclasses.field(
        default_factory=dict)
    # op -> family -> Profile(block px -> wall sec/drain)
    drain: Dict[str, Dict[str, Profile]] = dataclasses.field(
        default_factory=dict)
    # op -> Profile(log10 density -> per-drain factor vs the sparse regime)
    drain_density_factor: Dict[str, Profile] = dataclasses.field(
        default_factory=dict)
    # block px (str key) -> Profile(grid px -> sec/drain) on the reference
    # op: how per-drain cost grows with the *full grid* (queue compaction
    # and block scatter touch the whole grid every round, so a block's
    # drain at 1024^2 costs ~10x its drain at the 192^2 calibration grid).
    # Measured from round-capped tiled solves at the dense-knee sizes.
    drain_grid: Dict[str, Profile] = dataclasses.field(default_factory=dict)
    # block px (str key) -> Profile(drain_batch -> per-tile factor vs
    # drain_batch=1).  Keyed by block size because the sign flips: batching
    # amortizes per-drain dispatch at small blocks but pays padded compute
    # at large ones (measured: 0.6x at 32^2 vs 4.7x at 128^2 blocks).
    batch_factor: Dict[str, Profile] = dataclasses.field(default_factory=dict)
    # Profile(working-set bytes -> sec/byte): generic memory-bandwidth rate
    transfer: Optional[Profile] = None
    # op -> neighborhood size the op's profiles were measured at
    ref_n_offsets: Dict[str, int] = dataclasses.field(default_factory=dict)
    hybrid_rel_speed: Optional[float] = None
    round_overhead_s: float = 0.0
    recompile_s: float = 0.0
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "profile_version": PROFILE_VERSION,
            "dense_round": _nested_to_json(self.dense_round),
            "rounds_per_extent": _nested_to_json(self.rounds_per_extent),
            "drain": _nested_to_json(self.drain),
            "drain_density_factor": _nested_to_json(self.drain_density_factor),
            "drain_grid": _nested_to_json(self.drain_grid),
            "batch_factor": _nested_to_json(self.batch_factor),
            "transfer": self.transfer.to_list() if self.transfer else None,
            "ref_n_offsets": dict(self.ref_n_offsets),
            "hybrid_rel_speed": self.hybrid_rel_speed,
            "round_overhead_s": self.round_overhead_s,
            "recompile_s": self.recompile_s,
            "meta": dict(self.meta),
        }

    @classmethod
    def from_dict(cls, d: Any) -> Optional["CalibrationProfile"]:
        """Tolerant decode: None on version mismatch or non-dict input (a
        stale or foreign profile must fall back to analytic, not crash)."""
        if not isinstance(d, dict) or d.get("profile_version") != PROFILE_VERSION:
            return None
        prof = cls(
            dense_round=_nested_from_json(d.get("dense_round"), 1),
            rounds_per_extent=_nested_from_json(d.get("rounds_per_extent"), 0),
            drain=_nested_from_json(d.get("drain"), 1),
            drain_density_factor=_nested_from_json(
                d.get("drain_density_factor"), 0),
            drain_grid=_nested_from_json(d.get("drain_grid"), 0),
            batch_factor=_nested_from_json(d.get("batch_factor"), 0),
            transfer=Profile.from_list(d["transfer"])
            if d.get("transfer") else None,
            ref_n_offsets={k: int(v)
                           for k, v in (d.get("ref_n_offsets") or {}).items()
                           if isinstance(v, (int, float))},
            hybrid_rel_speed=d.get("hybrid_rel_speed"),
            round_overhead_s=float(d.get("round_overhead_s") or 0.0),
            recompile_s=float(d.get("recompile_s") or 0.0),
            meta=d.get("meta") if isinstance(d.get("meta"), dict) else {},
        )
        return prof

    @classmethod
    def from_analytic(cls, model, stats, tiles: Sequence[int],
                      unit: float = 1e-6) -> "CalibrationProfile":
        """The degenerate one-point profile: every curve sampled from the
        *analytic* model's own formulas at ``stats``'s area and the given
        tiles, scaled by ``unit`` seconds per pixel-visit.

        By construction, ``MeasuredCostModel`` over this profile agrees
        with the analytic model — cost(cfg) == unit * analytic cost(cfg) —
        for the dense engines and the db=1 tiled/scheduler configs at the
        sampled tiles.  The property test
        (tests/test_calibration.py) pins this, which pins the measured
        model's plumbing: no double-applied hint scaling, no lost terms.
        """
        op = stats.op_name or "morph"
        scale_t = stats.bytes_per_pixel / model.ref_bytes_per_pixel
        w = stats.round_cost_weight
        area = float(stats.area)
        dense = {op: {
            "frontier": Profile((area,), (unit * scale_t * area,)),
            "sweep": Profile((area,),
                             (unit * scale_t * area * model.sweep_penalty,)),
        }}
        drain: Dict[str, Profile] = {}
        for fam in ("tiled", "tiled-pallas", "scheduler"):
            pts = []
            for t in sorted(tiles):
                block = float((t + 2) ** stats.ndim)
                inner = block * t * model.vmem_discount
                if fam == "tiled":
                    y = w * (inner + model.tile_dispatch)
                elif fam == "tiled-pallas":
                    pen = model.interpret_penalty if model.interpret else 1.0
                    y = w * (inner * pen + model.tile_dispatch)
                else:
                    y = w * (inner * model.host_penalty + model.host_dispatch)
                pts.append((block, unit * (scale_t * block + y)))
            drain[fam] = Profile.from_points(pts)
        return cls(
            dense_round=dense,
            drain={op: drain},
            ref_n_offsets={op: stats.n_offsets},
            round_overhead_s=unit * model.round_overhead,
            recompile_s=unit * model.recompile_cost,
            meta={"interpret": model.interpret, "analytic": True},
        )


# ---------------------------------------------------------------------------
# solve() guard: calibration must never run inside a solve call path.
# ---------------------------------------------------------------------------

_SOLVE_DEPTH = threading.local()


@contextlib.contextmanager
def solve_guard() -> Iterator[None]:
    """Entered by ``repro.solve.solve`` for the duration of a call."""
    d = getattr(_SOLVE_DEPTH, "d", 0)
    _SOLVE_DEPTH.d = d + 1
    try:
        yield
    finally:
        _SOLVE_DEPTH.d = d


def in_solve() -> bool:
    return getattr(_SOLVE_DEPTH, "d", 0) > 0


# ---------------------------------------------------------------------------
# Lazy load / install of the current profile.
# ---------------------------------------------------------------------------

_UNSET = object()
_current: Any = _UNSET
_lock = threading.Lock()


def current_profile() -> Optional[CalibrationProfile]:
    """The process's calibration profile: memoized lazy load from the
    autotune disk cache (None when this (device, code version) has never
    been calibrated — the analytic fallback case)."""
    global _current
    with _lock:
        if _current is _UNSET:
            from repro.core import autotune_disk
            _current = CalibrationProfile.from_dict(
                autotune_disk.load_profile())
        return _current


def install_profile(profile: Optional[CalibrationProfile],
                    save: bool = False) -> None:
    """Set the process's profile (None reverts to analytic); ``save=True``
    also persists it through autotune_disk for future processes."""
    global _current
    with _lock:
        _current = profile
    if save and profile is not None:
        from repro.core import autotune_disk
        autotune_disk.store_profile(profile.to_dict())


def reset_profile_cache() -> None:
    """Forget the memoized profile so the next lookup re-reads disk
    (tests repoint ``REPRO_IWPP_CACHE_DIR`` per-case and need this)."""
    global _current
    with _lock:
        _current = _UNSET


def load_profile_json(path: str) -> Optional[CalibrationProfile]:
    """Decode a profile artifact written by ``benchmarks/calibrate.py``."""
    with open(path) as f:
        return CalibrationProfile.from_dict(json.load(f))


# ---------------------------------------------------------------------------
# The calibration bench itself.
# ---------------------------------------------------------------------------

def _timed(fn: Callable, warmup: int = 1, iters: int = 2) -> float:
    import jax
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(min(ts))


def _logd(density: float) -> float:
    return math.log10(max(density, 1e-9))


@contextlib.contextmanager
def _quiet():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        yield


def _measure_transfer(sizes: Sequence[int]) -> Profile:
    """Memory-bandwidth sweep: a fused shift+max pass (one propagation
    lane's traffic) over sizes x dtypes; x = working-set bytes."""
    import jax
    import jax.numpy as jnp
    pts = []
    step = jax.jit(lambda x: jnp.maximum(x, jnp.roll(x, 1, axis=0)))
    for size in sizes:
        for dtype in (np.int8, np.int32, np.float32):
            a = jnp.asarray(np.random.default_rng(0).integers(
                0, 100, (size, size)).astype(dtype))
            t = _timed(lambda a=a: step(a))
            nbytes = size * size * np.dtype(dtype).itemsize
            pts.append((nbytes, t / nbytes))
    # merge to sec/byte at each working-set size, then back to y=sec form
    rate = Profile.from_points(pts)
    return Profile(rate.xs, tuple(r * x for x, r in zip(rate.xs, rate.ys)))


def _measure_overheads() -> Tuple[float, float]:
    """(per-dispatch seconds, one trace+compile seconds)."""
    import jax
    import jax.numpy as jnp
    a = jnp.zeros((8, 8), jnp.int32)
    f = jax.jit(lambda x: x + 1)
    dispatch = _timed(lambda: f(a), warmup=2, iters=5)
    t0 = time.perf_counter()
    jax.block_until_ready(jax.jit(lambda x: x * 3 + 7)(a))
    compile_s = time.perf_counter() - t0
    return dispatch, max(compile_s - dispatch, dispatch)


def _pallas_drain_points(op, spec, state, tiles, interpret: bool,
                         queued: bool) -> List[Tuple[float, float]]:
    """Seconds per Pallas tile-solver call on real (T+2)-halo blocks cut
    from the workload state (the innermost drain of the tiled-pallas
    engines), per tile size."""
    import jax
    factory = spec.pallas_queue_solver if queued else spec.pallas_solver
    if factory is None:
        return []
    pts = []
    for t in tiles:
        side = t + 2
        block = jax.tree_util.tree_map(lambda x: x[..., :side, :side], state)
        max_iters = side * side
        solver = (factory(op, interpret, max_iters, None) if queued
                  else factory(op, interpret, max_iters))
        run = jax.jit(solver)
        sec = _timed(lambda: run(block), warmup=1, iters=1)
        pts.append((float(side ** 2), sec))
    return pts


def run_calibration(ops: Optional[Sequence[str]] = None,
                    smoke: bool = False,
                    save: bool = True,
                    interpret: bool = True,
                    cal_size: Optional[int] = None,
                    dense_sizes: Optional[Sequence[int]] = None,
                    verbose: bool = False) -> CalibrationProfile:
    """Measure a full :class:`CalibrationProfile` on this device and
    (by default) install + persist it.

    ``smoke=True`` is the CI profile: tiny grids, morph-only for the
    host/hybrid/Pallas families — enough to exercise every measurement
    path and produce a structurally-complete artifact in well under a
    minute, not enough to trust the magnitudes.

    Raises ``RuntimeError`` when invoked (directly or indirectly) inside a
    ``solve()`` call: calibration is an explicit, one-time step — lazily
    triggering minutes of micro-benchmarks from a user's solve would
    violate the cold-start contract (the analytic model IS the cold-start
    path).
    """
    if in_solve():
        raise RuntimeError(
            "run_calibration() called inside a solve() call path; "
            "calibration is explicit (benchmarks/calibrate.py or the "
            "--calibrate bench flag) — solve() falls back to the analytic "
            "CostModel when no profile exists")
    from repro import solve as S
    from repro.core import autotune_disk
    from repro.ops import get_op, list_ops

    def say(msg: str) -> None:
        if verbose:
            print(f"# calibrate: {msg}", flush=True)

    cal_size = cal_size or (96 if smoke else 192)
    dense_sizes = tuple(dense_sizes if dense_sizes is not None
                        else ((128,) if smoke else (256, 512, 1024)))
    tiles = (16, 32) if smoke else (32, 128)
    cap = 64

    prof = CalibrationProfile(
        transfer=None,
        meta={"device_kind": autotune_disk._device_kind(),
              "code_version": autotune_disk.code_version(),
              "interpret": interpret, "smoke": smoke,
              "cal_size": cal_size,
              "n_workers": CAL_N_WORKERS,
              "n_device_workers": CAL_N_DEVICE_WORKERS,
              "timestamp": time.time()})

    say(f"transfer sweep over {dense_sizes}")
    prof.transfer = _measure_transfer(tuple(dense_sizes) + (cal_size,))
    prof.round_overhead_s, prof.recompile_s = _measure_overheads()

    op_names = list(ops) if ops else [n for n in list_ops()
                                      if get_op(n).calibration_states]
    dense_pts: Dict[str, Dict[str, List]] = {}
    rc_pts: Dict[str, List] = {}
    drain_pts: Dict[str, Dict[str, List]] = {}
    dens_pts: Dict[str, List] = {}
    grid_pts: Dict[str, List] = {}
    batch_pts: Dict[str, List] = {}
    rel_speed: Optional[float] = None

    for op_name in op_names:
        spec = get_op(op_name)
        if spec.calibration_states is None:
            continue
        full_families = (op_name == "morph") or not smoke
        primary_spt: Dict[int, float] = {}
        # The first workload is the op's *primary* regime: it feeds every
        # per-drain curve.  Later workloads only contribute (density ->
        # rounds) points and the per-drain density factor vs the primary.
        for idx, (label, op, state) in enumerate(spec.calibration_states(
                cal_size)):
            primary = idx == 0
            stats = S.collect_input_stats(op, state)
            extent = max(stats.spatial)
            ld = _logd(stats.density)
            say(f"{op_name}/{label}: frontier solve at {cal_size}")
            with _quiet():
                res = {}

                def run_frontier(op=op, state=state, res=res):
                    out, res["st"] = S.solve(op, state, engine="frontier",
                                             interpret=interpret)
                    return out

                t_f = _timed(run_frontier, warmup=1, iters=1)
            st = res["st"]
            rounds = max(1, st.rounds)
            rc_pts.setdefault(op_name, []).append((ld, rounds / extent))
            if primary:
                dense_pts.setdefault(op_name, {}).setdefault(
                    "frontier", []).append((float(stats.area), t_f / rounds))
                prof.ref_n_offsets.setdefault(op_name, stats.n_offsets)
                # sweep rate: a few full-grid rounds suffice (same work/round)
                k = min(rounds, 6)
                with _quiet():
                    t_s = _timed(lambda: S.solve(op, state, engine="sweep",
                                                 max_rounds=k,
                                                 interpret=interpret)[0],
                                 warmup=1, iters=1)
                dense_pts[op_name].setdefault("sweep", []).append(
                    (float(stats.area), t_s / k))

            # tiled drains (plain XLA solver, sequential): sec per drain
            for t in tiles:
                with _quiet():
                    res = {}

                    def run_tiled(op=op, state=state, t=t, res=res):
                        out, res["st"] = S.solve(
                            op, state, engine="tiled", tile=t,
                            queue_capacity=cap, drain_batch=1,
                            interpret=interpret)
                        return out

                    t_t = _timed(run_tiled, warmup=1, iters=1)
                spt = t_t / max(1, res["st"].tiles_processed)
                block = float((t + 2) ** stats.ndim)
                if primary:
                    drain_pts.setdefault(op_name, {}).setdefault(
                        "tiled", []).append((block, spt))
                    primary_spt[t] = spt
                elif primary_spt.get(t):
                    # other regime: record the per-drain factor vs the
                    # primary regime instead of a new curve
                    dens_pts.setdefault(op_name, []).append(
                        (ld, spt / primary_spt[t]))
            if primary:
                dens_pts.setdefault(op_name, []).append((ld, 1.0))

            if not (primary and full_families):
                continue
            # host scheduler + cooperative hybrid: wall sec per tile at the
            # recorded worker counts
            t_big = tiles[-1]
            for fam, kw in (("scheduler", dict(engine="scheduler",
                                               tile=t_big,
                                               n_workers=CAL_N_WORKERS)),
                            ("hybrid", dict(engine="hybrid", tile=t_big,
                                            n_workers=CAL_N_WORKERS,
                                            n_device_workers=CAL_N_DEVICE_WORKERS,
                                            drain_batch=4))):
                say(f"{op_name}/{label}: {fam} at tile={t_big}")
                with _quiet():
                    res = {}

                    def run_fam(op=op, state=state, kw=kw, res=res):
                        out, res["st"] = S.solve(op, state,
                                                 interpret=interpret, **kw)
                        return out

                    t_w = _timed(run_fam, warmup=1, iters=1)
                drain_pts.setdefault(op_name, {}).setdefault(fam, []).append(
                    (float((t_big + 2) ** stats.ndim),
                     t_w / max(1, res["st"].tiles_processed)))

            say(f"{op_name}: pallas drain probes")
            for queued, fam in ((False, "tiled-pallas"),
                                (True, "tiled-pallas-queued")):
                try:
                    pts = _pallas_drain_points(op, spec, state, tiles,
                                               interpret, queued)
                except Exception as e:  # op without kernels: skip family
                    say(f"{op_name}: {fam} probe failed ({e!r})")
                    pts = []
                if pts:
                    drain_pts.setdefault(op_name, {}).setdefault(
                        fam, []).extend(pts)

        # dense-rate knee: a few rounds at each larger size (state build is
        # the expensive part; the rounds themselves are cheap)
        for sz in dense_sizes:
            if sz <= cal_size:
                continue
            _, op_sz, state_sz = spec.calibration_states(sz)[0]
            area = float(np.prod(
                np.asarray(S.tree_shape(state_sz, op_sz.ndim))))
            kr = 4
            say(f"{op_name}: dense-round rate at {sz}")
            with _quiet():
                t_r = _timed(lambda: S.solve(op_sz, state_sz,
                                             engine="frontier", max_rounds=kr,
                                             interpret=interpret)[0],
                             warmup=1, iters=1)
                t_w = _timed(lambda: S.solve(op_sz, state_sz, engine="sweep",
                                             max_rounds=kr,
                                             interpret=interpret)[0],
                             warmup=1, iters=1)
            dense_pts[op_name]["frontier"].append((area, t_r / kr))
            dense_pts[op_name]["sweep"].append((area, t_w / kr))

    # Per-drain grid scaling + batched-drain amortization, measured on the
    # reference op with *round-capped* tiled solves (a few outer rounds
    # time the steady per-drain rate without paying a full solve at every
    # size).  Both effects live outside the 192^2 full-solve regime the
    # drain curves were measured in: per-drain cost grows ~10x from the
    # calibration grid to 1024^2 (queue compaction + block scatter touch
    # the whole grid), and the batch factor flips sign with block size
    # (amortized dispatch at 32^2 blocks, padded compute at 128^2 ones) —
    # a single-point measurement gets one committed bench group right and
    # another one wrong.
    ref_op = "morph" if "morph" in op_names else (op_names[0] if op_names
                                                  else None)
    if ref_op is not None:
        spec_r = get_op(ref_op)
        ndim_r = spec_r.calibration_states(cal_size)[0][1].ndim
        grid_sizes = (cal_size,) + tuple(sz for sz in dense_sizes
                                         if sz > cal_size)
        batch_size = grid_sizes[-1] if smoke else min(grid_sizes[-1], 1024)
        kcap = 3    # outer rounds per capped timing

        def capped_spt(op, state, t, db):
            res = {}

            def run(op=op, state=state, t=t, db=db, res=res):
                out, res["st"] = S.solve(op, state, engine="tiled", tile=t,
                                         queue_capacity=cap, drain_batch=db,
                                         max_rounds=kcap, interpret=interpret)
                return out

            with _quiet():
                t_c = _timed(run, warmup=1, iters=1)
            return t_c / max(1, res["st"].tiles_processed)

        for sz in grid_sizes:
            say(f"{ref_op}: drain-grid sweep at {sz}")
            _, op_g, state_g = spec_r.calibration_states(sz)[0]
            area = float(sz ** ndim_r)
            for t in tiles:
                key = str(int((t + 2) ** ndim_r))
                grid_pts.setdefault(key, []).append(
                    (area, capped_spt(op_g, state_g, t, 1)))

        _, op_b, state_b = spec_r.calibration_states(batch_size)[0]
        spt4_small = None
        for t in tiles:
            say(f"{ref_op}: batch sweep at {batch_size}, tile={t}")
            key = str(int((t + 2) ** ndim_r))
            base = None
            for db in (1, 4, 8, 16):
                spt = capped_spt(op_b, state_b, t, db)
                if db == 1:
                    base = spt
                if db == 4 and t == tiles[0]:
                    spt4_small = spt
                batch_pts.setdefault(key, []).append((float(db), spt / base))
        # measured host-vs-device per-tile ratio (the ChunkPolicy seed):
        # host unit = scheduler wall-per-tile x its threads; device unit =
        # the batched tiled drain per tile.
        sched = drain_pts.get(ref_op, {}).get("scheduler")
        if sched and spt4_small:
            rel_speed = max(1.0, (sched[-1][1] * CAL_N_WORKERS) / spt4_small)

    prof.dense_round = {o: {e: Profile.from_points(p)
                            for e, p in fams.items()}
                        for o, fams in dense_pts.items()}
    prof.rounds_per_extent = {o: Profile.from_points(p)
                              for o, p in rc_pts.items()}
    prof.drain = {o: {f: Profile.from_points(p) for f, p in fams.items()}
                  for o, fams in drain_pts.items()}
    prof.drain_density_factor = {o: Profile.from_points(p)
                                 for o, p in dens_pts.items()}
    prof.drain_grid = {k: Profile.from_points(p)
                       for k, p in grid_pts.items()}
    prof.batch_factor = {k: Profile.from_points(p)
                         for k, p in batch_pts.items()}
    prof.hybrid_rel_speed = rel_speed

    if save:
        install_profile(prof, save=True)
    else:
        install_profile(prof)
    return prof
