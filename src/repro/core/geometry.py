"""N-D geometry: the one `Neighborhood`/`Geometry` abstraction every layer
consumes (DESIGN.md §2.7).

The paper's IWPP formulation is dimension-agnostic — the wavefront
propagates over *any* grid neighborhood — and the MIC follow-up
(arXiv:1605.00930) runs the same kernels on volumetric microscopy data.
This module removes the stack's former 2D hardcodings by making the two
geometric facts first-class values:

* :class:`Neighborhood` — an offset table plus its connectivity *name*
  (``conn4``/``conn8`` in 2D, ``conn6``/``conn18``/``conn26`` in 3D).
  Offsets are generated in ``itertools.product((-1, 0, 1), repeat=ndim)``
  order, which reproduces the historical 2D tables **bit-for-bit**
  (including EDT's per-offset tie resolution, which depends on iteration
  order) — the N-D generalization changes no 2D plane and no round count.
* :class:`Geometry` — the spatial rank, tile shape and halo width with the
  pad/unpad/grid helpers that used to live as private near-copies in
  ``core/tiles.py``, ``core/distributed.py`` and ``core/scheduler.py``.

The geodesic truncation bound generalizes from ``(T+2)²`` to
``prod(T_i + 2)`` — the longest serpentine corridor threading every cell
of one halo block, in any rank (:attr:`Geometry.geodesic_bound`).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

__all__ = [
    "Neighborhood", "Geometry", "NEIGHBORHOODS", "neighborhood",
    "connectivity_name", "tree_spatial_shape", "pad_value_for",
    "ravel_index", "unravel_index",
]


@dataclasses.dataclass(frozen=True)
class Neighborhood:
    """A named grid neighborhood: the offset table every layer iterates.

    ``offsets`` holds every nonzero offset ``d`` in
    ``product((-1, 0, 1), repeat=ndim)`` order with at most ``max_nonzero``
    nonzero components — ``conn4``/``conn6`` are the faces (exactly one
    nonzero axis), ``conn18`` adds the edges, ``conn8``/``conn26`` the full
    Moore neighborhood.  The order is load-bearing: EDT resolves Voronoi
    distance *ties* by per-offset iteration order (paper §3.4), so the 2D
    tables here are byte-identical to the historical ``N8_OFFSETS``/
    ``N4_OFFSETS`` constants.
    """

    name: str
    ndim: int
    offsets: Tuple[Tuple[int, ...], ...]

    @property
    def n_offsets(self) -> int:
        return len(self.offsets)


def _moore_offsets(ndim: int, max_nonzero: int) -> Tuple[Tuple[int, ...], ...]:
    return tuple(
        d for d in itertools.product((-1, 0, 1), repeat=ndim)
        if 0 < sum(1 for v in d if v != 0) <= max_nonzero)


NEIGHBORHOODS: Dict[str, Neighborhood] = {
    "conn4": Neighborhood("conn4", 2, _moore_offsets(2, 1)),
    "conn8": Neighborhood("conn8", 2, _moore_offsets(2, 2)),
    "conn6": Neighborhood("conn6", 3, _moore_offsets(3, 1)),
    "conn18": Neighborhood("conn18", 3, _moore_offsets(3, 2)),
    "conn26": Neighborhood("conn26", 3, _moore_offsets(3, 3)),
}

# Legacy integer spellings: `connectivity=4/8` predate the by-name knob and
# keep meaning the 2D neighborhoods.
_LEGACY_INT = {4: "conn4", 8: "conn8"}


def connectivity_name(connectivity: Union[int, str]) -> str:
    """Normalize a connectivity knob (legacy int 4/8 or ``connN`` name)."""
    if isinstance(connectivity, bool):   # bool is an int; reject explicitly
        raise ValueError(f"connectivity must be 4, 8 or one of "
                         f"{sorted(NEIGHBORHOODS)}, got {connectivity!r}")
    if isinstance(connectivity, int):
        try:
            return _LEGACY_INT[connectivity]
        except KeyError:
            raise ValueError(
                f"connectivity must be 4, 8 or one of "
                f"{sorted(NEIGHBORHOODS)}, got {connectivity}") from None
    if connectivity in NEIGHBORHOODS:
        return connectivity
    raise ValueError(f"unknown connectivity {connectivity!r}; known "
                     f"neighborhoods: {sorted(NEIGHBORHOODS)} "
                     "(legacy ints 4/8 mean conn4/conn8)")


def neighborhood(connectivity: Union[int, str]) -> Neighborhood:
    """Resolve a connectivity knob to its :class:`Neighborhood`."""
    return NEIGHBORHOODS[connectivity_name(connectivity)]


@dataclasses.dataclass(frozen=True)
class Geometry:
    """Spatial rank + tile shape + halo width, with the blocking helpers.

    The one value object the tiled engines derive their math from: a state
    pytree's leaves end in ``ndim`` spatial axes (leading axes — EDT's
    pointer component, a batch dim — ride along untouched), tiles are
    ``tile``-shaped boxes over those axes, and every block carries a
    ``halo``-cell ring per axis.
    """

    ndim: int = 2
    tile: Optional[Tuple[int, ...]] = None
    halo: int = 1

    def __post_init__(self):
        if self.tile is not None and len(self.tile) != self.ndim:
            raise ValueError(f"tile {self.tile} does not match ndim "
                             f"{self.ndim}")

    @classmethod
    def of(cls, ndim: int, tile: Union[int, Sequence[int], None] = None,
           halo: int = 1) -> "Geometry":
        """Build a geometry, broadcasting a scalar tile over every axis."""
        if tile is not None:
            tile = ((int(tile),) * ndim if isinstance(tile, int)
                    else tuple(int(t) for t in tile))
        return cls(ndim=ndim, tile=tile, halo=halo)

    # -- blocking ----------------------------------------------------------
    @property
    def block(self) -> Tuple[int, ...]:
        """Halo-block shape: ``tile + 2 * halo`` per axis."""
        return tuple(t + 2 * self.halo for t in self.tile)

    @property
    def geodesic_bound(self) -> int:
        """``prod(T_i + 2*halo)`` — the longest geodesic inside one halo
        block (a 1-px serpentine corridor threading every cell), the
        N-D generalization of the 2D ``(T+2)²`` truncation bound
        (DESIGN.md §2.1/§2.7)."""
        return int(math.prod(self.block))

    def grid(self, shape: Sequence[int]) -> Tuple[int, ...]:
        """Tiles per axis (ceil division)."""
        return tuple(-(-s // t) for s, t in zip(shape, self.tile))

    def padded_shape(self, shape: Sequence[int]) -> Tuple[int, ...]:
        """Spatial shape rounded up to a whole number of tiles."""
        return tuple(n * t for n, t in zip(self.grid(shape), self.tile))

    # -- state plumbing ----------------------------------------------------
    def spatial(self, state) -> Tuple[int, ...]:
        """Trailing-``ndim`` spatial shape of a state pytree's leaves."""
        return tree_spatial_shape(state, self.ndim)

    def pad_state(self, state, pad_vals, *, to_tiles: bool = True):
        """Pad every leaf's trailing spatial axes with its neutral value:
        ``halo`` cells before, and after enough to reach a whole number of
        tiles (``to_tiles``) plus the trailing halo."""
        shape = self.spatial(state)
        target = self.padded_shape(shape) if to_tiles else shape
        pads = [(self.halo, pt - s + self.halo)
                for s, pt in zip(shape, target)]

        def pad_leaf(x, v):
            cfg = [(0, 0)] * (x.ndim - self.ndim) + pads
            return jnp.pad(x, cfg, constant_values=v)

        return jax.tree_util.tree_map(pad_leaf, state, pad_vals)

    def unpad_state(self, state, shape: Sequence[int]):
        """Invert :meth:`pad_state`: slice the original ``shape`` back out
        (dropping the leading halo and any tile-rounding slack)."""
        def crop(x):
            idx = tuple(slice(None) for _ in range(x.ndim - self.ndim))
            idx += tuple(slice(self.halo, self.halo + s) for s in shape)
            return x[idx]
        return jax.tree_util.tree_map(crop, state)


def tree_spatial_shape(state, ndim: int = 2) -> Tuple[int, ...]:
    """Trailing-``ndim`` spatial shape of a state pytree — the single
    shared helper behind what used to be three private ``tree_shape``
    copies across the engines."""
    leaf = jax.tree_util.tree_leaves(state)[0]
    return tuple(leaf.shape[-ndim:])


def pad_value_for(pad_values: Optional[dict], key: str, dtype):
    """Neutral fill for one leaf: the caller-provided value when given,
    else the dtype's most-negative value (bool: False) — a cell holding it
    can never source propagation under a monotone-max update.  Factored
    from the host scheduler's private copy."""
    if pad_values is not None and pad_values.get(key) is not None:
        return pad_values[key]
    import numpy as np
    dt = np.dtype(dtype)
    if dt == np.bool_:
        return False
    if dt.kind in "ui":
        return np.iinfo(dt).min
    return -np.inf


def ravel_index(coords: Sequence, shape: Sequence[int]):
    """C-order flat index of per-axis coordinates (jnp arrays or ints)."""
    flat = coords[0]
    for c, n in zip(coords[1:], shape[1:]):
        flat = flat * n + c
    return flat


def unravel_index(flat, shape: Sequence[int]):
    """Invert :func:`ravel_index` by successive div/mod (C order)."""
    coords = []
    for n in reversed(shape[1:]):
        coords.append(flat % n)
        flat = flat // n
    coords.append(flat)
    return tuple(reversed(coords))
