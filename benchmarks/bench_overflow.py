"""Paper §5.2.4: cost of exceeding the queue storage limit.

The tiled engine's active-tile queue has fixed capacity; overflowed tiles
are retained for the next round (re-execution from partial output — the
paper's overflow semantics).  The paper reports 6% / 9% penalties for one /
two overflow rounds; we sweep capacity and report the penalty and the
number of overflow rounds."""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, morph_state, timeit
from repro.core.tiles import run_tiled


def main(size: int = 512):
    op, state = morph_state(size, coverage=1.0, seed=5, n_sweeps=1)
    tile = 64
    full_cap = (size // tile) ** 2
    _, st = run_tiled(op, state, tile=tile, queue_capacity=full_cap)
    t_full = timeit(lambda: run_tiled(op, state, tile=tile,
                                      queue_capacity=full_cap))
    emit("overflow/full_capacity", t_full,
         f"cap={full_cap};overflows={int(st.overflow_events)}")
    for frac in (0.5, 0.25, 0.125):
        cap = max(1, int(full_cap * frac))
        _, st = run_tiled(op, state, tile=tile, queue_capacity=cap)
        t = timeit(lambda: run_tiled(op, state, tile=tile, queue_capacity=cap))
        emit(f"overflow/cap={cap}", t,
             f"overflow_rounds={int(st.overflow_events)};"
             f"penalty={100 * (t - t_full) / t_full:.1f}%")


if __name__ == "__main__":
    main()
