"""Serving-layer benchmark: coalesced multi-tenant throughput vs the
serialized one-at-a-time baseline, plus an open-loop arrival sweep
(DESIGN.md §2.9, docs/SERVING.md).

Two row families:

* ``serve/throughput/*`` — N tenants submit a fixed request stream
  (1024² morph reconstruction by default) through one
  :class:`~repro.serve.IwppService`; ``seconds`` is the serve makespan
  (first ``start()`` to last future resolved) and
  ``speedup_vs_serial`` compares it against the **serialized baseline**:
  the sum over the same stream of each request's measured solo
  ``run_op`` wall time (every unique input is timed by actually running
  it; duplicate requests reuse their input's measured time — identical
  input, identical program).  The ``shared-pool`` row is the realistic
  multi-tenant mix (tenants overlap on a shared input pool, so
  coalescing *and* the content cache contribute); the ``unique`` row is
  the honest worst case (every request distinct — batching alone).
* ``serve/arrival/*`` — open-loop arrival sweep at a smaller size:
  requests arrive at a fixed rate from 4 tenant threads and the row
  records the SLO surface (p50/p95/p99 latency, mean batch size, cache
  hit rate, rejections under a tight queue bound).

Every jitted path (solo and batch-of-``max_batch``) is warmed before
timing, per the EXPERIMENTS.md §BENCH JSON schema compile-excluded rule.
``--smoke`` shrinks to the CI profile (256²/128², short streams);
``--json [PATH]`` writes ``BENCH_serve.json``.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import (bench_argparser, maybe_calibrate, record,
                               write_json)

DEFAULT_JSON = "BENCH_serve.json"
OP = "morph"
TENANTS = 4


def _pool(size: int, n_unique: int):
    """n_unique distinct seeded-marker reconstruction inputs (the
    bench_ops sparse-wavefront regime, one per seed)."""
    from repro.data.images import seeded_marker, tissue_image
    out = []
    for seed in range(n_unique):
        marker, mask = tissue_image(size, size, coverage=1.0, seed=seed)
        marker = seeded_marker(mask, n_seeds=max(8, size // 20), seed=seed)
        out.append((marker.astype(np.int32), mask.astype(np.int32)))
    return out


def _solo_seconds(pool):
    """Measured one-at-a-time wall seconds per unique input (warm path)."""
    from repro.ops import run_op
    times = []
    for inputs in pool:
        t0 = time.perf_counter()
        run_op(OP, *inputs, engine="frontier")
        times.append(time.perf_counter() - t0)
    return times


def _serve_stream(stream, pool, max_batch):
    """Run one request stream through a fresh service; returns
    ``(makespan_s, ServeStats)``.  The stream is queued first
    (``start=False``) so the coalescer sees the full backlog — the
    steady-state shape of a loaded service."""
    from repro.serve import IwppService
    svc = IwppService(engine="frontier", max_batch=max_batch,
                      batch_window_s=0.0, start=False)
    futs = [svc.submit(OP, pool[i], tenant=f"tenant{t}")
            for t, i in stream]
    t0 = time.perf_counter()
    svc.start()
    for f in futs:
        f.result()
    makespan = time.perf_counter() - t0
    svc.close()
    return makespan, svc.stats()


def _throughput_row(records, label, stream, pool, t_solo, size, max_batch):
    serialized = sum(t_solo[i] for _, i in stream)
    makespan, st = _serve_stream(stream, pool, max_batch)
    record(records,
           f"serve/throughput/{OP}/size={size}/engine=frontier/{label}",
           makespan, tenants=TENANTS, requests=len(stream),
           unique=len({i for _, i in stream}), max_batch=max_batch,
           batches=st.batches, mean_batch=round(st.mean_batch_size, 2),
           cache_hit_rate=round(st.cache_hit_rate, 3),
           p50_s=round(st.latency_p50_s, 3), p99_s=round(st.latency_p99_s, 3),
           serialized_s=round(serialized, 3),
           speedup_vs_serial=round(serialized / makespan, 2))


def _arrival_row(records, size, pool, rate_hz, n_requests, max_batch,
                 max_queue_depth=64):
    """Open-loop: fixed-rate arrivals from TENANTS submitter threads."""
    from repro.serve import IwppService, Rejected
    svc = IwppService(engine="frontier", max_batch=max_batch,
                      batch_window_s=0.01, max_queue_depth=max_queue_depth)
    futs, rejects = [], [0]
    lock = threading.Lock()

    def tenant(t):
        for k in range(t, n_requests, TENANTS):
            time.sleep(TENANTS / rate_hz)
            try:
                f = svc.submit(OP, pool[k % len(pool)], tenant=f"tenant{t}")
                with lock:
                    futs.append(f)
            except Rejected:
                with lock:
                    rejects[0] += 1

    t0 = time.perf_counter()
    threads = [threading.Thread(target=tenant, args=(t,))
               for t in range(TENANTS)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    for f in futs:
        f.result()
    makespan = time.perf_counter() - t0
    svc.close()
    st = svc.stats()
    record(records,
           f"serve/arrival/{OP}/size={size}/rate={rate_hz}"
           f"/depth={max_queue_depth}",
           makespan, tenants=TENANTS, requests=n_requests,
           completed=st.completed, rejected=rejects[0],
           mean_batch=round(st.mean_batch_size, 2),
           cache_hit_rate=round(st.cache_hit_rate, 3),
           p50_s=round(st.latency_p50_s, 3),
           p95_s=round(st.latency_p95_s, 3),
           p99_s=round(st.latency_p99_s, 3))


def _warm(pool, sizes, small_pool):
    """Compile every timed program shape up front (solo + each batch
    size the arrival sweep can form), so rows exclude compile time."""
    from repro.ops import get_op
    from repro.solve import solve_batch
    import jax.numpy as jnp
    spec = get_op(OP)
    op = spec.make_op(None)
    for p, ks in ((pool, sizes), (small_pool, range(1, len(small_pool) + 1))):
        states = [spec.build_state(op, jnp.asarray(m), jnp.asarray(i))
                  for m, i in p]
        for k in ks:
            solve_batch(op, states[:k], engine="frontier")


def main(size: int = 1024, json_path: str | None = None, smoke: bool = False):
    records: list = []
    if smoke:
        size, small, n_unique, reps, max_batch = 256, 128, 4, 2, 4
        rates = (8.0,)
        n_arrival = 8
    else:
        small, n_unique, reps, max_batch = 256, 8, 6, 4
        rates = (4.0, 16.0)
        n_arrival = 16

    pool = _pool(size, n_unique)
    small_pool = _pool(small, 4)
    print(f"# warming jitted paths (size={size}/{small}) ...", flush=True)
    _warm(pool[:max_batch], (1, max_batch), small_pool)

    t_solo = _solo_seconds(pool)
    # shared-pool: TENANTS tenants x reps requests over the first
    # max_batch unique inputs — the overlapping multi-tenant mix.
    stream = [(t, (t + k) % max_batch)
              for k in range(reps) for t in range(TENANTS)]
    _throughput_row(records, "shared-pool", stream, pool, t_solo, size,
                    max_batch)
    # unique: every request distinct — no cache help, batching alone.
    stream = [(i % TENANTS, i) for i in range(len(pool))]
    _throughput_row(records, "unique", stream, pool, t_solo, size, max_batch)

    for rate in rates:
        _arrival_row(records, small, small_pool, rate, n_arrival, max_batch)
    # backpressure row: all-unique arrivals (cache hits bypass the queue,
    # so a shared pool would never fill it) far above service capacity
    # against a tight queue bound — rejections (with retry-after) instead
    # of an unbounded queue.
    _arrival_row(records, small, _pool(small, n_arrival), rate_hz=200.0,
                 n_requests=n_arrival, max_batch=max_batch,
                 max_queue_depth=2)

    write_json(records, json_path)
    return records


if __name__ == "__main__":
    ap = bench_argparser(
        DEFAULT_JSON, size=1024,
        smoke_help="CI profile: 256² streams, one arrival rate")
    a = ap.parse_args()
    maybe_calibrate(a)
    main(a.size, json_path=a.json, smoke=a.smoke)
