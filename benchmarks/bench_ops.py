"""Per-op solve time across engines: the op-catalog benchmark.

The plugin API's promise (DESIGN.md §2.4) is that every registered op rides
every engine; this benchmark makes the promise measurable: for each op in
``repro.ops.list_ops()`` it times a representative sparse-wavefront input
through the frontier / tiled / scheduler / hybrid engines, back to back in
one process, and derives per-row ``speedup_vs_frontier`` (>= 1.0 means the
engine beat the dense baseline on that op).

``--json [PATH]`` writes the records to ``BENCH_ops.json`` (schema:
EXPERIMENTS.md §BENCH JSON schema); ``--smoke`` shrinks to the CI profile
(256², frontier + tiled only, single timed iteration).  CPU-host caveat
applies (EXPERIMENTS.md): magnitudes calibrate the CPU backend; the
cross-op/cross-engine *shape* is the reproducible claim.
"""

from __future__ import annotations

from benchmarks.common import (maybe_calibrate as common_calibrate,
                               bench_argparser, edt_state, edt_state3d,
                               fill_state, label_state, morph_state,
                               morph_state3d, record, timeit, write_json)
from repro.solve import solve

DEFAULT_JSON = "BENCH_ops.json"

# One representative sparse-wavefront workload per registered op.
WORKLOADS = {
    "morph": lambda size: morph_state(size, coverage=1.0, seed=0,
                                      marker_kind="seeded"),
    "edt": lambda size: edt_state(size, coverage=0.9, seed=0),
    "fill_holes": lambda size: fill_state(size, coverage=0.5, seed=0),
    "label": lambda size: label_state(size, coverage=0.55, seed=0),
}

# Volumetric rows (DESIGN.md §2.7): the 3-D-capable ops under conn26.
WORKLOADS3D = {
    "morph": lambda size: morph_state3d(size, seed=0),
    "edt": lambda size: edt_state3d(size, seed=0),
}

ENGINE_KW = {
    "frontier": {},
    "tiled": dict(tile=128, queue_capacity=64, drain_batch=4),
    "scheduler": dict(tile=128, n_workers=2),
    "hybrid": dict(tile=128, n_workers=2, n_device_workers=1, drain_batch=4),
}


def bench_op(records: list, op_name: str, size: int, engines, iters: int = 3,
             tile: int = 128, workloads=WORKLOADS, prefix: str = "ops"):
    op, state = workloads[op_name](size)
    base = f"{prefix}/{op_name}/size={size}/tile={tile}"
    t_frontier = None
    for engine in engines:
        kw = dict(ENGINE_KW[engine])
        for k in ("tile",):
            if k in kw:
                kw[k] = tile
        last = {}

        def run():
            out, last["stats"] = solve(op, state, engine=engine, **kw)
            return out

        t = timeit(run, iters=iters)
        s = last["stats"]
        derived = dict(engine=engine, rounds=s.rounds,
                       tiles=s.tiles_processed, sources=s.sources_processed)
        if engine == "frontier":
            t_frontier = t
        elif t_frontier is not None:
            derived["speedup_vs_frontier"] = round(t_frontier / t, 2)
        if kw.get("tile"):
            derived["tile"] = kw["tile"]
        if kw.get("drain_batch"):
            derived["drain_batch"] = kw["drain_batch"]
        record(records, f"{base}/{engine}", t, **derived)


def main(size: int = 1024, json_path: str | None = None, smoke: bool = False):
    records: list = []
    if smoke:
        # CI profile: every op, the two cheap engines, one timed iteration.
        for op_name in WORKLOADS:
            bench_op(records, op_name, min(size, 256),
                     engines=("frontier", "tiled"), iters=1, tile=64)
        for op_name in WORKLOADS3D:
            bench_op(records, op_name, 32, engines=("frontier", "tiled"),
                     iters=1, tile=16, workloads=WORKLOADS3D, prefix="ops3d")
    else:
        for op_name in WORKLOADS:
            bench_op(records, op_name, size,
                     engines=("frontier", "tiled", "scheduler", "hybrid"))
        # 3-D rows: 128³ at tile=32 — same sparse-wavefront regimes, one
        # rank up (frontier baseline + the tiled active-set hierarchy).
        for op_name in WORKLOADS3D:
            bench_op(records, op_name, min(size, 128),
                     engines=("frontier", "tiled"), tile=32,
                     workloads=WORKLOADS3D, prefix="ops3d")
    write_json(records, json_path)
    return records


if __name__ == "__main__":
    ap = bench_argparser(
        DEFAULT_JSON, size=1024,
        smoke_help="CI profile: 256², frontier+tiled only, 1 timed iteration")
    a = ap.parse_args()
    common_calibrate(a)
    main(a.size, json_path=a.json, smoke=a.smoke)
