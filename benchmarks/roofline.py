"""Roofline analysis from the dry-run's compiled artifacts.

Per (arch x shape x mesh) cell (results/dryrun/*.json):
  compute_s    = per-device HLO FLOPs / 197e12        (v5e bf16 peak)
  memory_s     = per-device HLO bytes accessed / 819e9 (HBM bw)
  collective_s = per-device collective wire bytes / 50e9 (ICI link bw)

(cost_analysis() of the post-SPMD module is per-device, so the prompt's
"HLO_FLOPs / (chips x peak)" with global FLOPs reduces to the same value.)

MODEL_FLOPS uses the step kind: 6*N_active*tokens (train: fwd+bwd),
2*N_active*tokens (prefill), 2*N_active*batch (decode, one token each).
usefulness = MODEL_FLOPS / (per-device FLOPs x chips): how much of the
compiled compute is "useful" model math (catches remat recompute, padding
and dispatch waste).  roofline_fraction = model-flops-time / dominant-term
time: the score of how close the cell is to its hardware bound.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from functools import lru_cache

import numpy as np

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes / s / chip
ICI_BW = 50e9             # bytes / s / link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


@lru_cache(maxsize=None)
def _n_active(arch: str) -> int:
    from repro.configs.registry import get_config
    from repro.models.counting import active_matmul_param_count
    return active_matmul_param_count(get_config(arch))


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs.base import SHAPES
    sh = SHAPES[shape_name]
    n = _n_active(arch)
    if sh.kind == "train":
        return 6.0 * n * sh.global_batch * sh.seq_len
    if sh.kind == "prefill":
        return 2.0 * n * sh.global_batch * sh.seq_len
    return 2.0 * n * sh.global_batch          # decode: one token / sequence


def analyze_cell(rec: dict) -> dict:
    chips = int(np.prod(rec["mesh"]))
    ca = rec.get("cost_analysis", {})
    hc = rec.get("hlo_cost", {})
    if "flops" in hc:          # loop-aware model (preferred; see hlocost.py)
        flops_dev = hc["flops"]
        bytes_dev = hc["bytes"]
        wire_dev = hc["collectives"].get("total_wire_bytes", 0)
    else:                      # raw cost_analysis (undercounts scan bodies)
        flops_dev = ca.get("flops", 0.0)
        bytes_dev = ca.get("bytes accessed", 0.0)
        wire_dev = rec.get("collectives", {}).get("total_wire_bytes", 0)
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = wire_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    mf_time = mf / (chips * PEAK_FLOPS)
    bound = max(terms.values())
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "chips": chips,
        "status": rec["status"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": mf,
        "usefulness": mf / max(flops_dev * chips, 1.0),
        "roofline_fraction": mf_time / max(bound, 1e-30),
        "hbm_gib": (rec.get("memory_analysis", {}).get("temp_size_in_bytes", 0)
                    + rec.get("memory_analysis", {}).get("argument_size_in_bytes", 0))
        / 2**30,
        "tag": rec.get("tag", ""),
    }
    out["suggestion"] = _suggest(out)
    return out


def _suggest(c: dict) -> str:
    if c["dominant"] == "collective":
        return ("cut collective bytes: reshard to keep the dominant matmul "
                "local, or overlap the gather under the scan body")
    if c["dominant"] == "memory":
        if c["usefulness"] < 0.4:
            return ("memory-bound with low usefulness: remat recompute or "
                    "padded dispatch dominates — relax remat / shrink buffers")
        return ("memory-bound: raise arithmetic intensity (larger per-chip "
                "microbatch, fuse the loss, bf16 cache)")
    if c["usefulness"] < 0.5:
        return ("compute-bound but <50% useful flops: eliminate recompute "
                "(remat policy) or dispatch padding (MoE capacity)")
    return "compute-bound and mostly useful flops: near roofline"


def load_cells(mesh_kind: str, results_dir: str = RESULTS_DIR, tag: str = ""):
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, mesh_kind, "*.json"))):
        rec = json.load(open(path))
        if rec.get("tag", "") != tag:
            continue
        if rec["status"] != "ok":
            cells.append({"arch": rec["arch"], "shape": rec["shape"],
                          "status": rec["status"]})
            continue
        cells.append(analyze_cell(rec))
    return cells


def markdown_table(cells) -> str:
    hdr = ("| arch | shape | dominant | compute_s | memory_s | collective_s | "
           "roofline_frac | useful | HBM GiB | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    rows = [hdr]
    for c in cells:
        if c.get("status", "ok") != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | — | — "
                        f"| — | {c['status']} |")
            continue
        rows.append(
            f"| {c['arch']} | {c['shape']} | **{c['dominant']}** "
            f"| {c['compute_s']:.4f} | {c['memory_s']:.4f} "
            f"| {c['collective_s']:.4f} | {c['roofline_fraction']:.3f} "
            f"| {c['usefulness']:.2f} | {c['hbm_gib']:.1f} "
            f"| {c['suggestion']} |")
    return "\n".join(rows)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--results", default=RESULTS_DIR)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    cells = load_cells(args.mesh, args.results, args.tag)
    table = markdown_table(cells)
    print(table)
    ok = [c for c in cells if c.get("status", "ok") == "ok"]
    if ok:
        worst = min(ok, key=lambda c: c["roofline_fraction"])
        coll = max(ok, key=lambda c: c["collective_s"])
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"({worst['roofline_fraction']:.3f})")
        print(f"most collective-bound:   {coll['arch']}/{coll['shape']} "
              f"({coll['collective_s']:.3f}s)")
    if args.out:
        with open(args.out, "w") as f:
            f.write(table + "\n")
    return cells


if __name__ == "__main__":
    main()
