"""Paper Figs. 13/14: speedup vs input tissue coverage (25..100%).

The frontier/tiled engines' advantage over the full sweep should GROW as
coverage shrinks (the sweep wastes passes on pixels that never change —
exactly the FH_GPU vs SR_GPU gap the paper measures)."""

from __future__ import annotations

from benchmarks.common import edt_state, emit, morph_state, timeit
from repro.core.frontier import run_dense
from repro.core.tiles import run_tiled


def main(size: int = 512):
    for cov in (0.25, 0.5, 0.75, 1.0):
        op, state = morph_state(size, coverage=cov, seed=3, n_sweeps=1)
        t_sweep = timeit(lambda: run_dense(op, state, "sweep"))
        t_front = timeit(lambda: run_dense(op, state, "frontier"))
        t_tiled = timeit(lambda: run_tiled(op, state, tile=128,
                                           queue_capacity=64))
        emit(f"fig13/morph/cov={cov}", t_front,
             f"sweep={t_sweep * 1e6:.0f}us;frontier_speedup={t_sweep / t_front:.2f};"
             f"tiled_speedup={t_sweep / t_tiled:.2f}")

        op2, st2 = edt_state(size, coverage=cov, seed=4)
        t2_sweep = timeit(lambda: run_dense(op2, st2, "sweep"))
        t2_tiled = timeit(lambda: run_tiled(op2, st2, tile=128,
                                            queue_capacity=64))
        emit(f"fig14/edt/cov={cov}", t2_tiled,
             f"sweep={t2_sweep * 1e6:.0f}us;tiled_speedup={t2_sweep / t2_tiled:.2f}")


if __name__ == "__main__":
    main()
