"""Paper Table 1: queue design vs initialization depth — plus the §3.2
sequential-vs-batched global-queue drain comparison.

The paper varies the number of FH init raster scans (7..19) to shrink the
initial queue, then compares Naive / prefix-sum (PF) / +thread-queue (TQ)
GPU queue designs.  Our TPU analogues of increasing locality:

  E0 sweep    — no wavefront tracking at all (queue-less lower bound;
                the SR_GPU-style full-grid pass),
  E1 frontier — wavefront tracked as a dense mask (Naive/PF analogue:
                tracks the queue but pays full-grid bandwidth each round),
  E2 tiled    — hierarchical: active-tile queue + VMEM-local drain (the
                paper's TQ/BQ/GBQ multi-level design).

All runs go through ``repro.solve.solve``, so each row reports the same
normalized SolveStats record (rounds / sources / tile drains / overflow
events) — the uniform comparison EXPERIMENTS.md is built on.  A final row
shows what the cost model would pick for each init depth (engine="auto").

The drain section reproduces the paper's central parallelism claim at the
queue level: popping the compacted active-tile queue in concurrent batches
(``drain_batch`` > 1) versus one tile at a time.  ``--json`` (or
``main(json_path=...)``) writes every record to ``BENCH_tiled.json`` so the
perf trajectory is tracked across PRs.

The paper's trend to reproduce: deeper init -> smaller queue -> faster
wavefront phase; hierarchical queueing wins and its advantage grows as the
wavefront sparsifies; batch-draining the queue wins once occupancy covers
the batch (K >= 4).

The kernel section compares the dense Pallas tile kernels against their
in-kernel-queue variants (``kernel_queue=True``, DESIGN.md §2.5): the
serpentine-corridor rows are the sparse-wavefront regime where the queued
kernels win, the seeded-tissue engine rows the dense regime where they
don't, and ``serpentine_kernel_guard`` is the asserting CI check that the
queued kernel never needs more rounds than the dense one.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (maybe_calibrate as common_calibrate,
                               bench_argparser, morph_state, record,
                               timeit, write_json)
from repro.core.tiles import initial_active_tiles
from repro.kernels.morph_tile import morph_tile_solve, morph_tile_solve_queued
from repro.morph.ops import MorphReconstructOp
from repro.solve import solve

DEFAULT_JSON = "BENCH_tiled.json"


def serpentine_state(n: int):
    """1-px serpentine corridor, seed at (0, 0): geodesic depth ~n²/2 with a
    1-2 pixel wavefront — the sparse-seed regime where the in-kernel queue
    (DESIGN.md §2.5) pays off (the paper's point that the queue advantage
    grows as the wavefront sparsifies).  Mirrors tests/test_truncation.py's
    fixture."""
    corridor = np.zeros((n, n), bool)
    corridor[0::2, :] = True
    for i, r in enumerate(range(1, n - 1, 2)):
        corridor[r, (n - 1) if i % 2 == 0 else 0] = True
    mask = np.where(corridor, 100, 0).astype(np.int32)
    marker = np.zeros_like(mask)
    marker[0, 0] = 100
    op = MorphReconstructOp(connectivity=8)
    return op, op.make_state(jnp.asarray(marker), jnp.asarray(mask))


def table1(size: int, records: list):
    for n_sweeps in (1, 2, 3, 4):
        op, state = morph_state(size, coverage=1.0, seed=0, n_sweeps=n_sweeps)
        init_q = int(jnp.sum(op.init_frontier(state)))
        _, st = solve(op, state, engine="frontier")
        total = st.sources_processed
        t0 = timeit(lambda: solve(op, state, engine="sweep")[0])
        t1 = timeit(lambda: solve(op, state, engine="frontier")[0])
        t2 = timeit(lambda: solve(op, state, engine="tiled",
                                  tile=128, queue_capacity=64)[0])
        _, s2 = solve(op, state, engine="tiled", tile=128, queue_capacity=64)
        record(records, f"table1/sweeps={n_sweeps}/E0_sweep", t0,
                init_q=init_q, total_q=total)
        record(records, f"table1/sweeps={n_sweeps}/E1_frontier", t1,
                rounds=st.rounds, speedup_vs_E0=round(t0 / t1, 2))
        record(records, f"table1/sweeps={n_sweeps}/E2_tiled", t2,
                drains=s2.tiles_processed, overflows=s2.overflow_events,
                speedup_vs_E0=round(t0 / t2, 2), vs_E1=round(t1 / t2, 2))
        _, sa = solve(op, state, engine="auto")
        record(records, f"table1/sweeps={n_sweeps}/auto", 0.0,
                picked=sa.engine, tile=sa.tile,
                predicted_cost=round(sa.predicted_cost))


def drain_comparison(size: int, records: list, tile: int = 32,
                     queue_capacity: int = 64):
    """§3.2 parallel queue consumption: sequential scan vs batched drain.

    Sparse seeded markers on a ``size``² grid keep the wavefront thin, so
    the active-tile queue stays well occupied (K >= 4) for many rounds —
    the regime where draining the queue in concurrent batches pays.
    """
    op, state = morph_state(size, coverage=1.0, seed=0, n_sweeps=0,
                            marker_kind="seeded")
    active0 = int(jnp.sum(initial_active_tiles(op, state, tile)))
    t_seq = timeit(lambda: solve(op, state, engine="tiled", tile=tile,
                                 queue_capacity=queue_capacity,
                                 drain_batch=1)[0])
    _, s_seq = solve(op, state, engine="tiled", tile=tile,
                     queue_capacity=queue_capacity, drain_batch=1)
    occupancy = s_seq.tiles_processed / max(s_seq.rounds, 1)
    record(records, f"drain/size={size}/tile={tile}/sequential", t_seq,
            drain_batch=1, rounds=s_seq.rounds, drains=s_seq.tiles_processed,
            active0=active0, occupancy=round(occupancy, 1))
    for db in (4, 8, 16):
        t_b = timeit(lambda: solve(op, state, engine="tiled", tile=tile,
                                   queue_capacity=queue_capacity,
                                   drain_batch=db)[0])
        _, s_b = solve(op, state, engine="tiled", tile=tile,
                       queue_capacity=queue_capacity, drain_batch=db)
        record(records, f"drain/size={size}/tile={tile}/batched", t_b,
                drain_batch=db, rounds=s_b.rounds, drains=s_b.tiles_processed,
                occupancy=round(s_b.tiles_processed / max(s_b.rounds, 1), 1),
                speedup_vs_seq=round(t_seq / t_b, 2))


def kernel_comparison(records: list, sizes=(128, 256), caps=(16, 64)):
    """Dense vs queued Pallas tile kernels (DESIGN.md §2.5).

    Serpentine rows are the sparse-wavefront regime (1-2 px front, depth
    ~n²/2): each run is one whole-image tile drained in-kernel, dense
    full-block rounds against O(capacity) push rounds.  Both variants reach
    bit-identical fixed points in the same number of rounds; only the work
    per round differs, so ``speedup_vs_dense`` isolates the queue itself.
    """
    for n in sizes:
        op, state = serpentine_state(n)
        t_d = timeit(lambda: solve(op, state, engine="tiled-pallas",
                                   tile=n)[0])
        _, sd = solve(op, state, engine="tiled-pallas", tile=n)
        record(records, f"kernel/serpentine={n}/dense", t_d, rounds=sd.rounds)
        for cap in caps:
            t_q = timeit(lambda: solve(op, state, engine="tiled-pallas",
                                       tile=n, kernel_queue=True,
                                       kernel_queue_capacity=cap)[0])
            _, sq = solve(op, state, engine="tiled-pallas", tile=n,
                          kernel_queue=True, kernel_queue_capacity=cap)
            record(records, f"kernel/serpentine={n}/queued", t_q,
                   capacity=cap, rounds=sq.rounds,
                   speedup_vs_dense=round(t_d / t_q, 2))


def engine_queue_comparison(size: int, records: list, tile: int = 128):
    """The honest non-corridor counterpart: seeded-tissue markers (ring
    wavefronts, shallow per-tile depth).  Dense rounds fuse into a couple
    of XLA kernels here while push rounds pay per-round dispatch overhead,
    so dense typically wins — the cost model's reason for only proposing
    kernel_queue on deep sparse drains."""
    op, state = morph_state(size, coverage=1.0, seed=0, marker_kind="seeded")
    t_d = timeit(lambda: solve(op, state, engine="tiled-pallas", tile=tile)[0])
    _, sd = solve(op, state, engine="tiled-pallas", tile=tile)
    record(records, f"engine/seeded={size}/tile={tile}/dense", t_d,
           rounds=sd.rounds, drains=sd.tiles_processed)
    t_q = timeit(lambda: solve(op, state, engine="tiled-pallas", tile=tile,
                               kernel_queue=True)[0])
    _, sq = solve(op, state, engine="tiled-pallas", tile=tile,
                  kernel_queue=True)
    record(records, f"engine/seeded={size}/tile={tile}/queued", t_q,
           capacity=sq.kernel_queue_capacity, rounds=sq.rounds,
           drains=sq.tiles_processed, speedup_vs_dense=round(t_d / t_q, 2))


def serpentine_kernel_guard(records: list, n: int = 64):
    """CI perf-regression guard (ISSUE 6 satellite): on the serpentine
    fixture the queued kernel must reach the *same* fixed point in *no
    more* rounds than the dense kernel — a silently dropped enqueue would
    stall the wavefront and inflate the round count.  Raises
    ``AssertionError`` (failing the CI step) on violation."""
    op, state = serpentine_state(n)
    neut = np.iinfo(np.int32).min
    J = jnp.asarray(np.pad(np.asarray(state["J"]), 1, constant_values=neut))
    I = jnp.asarray(np.pad(np.asarray(state["I"]), 1, constant_values=neut))
    valid = jnp.asarray(np.pad(np.ones((n, n), bool), 1))
    d, di = morph_tile_solve(J, I, valid, connectivity=8,
                             max_iters=(n + 2) ** 2, interpret=True)
    q, qi, spills = morph_tile_solve_queued(J, I, valid, connectivity=8,
                                            max_iters=(n + 2) ** 2,
                                            queue_capacity=16, interpret=True)
    assert np.array_equal(np.asarray(d), np.asarray(q)), \
        "queued kernel diverged from the dense fixed point"
    assert int(qi) <= int(di), \
        f"queued rounds {int(qi)} exceed dense rounds {int(di)}"
    record(records, f"guard/serpentine={n}", 0.0, dense_rounds=int(di),
           queued_rounds=int(qi), spills=int(spills), passed=True)


def main(size: int = 512, json_path: str | None = None,
         drain_size: int | None = None, smoke: bool = False):
    records: list = []
    if smoke:
        table1(128, records)
        drain_comparison(256, records, tile=32)
        kernel_comparison(records, sizes=(64,), caps=(16,))
        engine_queue_comparison(128, records, tile=64)
    else:
        table1(size, records)
        drain_comparison(
            drain_size if drain_size is not None else max(size, 1024),
            records)
        kernel_comparison(records)
        engine_queue_comparison(256, records)
    serpentine_kernel_guard(records)
    write_json(records, json_path)
    return records


if __name__ == "__main__":
    ap = bench_argparser(DEFAULT_JSON,
                         smoke_help="CI profile: small grids, the queued-vs-"
                                    "dense kernel rows, and the asserting "
                                    "serpentine rounds guard")
    ap.add_argument("--drain-size", type=int, default=None,
                    help="grid side for the drain comparison (default: "
                         "max(size, 1024))")
    a = ap.parse_args()
    common_calibrate(a)
    main(a.size, json_path=a.json, drain_size=a.drain_size, smoke=a.smoke)
