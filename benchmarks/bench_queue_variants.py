"""Paper Table 1: queue design vs initialization depth — plus the §3.2
sequential-vs-batched global-queue drain comparison.

The paper varies the number of FH init raster scans (7..19) to shrink the
initial queue, then compares Naive / prefix-sum (PF) / +thread-queue (TQ)
GPU queue designs.  Our TPU analogues of increasing locality:

  E0 sweep    — no wavefront tracking at all (queue-less lower bound;
                the SR_GPU-style full-grid pass),
  E1 frontier — wavefront tracked as a dense mask (Naive/PF analogue:
                tracks the queue but pays full-grid bandwidth each round),
  E2 tiled    — hierarchical: active-tile queue + VMEM-local drain (the
                paper's TQ/BQ/GBQ multi-level design).

All runs go through ``repro.solve.solve``, so each row reports the same
normalized SolveStats record (rounds / sources / tile drains / overflow
events) — the uniform comparison EXPERIMENTS.md is built on.  A final row
shows what the cost model would pick for each init depth (engine="auto").

The drain section reproduces the paper's central parallelism claim at the
queue level: popping the compacted active-tile queue in concurrent batches
(``drain_batch`` > 1) versus one tile at a time.  ``--json`` (or
``main(json_path=...)``) writes every record to ``BENCH_tiled.json`` so the
perf trajectory is tracked across PRs.

The paper's trend to reproduce: deeper init -> smaller queue -> faster
wavefront phase; hierarchical queueing wins and its advantage grows as the
wavefront sparsifies; batch-draining the queue wins once occupancy covers
the batch (K >= 4).
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_argparser, morph_state, record, timeit, write_json
from repro.core.tiles import initial_active_tiles
from repro.solve import solve

DEFAULT_JSON = "BENCH_tiled.json"


def table1(size: int, records: list):
    for n_sweeps in (1, 2, 3, 4):
        op, state = morph_state(size, coverage=1.0, seed=0, n_sweeps=n_sweeps)
        init_q = int(jnp.sum(op.init_frontier(state)))
        _, st = solve(op, state, engine="frontier")
        total = st.sources_processed
        t0 = timeit(lambda: solve(op, state, engine="sweep")[0])
        t1 = timeit(lambda: solve(op, state, engine="frontier")[0])
        t2 = timeit(lambda: solve(op, state, engine="tiled",
                                  tile=128, queue_capacity=64)[0])
        _, s2 = solve(op, state, engine="tiled", tile=128, queue_capacity=64)
        record(records, f"table1/sweeps={n_sweeps}/E0_sweep", t0,
                init_q=init_q, total_q=total)
        record(records, f"table1/sweeps={n_sweeps}/E1_frontier", t1,
                rounds=st.rounds, speedup_vs_E0=round(t0 / t1, 2))
        record(records, f"table1/sweeps={n_sweeps}/E2_tiled", t2,
                drains=s2.tiles_processed, overflows=s2.overflow_events,
                speedup_vs_E0=round(t0 / t2, 2), vs_E1=round(t1 / t2, 2))
        _, sa = solve(op, state, engine="auto")
        record(records, f"table1/sweeps={n_sweeps}/auto", 0.0,
                picked=sa.engine, tile=sa.tile,
                predicted_cost=round(sa.predicted_cost))


def drain_comparison(size: int, records: list, tile: int = 32,
                     queue_capacity: int = 64):
    """§3.2 parallel queue consumption: sequential scan vs batched drain.

    Sparse seeded markers on a ``size``² grid keep the wavefront thin, so
    the active-tile queue stays well occupied (K >= 4) for many rounds —
    the regime where draining the queue in concurrent batches pays.
    """
    op, state = morph_state(size, coverage=1.0, seed=0, n_sweeps=0,
                            marker_kind="seeded")
    active0 = int(jnp.sum(initial_active_tiles(op, state, tile)))
    t_seq = timeit(lambda: solve(op, state, engine="tiled", tile=tile,
                                 queue_capacity=queue_capacity,
                                 drain_batch=1)[0])
    _, s_seq = solve(op, state, engine="tiled", tile=tile,
                     queue_capacity=queue_capacity, drain_batch=1)
    occupancy = s_seq.tiles_processed / max(s_seq.rounds, 1)
    record(records, f"drain/size={size}/tile={tile}/sequential", t_seq,
            drain_batch=1, rounds=s_seq.rounds, drains=s_seq.tiles_processed,
            active0=active0, occupancy=round(occupancy, 1))
    for db in (4, 8, 16):
        t_b = timeit(lambda: solve(op, state, engine="tiled", tile=tile,
                                   queue_capacity=queue_capacity,
                                   drain_batch=db)[0])
        _, s_b = solve(op, state, engine="tiled", tile=tile,
                       queue_capacity=queue_capacity, drain_batch=db)
        record(records, f"drain/size={size}/tile={tile}/batched", t_b,
                drain_batch=db, rounds=s_b.rounds, drains=s_b.tiles_processed,
                occupancy=round(s_b.tiles_processed / max(s_b.rounds, 1), 1),
                speedup_vs_seq=round(t_seq / t_b, 2))


def main(size: int = 512, json_path: str | None = None,
         drain_size: int | None = None):
    records: list = []
    table1(size, records)
    drain_comparison(drain_size if drain_size is not None else max(size, 1024),
                     records)
    write_json(records, json_path)
    return records


if __name__ == "__main__":
    ap = bench_argparser(DEFAULT_JSON)
    ap.add_argument("--drain-size", type=int, default=None,
                    help="grid side for the drain comparison (default: "
                         "max(size, 1024))")
    a = ap.parse_args()
    main(a.size, json_path=a.json, drain_size=a.drain_size)
