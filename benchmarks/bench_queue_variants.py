"""Paper Table 1: queue design vs initialization depth.

The paper varies the number of FH init raster scans (7..19) to shrink the
initial queue, then compares Naive / prefix-sum (PF) / +thread-queue (TQ)
GPU queue designs.  Our TPU analogues of increasing locality:

  E0 sweep    — no wavefront tracking at all (queue-less lower bound;
                the SR_GPU-style full-grid pass),
  E1 frontier — wavefront tracked as a dense mask (Naive/PF analogue:
                tracks the queue but pays full-grid bandwidth each round),
  E2 tiled    — hierarchical: active-tile queue + VMEM-local drain (the
                paper's TQ/BQ/GBQ multi-level design).

All runs go through ``repro.solve.solve``, so each row reports the same
normalized SolveStats record (rounds / sources / tile drains / overflow
events) — the uniform comparison EXPERIMENTS.md is built on.  A final row
shows what the cost model would pick for each init depth (engine="auto").

The paper's trend to reproduce: deeper init -> smaller queue -> faster
wavefront phase; hierarchical queueing wins and its advantage grows as the
wavefront sparsifies.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, morph_state, timeit
from repro.solve import solve


def main(size: int = 512):
    for n_sweeps in (1, 2, 3, 4):
        op, state = morph_state(size, coverage=1.0, seed=0, n_sweeps=n_sweeps)
        init_q = int(jnp.sum(op.init_frontier(state)))
        _, st = solve(op, state, engine="frontier")
        total = st.sources_processed
        t0 = timeit(lambda: solve(op, state, engine="sweep")[0])
        t1 = timeit(lambda: solve(op, state, engine="frontier")[0])
        t2 = timeit(lambda: solve(op, state, engine="tiled",
                                  tile=128, queue_capacity=64)[0])
        _, s2 = solve(op, state, engine="tiled", tile=128, queue_capacity=64)
        emit(f"table1/sweeps={n_sweeps}/E0_sweep", t0,
             f"init_q={init_q};total_q={total}")
        emit(f"table1/sweeps={n_sweeps}/E1_frontier", t1,
             f"rounds={st.rounds};speedup_vs_E0={t0 / t1:.2f}")
        emit(f"table1/sweeps={n_sweeps}/E2_tiled", t2,
             f"drains={s2.tiles_processed};overflows={s2.overflow_events};"
             f"speedup_vs_E0={t0 / t2:.2f};vs_E1={t1 / t2:.2f}")
        _, sa = solve(op, state, engine="auto")
        emit(f"table1/sweeps={n_sweeps}/auto", 0.0,
             f"picked={sa.engine};tile={sa.tile};"
             f"predicted_cost={sa.predicted_cost:.0f}")


if __name__ == "__main__":
    main()
