"""Benchmark entry point: one bench per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--size 512] [--quick] [--skip ...]

Emits ``name,us_per_call,derived`` CSV lines (benchmarks/common.emit).
Wall-clock numbers are CPU-host engine times — they validate the paper's
*trends* (queue design, tile size, coverage, overflow, scaling); the TPU
roofline story lives in benchmarks/roofline.py over the dry-run artifacts.
"""

from __future__ import annotations

import argparse
import os
import time
import traceback


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=512)
    ap.add_argument("--quick", action="store_true",
                    help="256px inputs, skip the multidevice subprocess bench")
    ap.add_argument("--skip", nargs="*", default=[])
    args = ap.parse_args(argv)
    size = 256 if args.quick else args.size

    from benchmarks import (bench_coverage, bench_ops, bench_overflow,
                            bench_queue_variants, bench_serve,
                            bench_tile_size)
    benches = [
        ("queue_variants", lambda: bench_queue_variants.main(size)),
        ("tile_size", lambda: bench_tile_size.main(size)),
        ("coverage", lambda: bench_coverage.main(size)),
        ("overflow", lambda: bench_overflow.main(size)),
        ("ops", lambda: bench_ops.main(size, smoke=args.quick)),
        ("serve", lambda: bench_serve.main(size, smoke=args.quick)),
    ]
    if not args.quick and "multidevice" not in args.skip:
        from benchmarks import bench_multidevice
        benches.append(("multidevice", lambda: bench_multidevice.main(size)))

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if name in args.skip:
            continue
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR")
            traceback.print_exc()
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s", flush=True)

    # roofline summary (if a dry-run sweep exists)
    from benchmarks import roofline
    for mesh in ("single", "multi"):
        d = os.path.join(roofline.RESULTS_DIR, mesh)
        if os.path.isdir(d) and os.listdir(d):
            print(f"\n## roofline ({mesh}-pod)")
            try:
                roofline.main(["--mesh", mesh])
            except Exception:  # noqa: BLE001
                failures += 1
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
