"""Shared benchmark helpers: timing, CSV emission, the ``--json``/``--smoke``
record plumbing (one JSON schema for every ``BENCH_*.json`` — see
EXPERIMENTS.md §BENCH JSON schema), and workload builders."""

from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.images import binary_blobs, tissue_image
from repro.edt.ops import EdtOp
from repro.morph.ops import MorphReconstructOp


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) (block_until_ready on pytrees)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.0f},{derived}", flush=True)


def record(records: list, name: str, seconds: float, **derived):
    """Emit one CSV row and append the matching JSON record.

    This is the single writer behind every ``BENCH_*.json`` row:
    ``{"name": ..., "seconds": ..., <derived fields>}`` — keep the schema in
    sync with EXPERIMENTS.md §BENCH JSON schema.
    """
    emit(name, seconds, ";".join(f"{k}={v}" for k, v in derived.items()))
    records.append({"name": name, "seconds": seconds, **derived})


def write_json(records: list, json_path: Optional[str]):
    """Write the collected records if ``--json`` was requested (no-op else)."""
    if not json_path:
        return
    with open(json_path, "w") as f:
        json.dump(records, f, indent=2)
    print(f"# wrote {len(records)} records to {json_path}", flush=True)


def bench_argparser(default_json: str, *, size: int = 512,
                    smoke_help: Optional[str] = None) -> argparse.ArgumentParser:
    """The shared benchmark CLI: ``--size``, ``--json [PATH]`` and (when
    ``smoke_help`` is given) the ``--smoke`` CI profile flag.  Callers add
    their bench-specific arguments on the returned parser."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=size)
    ap.add_argument("--json", nargs="?", const=default_json, default=None,
                    metavar="PATH",
                    help=f"write records as JSON (default path {default_json})")
    if smoke_help is not None:
        ap.add_argument("--smoke", action="store_true", help=smoke_help)
    return ap


def morph_state(size: int, coverage: float, seed: int = 0, n_sweeps: int = 0,
                marker_kind: str = "seeded"):
    """marker_kind: "seeded" (paper Fig. 1 markers-in-objects; sparse ring
    wavefront) or "dense" (mask - h dome filling; dense wavefront)."""
    marker, mask = tissue_image(size, size, coverage, seed)
    if marker_kind == "seeded":
        from repro.data.images import seeded_marker
        marker = seeded_marker(mask, n_seeds=max(8, size // 20), seed=seed)
    op = MorphReconstructOp(connectivity=8)
    J = jnp.asarray(marker.astype(np.int32))
    I = jnp.asarray(mask.astype(np.int32))
    if n_sweeps:
        from repro.morph.ops import fh_init
        J = fh_init(J, I, n_sweeps=n_sweeps)
    return op, op.make_state(J, I)


def edt_state(size: int, coverage: float, seed: int = 0):
    """Few concentrated background disks -> distances of O(size): the
    long-propagation regime of the paper's whole-slide images."""
    from repro.data.images import bg_disks
    fg = bg_disks(size, size, min(coverage, 0.97), n_disks=6, seed=seed)
    op = EdtOp(connectivity=8)
    return op, op.make_state(jnp.asarray(fg))


def fill_state(size: int, coverage: float = 0.5, seed: int = 0):
    """Blob image whose background splits into border-reachable sea plus
    enclosed holes — the fill-holes regime (border flood depth O(size))."""
    from repro.fill.ops import FillHolesOp
    img = binary_blobs(size, size, coverage, seed)
    op = FillHolesOp()
    return op, op.make_state(jnp.asarray(img))


def _blob_volume(size: int, seed: int = 0, scale: int = 8) -> np.ndarray:
    """Blocky random blob field in [0, 1): a low-res random volume
    upsampled by ``scale`` — cheap 3-D structure at O(size/scale) feature
    scale (no scipy, same spirit as ``binary_blobs``)."""
    rng = np.random.default_rng(seed)
    lo = rng.random((max(2, -(-size // scale)),) * 3)
    vol = lo
    for ax in range(3):
        vol = np.repeat(vol, scale, axis=ax)
    return vol[:size, :size, :size]


def morph_state3d(size: int, seed: int = 0, connectivity: str = "conn26"):
    """3-D reconstruction workload (DESIGN.md §2.7): blob intensity volume
    with sparse seeded markers — the volumetric analogue of the seeded
    2-D regime (wavefronts climb whole blobs)."""
    vol = _blob_volume(size, seed)
    mask = (vol * 200).astype(np.int32)
    rng = np.random.default_rng(seed + 1)
    marker = np.where(rng.random(mask.shape) < 1e-3, mask, 0).astype(np.int32)
    op = MorphReconstructOp(connectivity=connectivity)
    return op, op.make_state(jnp.asarray(marker), jnp.asarray(mask))


def edt_state3d(size: int, seed: int = 0, connectivity: str = "conn26"):
    """Few background balls in a foreground volume -> distances of
    O(size): the long-propagation regime, volumetric."""
    rng = np.random.default_rng(seed)
    z, y, x = np.ogrid[:size, :size, :size]
    fg = np.ones((size, size, size), bool)
    r = max(2, size // 8)
    for _ in range(4):
        c = rng.integers(0, size, 3)
        fg &= ((z - c[0]) ** 2 + (y - c[1]) ** 2 + (x - c[2]) ** 2) > r * r
    op = EdtOp(connectivity=connectivity)
    return op, op.make_state(jnp.asarray(fg))


def label_state(size: int, coverage: float = 0.55, seed: int = 0):
    """Blob foreground with many components of mixed scales — the labeling
    regime (per-component flood depth ~ component diameter)."""
    from repro.label.ops import LabelPropagationOp
    fg = binary_blobs(size, size, coverage, seed)
    op = LabelPropagationOp(connectivity=8)
    return op, op.make_state(jnp.asarray(fg))
