"""Shared benchmark helpers: timing, CSV emission, the ``--json``/``--smoke``
record plumbing (one JSON schema for every ``BENCH_*.json`` — see
EXPERIMENTS.md §BENCH JSON schema), and workload builders."""

from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Optional

import jax
import numpy as np

# The canonical workload builders live in the package now
# (repro.ops.workloads) so calibration and the selection-regression tests
# rebuild the exact inputs these benchmarks time; re-exported here so bench
# scripts (and their committed BENCH_*.json record names) are unchanged.
from repro.ops.workloads import (_blob_volume, edt_state, edt_state3d,
                                 fill_state, label_state, morph_state,
                                 morph_state3d)  # noqa: F401


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) (block_until_ready on pytrees)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.0f},{derived}", flush=True)


def record(records: list, name: str, seconds: float, **derived):
    """Emit one CSV row and append the matching JSON record.

    This is the single writer behind every ``BENCH_*.json`` row:
    ``{"name": ..., "seconds": ..., <derived fields>}`` — keep the schema in
    sync with EXPERIMENTS.md §BENCH JSON schema.
    """
    emit(name, seconds, ";".join(f"{k}={v}" for k, v in derived.items()))
    records.append({"name": name, "seconds": seconds, **derived})


def write_json(records: list, json_path: Optional[str]):
    """Write the collected records if ``--json`` was requested (no-op else)."""
    if not json_path:
        return
    with open(json_path, "w") as f:
        json.dump(records, f, indent=2)
    print(f"# wrote {len(records)} records to {json_path}", flush=True)


def bench_argparser(default_json: str, *, size: int = 512,
                    smoke_help: Optional[str] = None) -> argparse.ArgumentParser:
    """The shared benchmark CLI: ``--size``, ``--json [PATH]`` and (when
    ``smoke_help`` is given) the ``--smoke`` CI profile flag.  Callers add
    their bench-specific arguments on the returned parser."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=size)
    ap.add_argument("--json", nargs="?", const=default_json, default=None,
                    metavar="PATH",
                    help=f"write records as JSON (default path {default_json})")
    ap.add_argument("--calibrate", action="store_true",
                    help="run/refresh the measured cost-model calibration "
                         "(DESIGN.md §2.8) before benchmarking, so auto "
                         "rows select with the MeasuredCostModel")
    if smoke_help is not None:
        ap.add_argument("--smoke", action="store_true", help=smoke_help)
    return ap


def maybe_calibrate(args) -> None:
    """Honor ``--calibrate``: measure + install + persist a calibration
    profile before the bench runs (a no-op without the flag, so default
    bench runs still exercise the analytic cold-start path)."""
    if not getattr(args, "calibrate", False):
        return
    from repro.core.calibrate import run_calibration
    smoke = bool(getattr(args, "smoke", False))
    print(f"# calibrating (smoke={smoke}) ...", flush=True)
    run_calibration(smoke=smoke, save=True, verbose=True)
