"""Paper §4 / Fig. 8: cooperative CPU+device execution vs the solo engines.

The paper's title result is that CPUs and GPUs *cooperatively* consuming one
demand-driven tile queue beat either processor class alone.  This benchmark
reproduces that comparison with the `hybrid` engine (DESIGN.md §2.3): for
each (workload, tile, drain_batch) config it times, back to back in one
process,

  * ``solo_host``   — engine="scheduler" (host FCFS threads only),
  * ``solo_device`` — engine="tiled" (the jitted active-tile queue only),
  * ``coop``        — engine="hybrid" (host threads + a device drain stream
                      on the same queue, ChunkPolicy-sized claims),

on 1024² sparse-seed inputs (seeded morph markers; concentrated-background
EDT — the paper's long-propagation regimes).  Each coop row derives
``speedup_vs_best_solo`` = best-solo seconds / coop seconds (>= 1.0 means
the cooperative pool won that config).

``--json [PATH]`` writes the records to ``BENCH_hybrid.json`` (schema in
EXPERIMENTS.md §BENCH JSON schema); ``--smoke`` shrinks to the CI profile
(one small config, single timed iteration).  CPU-host caveat: see
EXPERIMENTS.md — both "classes" here run on the same socket, so the
reproducible claim is the cooperative overhead/split, not GPU magnitudes.
"""

from __future__ import annotations

from benchmarks.common import (bench_argparser, edt_state, morph_state,
                               record, timeit, write_json)
from repro.solve import solve

DEFAULT_JSON = "BENCH_hybrid.json"


def _workload(kind: str, size: int):
    if kind == "morph":
        return morph_state(size, coverage=1.0, seed=0, n_sweeps=0,
                           marker_kind="seeded")
    return edt_state(size, coverage=0.9, seed=0)


def coop_vs_solo(records: list, kind: str, size: int, tile: int,
                 drain_batch: int = 1, n_workers: int = 1, iters: int = 3):
    """One cooperative-vs-solo config; all three engines timed in-process
    so the comparison is noise-paired."""
    op, state = _workload(kind, size)
    base = f"coop/{kind}/size={size}/tile={tile}"

    t_host = timeit(lambda: solve(op, state, engine="scheduler", tile=tile,
                                  n_workers=n_workers + 1)[0], iters=iters)
    _, s_host = solve(op, state, engine="scheduler", tile=tile,
                      n_workers=n_workers + 1)
    record(records, f"{base}/solo_host", t_host,
           engine="scheduler", n_workers=n_workers + 1,
           tiles=s_host.tiles_processed)

    t_dev = timeit(lambda: solve(op, state, engine="tiled", tile=tile,
                                 queue_capacity=64,
                                 drain_batch=drain_batch)[0], iters=iters)
    _, s_dev = solve(op, state, engine="tiled", tile=tile, queue_capacity=64,
                     drain_batch=drain_batch)
    record(records, f"{base}/solo_device", t_dev,
           engine="tiled", drain_batch=drain_batch,
           tiles=s_dev.tiles_processed, rounds=s_dev.rounds)

    kw = dict(tile=tile, drain_batch=drain_batch, n_workers=n_workers,
              n_device_workers=1)
    t_coop = timeit(lambda: solve(op, state, engine="hybrid", **kw)[0],
                    iters=iters)
    _, s_coop = solve(op, state, engine="hybrid", **kw)
    best_solo = min(t_host, t_dev)
    record(records, f"{base}/coop", t_coop,
           engine="hybrid", n_workers=n_workers, n_device_workers=1,
           drain_batch=drain_batch, tiles=s_coop.tiles_processed,
           rounds=s_coop.rounds, requeued=s_coop.tiles_requeued,
           speedup_vs_host=round(t_host / t_coop, 2),
           speedup_vs_device=round(t_dev / t_coop, 2),
           speedup_vs_best_solo=round(best_solo / t_coop, 2))


def main(size: int = 1024, json_path: str | None = None, smoke: bool = False):
    records: list = []
    if smoke:
        # CI profile: one small config, single timed iteration.
        coop_vs_solo(records, "morph", min(size, 256), tile=64, iters=1)
    else:
        for kind, tile in (("morph", 128), ("morph", 256),
                           ("edt", 128), ("edt", 256)):
            coop_vs_solo(records, kind, size, tile=tile)
    write_json(records, json_path)
    return records


if __name__ == "__main__":
    ap = bench_argparser(
        DEFAULT_JSON, size=1024,
        smoke_help="CI profile: one 256² config, single timed iteration")
    a = ap.parse_args()
    main(a.size, json_path=a.json, smoke=a.smoke)
