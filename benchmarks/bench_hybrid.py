"""Paper §4 / Fig. 8: cooperative CPU+device execution vs the solo engines.

The paper's title result is that CPUs and GPUs *cooperatively* consuming one
demand-driven tile queue beat either processor class alone.  This benchmark
reproduces that comparison with the `hybrid` engine (DESIGN.md §2.3): for
each (workload, tile, drain_batch) config it times, back to back in one
process,

  * ``solo_host``   — engine="scheduler" (host FCFS threads only),
  * ``solo_device`` — engine="tiled" (the jitted active-tile queue only),
  * ``coop``        — engine="hybrid" (host threads + a device drain stream
                      on the same queue, ChunkPolicy-sized claims),

on sparse-seed inputs (seeded morph markers; concentrated-background
EDT — the paper's long-propagation regimes) at 1024² and 2048² under a
fixed 64-slot device queue budget, the §5.2.4 bounded-queue regime where
the cooperative pool's unbounded host-side FCFS queue has its structural
edge.  Each coop row derives ``speedup_vs_best_solo`` = best-solo
seconds / coop seconds (>= 1.0 means the cooperative pool won that
config).

``--json [PATH]`` writes the records to ``BENCH_hybrid.json`` (schema in
EXPERIMENTS.md §BENCH JSON schema); ``--smoke`` shrinks to the CI profile
(one small config, single timed iteration).  CPU-host caveat: see
EXPERIMENTS.md — both "classes" here run on the same socket, so the
reproducible claim is the cooperative overhead/split, not GPU magnitudes.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import (maybe_calibrate as common_calibrate,
                               bench_argparser, edt_state, morph_state,
                               record, write_json)
from repro.solve import solve

DEFAULT_JSON = "BENCH_hybrid.json"


def _workload(kind: str, size: int):
    if kind == "morph":
        return morph_state(size, coverage=1.0, seed=0, n_sweeps=0,
                           marker_kind="seeded")
    return edt_state(size, coverage=0.9, seed=0)


def coop_vs_solo(records: list, kind: str, size: int, tile: int,
                 drain_batch: int = 1, n_workers: int = 1, iters: int = 3):
    """One cooperative-vs-solo config, timed *interleaved*.

    The three engines are sampled round-robin (host, device, coop, host,
    device, coop, ...) rather than as three back-to-back `timeit` medians:
    on a shared host whose background load drifts over minutes, grouping
    an engine's samples into one contiguous window lets a slow period land
    entirely on one engine and skew every derived ratio.  Interleaving
    puts each sample triplet under near-identical machine conditions; the
    per-engine median over rounds is then robust both to outliers and to
    drift."""
    op, state = _workload(kind, size)
    base = f"coop/{kind}/size={size}/tile={tile}"

    hybrid_kw = dict(tile=tile, drain_batch=drain_batch, n_workers=n_workers,
                     n_device_workers=1)
    runs = {
        "host": lambda: solve(op, state, engine="scheduler", tile=tile,
                              n_workers=n_workers + 1),
        "dev": lambda: solve(op, state, engine="tiled", tile=tile,
                             queue_capacity=64, drain_batch=drain_batch),
        "coop": lambda: solve(op, state, engine="hybrid", **hybrid_kw),
    }
    stats = {}
    for name, fn in runs.items():     # warm-up round: compiles + stats
        _, stats[name] = fn()
    samples = {name: [] for name in runs}
    for _ in range(iters):
        for name, fn in runs.items():
            t0 = time.perf_counter()
            out, _ = fn()
            jax.block_until_ready(out)
            samples[name].append(time.perf_counter() - t0)
    t_host, t_dev, t_coop = (float(np.median(samples[n]))
                             for n in ("host", "dev", "coop"))
    s_host, s_dev, s_coop = stats["host"], stats["dev"], stats["coop"]

    record(records, f"{base}/solo_host", t_host,
           engine="scheduler", n_workers=n_workers + 1,
           tiles=s_host.tiles_processed)
    record(records, f"{base}/solo_device", t_dev,
           engine="tiled", drain_batch=drain_batch,
           tiles=s_dev.tiles_processed, rounds=s_dev.rounds)
    best_solo = min(t_host, t_dev)
    record(records, f"{base}/coop", t_coop,
           engine="hybrid", n_workers=n_workers, n_device_workers=1,
           drain_batch=drain_batch, tiles=s_coop.tiles_processed,
           rounds=s_coop.rounds, requeued=s_coop.tiles_requeued,
           speedup_vs_host=round(t_host / t_coop, 2),
           speedup_vs_device=round(t_dev / t_coop, 2),
           speedup_vs_best_solo=round(best_solo / t_coop, 2))


def main(size: int = 1024, json_path: str | None = None, smoke: bool = False):
    records: list = []
    if smoke:
        # CI profile: one small config, single timed iteration.
        coop_vs_solo(records, "morph", min(size, 256), tile=64, iters=1)
    else:
        # Two workloads x two image sizes at a fixed 64-slot device queue
        # budget (tile=64): 1024² puts 256 tiles and 2048² puts 1024 tiles
        # against the 64-slot queue, the paper's §5.2.4 overflow regime —
        # the solo device path pays dense re-seed rounds per overflow while
        # the cooperative pool's host-side FCFS queue is unbounded, which
        # is the structural coop edge the §4 claim rests on.
        for kind, wsize in (("morph", size), ("morph", 2 * size),
                            ("edt", size), ("edt", 2 * size)):
            coop_vs_solo(records, kind, wsize, tile=64)
    write_json(records, json_path)
    return records


if __name__ == "__main__":
    ap = bench_argparser(
        DEFAULT_JSON, size=1024,
        smoke_help="CI profile: one 256² config, single timed iteration")
    a = ap.parse_args()
    common_calibrate(a)
    main(a.size, json_path=a.json, smoke=a.smoke)
