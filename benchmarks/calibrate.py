"""Calibration runner: measure this machine's cost-model profile
(DESIGN.md §2.8) and persist/export it.

    PYTHONPATH=src python benchmarks/calibrate.py                 # full run
    PYTHONPATH=src python benchmarks/calibrate.py --smoke         # CI probe
    PYTHONPATH=src python benchmarks/calibrate.py --json CAL.json # artifact

The measured profile installs into the autotune disk cache
(``~/.cache/repro-iwpp/autotune.json``, keyed by device kind + code
version), from where every later ``solve(engine="auto")`` in any process
picks it up; ``--no-install`` measures and exports without persisting.
``--json`` additionally writes the profile as a standalone artifact —
``benchmarks/CALIBRATION.json`` is one such committed run, replayed by
``tests/test_calibration.py`` as the selection-regression fixture.
"""

from __future__ import annotations

import argparse
import json
import sys


DEFAULT_JSON = "CALIBRATION.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI profile: tiny grids, morph-only host/hybrid/"
                         "Pallas families; structurally complete, "
                         "magnitudes not to be trusted")
    ap.add_argument("--json", nargs="?", const=DEFAULT_JSON, default=None,
                    metavar="PATH",
                    help=f"also write the profile as a standalone JSON "
                         f"artifact (default path {DEFAULT_JSON})")
    ap.add_argument("--no-install", action="store_true",
                    help="measure and export only; do not persist to the "
                         "autotune disk cache")
    ap.add_argument("--ops", nargs="*", default=None,
                    help="restrict to these registered ops (default: every "
                         "op with calibration workloads)")
    ap.add_argument("--size", type=int, default=None,
                    help="override the calibration grid size")
    a = ap.parse_args(argv)

    from repro.core.calibrate import run_calibration

    prof = run_calibration(ops=a.ops, smoke=a.smoke,
                           save=not a.no_install, cal_size=a.size,
                           verbose=True)
    doc = prof.to_dict()
    if a.json:
        with open(a.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote calibration profile to {a.json}", flush=True)
    n_ops = len(doc.get("drain", {}))
    fams = sorted({f for fams in prof.drain.values() for f in fams})
    print(f"# profile: {n_ops} ops, drain families {fams}, "
          f"hybrid_rel_speed={prof.hybrid_rel_speed}, "
          f"installed={not a.no_install}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
