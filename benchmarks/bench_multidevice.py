"""Paper Figs. 10/15/16: multi-processor scaling — and the composed
`shard_map-tiled` hierarchy against the flat `shard_map` engine.

Three layers, matching the paper's experiments:
  * host-scheduler scaling (paper Fig. 10 tiled-vs-non-tiled multicore):
    the demand-driven FCFS TileScheduler with 1..4 workers;
  * device-mesh scaling (paper Figs. 15/16 multi-GPU): the E3 shard_map
    engine on 1/2/4/8 host devices, run in subprocesses so the parent
    process keeps a single-device view;
  * engine composition (the §4-over-§3.2 hierarchy): `shard_map` (dense
    per-device TP drains) vs `shard_map-tiled` (per-shard active-tile
    queues re-seeded each BP round from only the halo-improved tiles) on
    sparse-seeded and dense wavefronts over the same meshes.

``--json [PATH]`` writes every record to ``BENCH_multidevice.json`` (the
perf-trajectory seed, tracked per PR like ``BENCH_tiled.json``); ``--smoke``
shrinks sizes/meshes/iterations to the CI profile (8 fake CPU devices).

CPU-host caveat recorded in EXPERIMENTS.md: all "devices" share one socket
here, so scaling saturates at the memory bus — the numbers validate the
TP/BP pipeline's correctness+overhead, not TPU-pod bandwidth.  The
composition comparison is still meaningful on CPU hosts for the *work*
columns (BP rounds, tiles drained vs whole-shard redrains).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np

from benchmarks.common import (maybe_calibrate as common_calibrate,
                               bench_argparser, record, write_json)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_JSON = "BENCH_multidevice.json"

_CHILD = """
import time
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import run_sharded
from repro.data.images import tissue_image, seeded_marker
from repro.morph.ops import MorphReconstructOp
mesh = jax.make_mesh({mesh_shape}, ("data", "model"))
marker, mask = tissue_image({size}, {size}, 1.0, seed=0)
if {sparse}:
    marker = seeded_marker(mask, n_seeds=max(8, {size} // 20), seed=0)
op = MorphReconstructOp(connectivity=8)
state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                      jnp.asarray(mask.astype(np.int32)))
kw = dict(tile={tile}, queue_capacity=64, drain_batch=1) if {tiled} else {{}}
out, st = run_sharded(op, state, mesh, **kw)   # compile+warm
ts = []
for _ in range({iters}):
    t0 = time.perf_counter()
    out, st = run_sharded(op, state, mesh, **kw)
    jax.block_until_ready(out)
    ts.append(time.perf_counter() - t0)
print("RESULT", np.median(ts), int(st.bp_rounds), int(st.tiles_processed),
      int(st.overflow_events))
"""


def _run_child(ndev, mesh_shape, size, sparse=False, tiled=False, tile=128,
               iters=3):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = _CHILD.format(mesh_shape=mesh_shape, size=size, sparse=sparse,
                         tiled=tiled, tile=tile, iters=iters)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=560, env=env)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][0]
    _, t, rounds, tiles, ovf = line.split()
    return float(t), int(rounds), int(tiles), int(ovf)


def scheduler_scaling(size: int, records: list, workers_list=(1, 2, 4),
                      tag: str = "fig10/scheduler"):
    """Fig 10 analogue: host tile scheduler, 1..N workers.

    Every worker thread drains through solve.py's process-wide compiled
    scheduler drain (the "scheduler-drain" compile-cache entry) — per-bench
    local re-jits used to serialize workers behind tracing and showed up as
    the fig10 workers=2 = 0.47x regression.  Returns {workers: seconds}.
    """
    from repro.core.scheduler import TileScheduler
    from repro.core.tiles import initial_active_tiles
    from repro.data.images import tissue_image
    from repro.morph.ops import MorphReconstructOp
    from repro.solve import _host_tile_fn_for
    import jax.numpy as jnp
    import time

    marker, mask = tissue_image(size, size, 1.0, seed=0)
    op = MorphReconstructOp(connectivity=8)
    T = 128
    tile_fn = _host_tile_fn_for(op, T)

    # warm the shared jitted drain so worker=1 timing excludes compilation
    warm = {"J": np.zeros((T + 2, T + 2), np.int32),
            "I": np.zeros((T + 2, T + 2), np.int32),
            "valid": np.ones((T + 2, T + 2), bool)}
    tile_fn(warm)

    times, base = {}, None
    for workers in workers_list:
        state = {"J": np.minimum(marker, mask).astype(np.int32),
                 "I": mask.astype(np.int32),
                 "valid": np.ones(mask.shape, bool)}
        active = np.asarray(initial_active_tiles(
            op, {k: jnp.asarray(v) for k, v in state.items()}, T))
        t0 = time.perf_counter()
        TileScheduler(state, T, tile_fn, active, n_workers=workers).run()
        t = time.perf_counter() - t0
        times[workers] = t
        base = base or t
        record(records, f"{tag}/workers={workers}", t,
                speedup=round(base / t, 2))
    return times


def scheduler_guard(records: list, size: int = 2048, reps: int = 3):
    """The workers=2 regression guard on a 2048² input.

    On a multi-core host the shared compiled drain makes two workers a
    genuine win, so the floor is 1.0x.  A process pinned to ONE core (this
    repo's CI containers) caps thread parallelism at parity minus GIL +
    XLA-dispatch contention — measured ~0.8-0.9x there — so the floor drops
    to 0.75x, which still trips on the re-trace regression class this
    guards against (workers=2 used to measure 0.47x).  Best-of-`reps`
    ratios, because single-core interleaving is noisy.
    """
    ratios = []
    for rep in range(reps):
        rec_sink = records if rep == 0 else []   # record one rep, time all
        times = scheduler_scaling(size, rec_sink, workers_list=(1, 2),
                                  tag=f"fig10/scheduler{size}")
        ratios.append(times[1] / times[2])
    speedup = max(ratios)
    try:
        cores = len(os.sched_getaffinity(0))
    except AttributeError:                        # non-Linux fallback
        cores = os.cpu_count() or 1
    floor = 1.0 if cores >= 2 else 0.75
    record(records, f"fig10/scheduler{size}/workers=2/guard", 0.0,
           speedup=round(speedup, 2), floor=floor, cores=cores)
    assert speedup >= floor, (
        f"scheduler workers=2 regression: best {speedup:.2f}x vs workers=1 "
        f"on {size}^2 over {reps} reps (floor {floor} at {cores} cores)")


def compose_guard(records: list, threshold: float = 0.5):
    """CI tripwire: the composed shard_map-tiled engine must stay within
    `threshold` of the flat shard_map engine on every recorded config."""
    rows = [r for r in records
            if r["name"].endswith("/shard_map-tiled")
            and "speedup_vs_flat" in r]
    bad = [(r["name"], r["speedup_vs_flat"]) for r in rows
           if r["speedup_vs_flat"] < threshold]
    if bad:
        raise SystemExit(
            f"compose_guard: shard_map-tiled below {threshold}x flat: {bad}")
    print(f"# compose_guard OK: {len(rows)} rows >= {threshold}x flat",
          flush=True)


def mesh_scaling(size: int, records: list, meshes, iters=3):
    """Figs 15/16 analogue: flat shard_map mesh scaling via subprocesses.

    Returns {ndev: (seconds, bp_rounds)} so composition_comparison can
    reuse these dense flat runs instead of re-spawning identical children.
    """
    base, flat_dense = None, {}
    for ndev, mesh_shape in meshes:
        t, rounds, _, _ = _run_child(ndev, mesh_shape, size, iters=iters)
        base = base or t
        flat_dense[ndev] = (t, rounds)
        record(records, f"fig15/mesh/devices={ndev}", t,
                speedup=round(base / t, 2), bp_rounds=rounds)
    return flat_dense


def composition_comparison(size: int, records: list, meshes, tile=128,
                           iters=3, flat_dense=None):
    """shard_map vs shard_map-tiled on sparse/dense seeds over the meshes.

    The regime claim (paper Fig. 12 transplanted to the mesh level): with
    sparse seeds the wavefront touches few tiles per shard, so the composed
    engine's per-shard queue skips the stable interior every BP round; with
    near-full wavefronts the dense drain's full-shard rounds are already
    optimal and the queue is pure overhead.
    """
    for kind, sparse in (("sparse", True), ("dense", False)):
        for ndev, mesh_shape in meshes:
            if not sparse and flat_dense and ndev in flat_dense:
                # identical workload to the fig15 run — reuse, don't respawn
                t_flat, rounds_f = flat_dense[ndev]
            else:
                t_flat, rounds_f, _, _ = _run_child(
                    ndev, mesh_shape, size, sparse=sparse, iters=iters)
            record(records,
                    f"compose/{kind}/devices={ndev}/shard_map", t_flat,
                    bp_rounds=rounds_f)
            t_tiled, rounds_t, tiles, ovf = _run_child(
                ndev, mesh_shape, size, sparse=sparse, tiled=True, tile=tile,
                iters=iters)
            record(records,
                    f"compose/{kind}/devices={ndev}/shard_map-tiled", t_tiled,
                    bp_rounds=rounds_t, tiles=tiles, overflows=ovf,
                    speedup_vs_flat=round(t_flat / t_tiled, 2))


def main(size: int = 512, json_path: str | None = None, smoke: bool = False):
    records: list = []
    if smoke:
        # CI profile: one small grid, the 1-device baseline and the full
        # 8-fake-device mesh, single timed iteration.
        size = 256
        meshes = ((1, (1, 1)), (8, (2, 4)))
        scheduler_scaling(size, records, workers_list=(1, 2))
        # The compose guard needs shards that fit at least one full T=128
        # tile queue: 512²/(2,4) = 256x128 per-shard.  At 256² the tile
        # covers the whole shard and the guard would measure pure queue
        # overhead instead of the hierarchy.
        csize = 512
        flat = mesh_scaling(csize, records, meshes, iters=1)
        composition_comparison(csize, records, meshes, iters=1,
                               flat_dense=flat)
        compose_guard(records)
    else:
        meshes = ((1, (1, 1)), (2, (1, 2)), (4, (2, 2)), (8, (2, 4)))
        scheduler_scaling(size, records)
        scheduler_guard(records)
        flat = mesh_scaling(size, records, meshes)
        composition_comparison(size, records, meshes, flat_dense=flat)
        compose_guard(records)
    write_json(records, json_path)
    return records


if __name__ == "__main__":
    ap = bench_argparser(
        DEFAULT_JSON,
        smoke_help="CI profile: small grid, 1+8 device meshes, 1 iter")
    a = ap.parse_args()
    common_calibrate(a)
    main(a.size, json_path=a.json, smoke=a.smoke)
