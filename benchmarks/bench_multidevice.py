"""Paper Figs. 10/15/16: multi-processor scaling.

Two layers, matching the paper's two experiments:
  * host-scheduler scaling (paper Fig. 10 tiled-vs-non-tiled multicore):
    the demand-driven FCFS TileScheduler with 1..4 workers;
  * device-mesh scaling (paper Figs. 15/16 multi-GPU): the E3 shard_map
    engine on 1/2/4/8 host devices, run in subprocesses so the parent
    process keeps a single-device view.

CPU-host caveat recorded in EXPERIMENTS.md: all "devices" share one socket
here, so scaling saturates at the memory bus — the numbers validate the
TP/BP pipeline's correctness+overhead, not TPU-pod bandwidth.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np

from benchmarks.common import emit

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = """
import time
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import run_sharded
from repro.data.images import tissue_image
from repro.morph.ops import MorphReconstructOp
ndev = {ndev}
shape = {mesh_shape}
mesh = jax.make_mesh(shape, ("data", "model"))
marker, mask = tissue_image({size}, {size}, 1.0, seed=0)
op = MorphReconstructOp(connectivity=8)
state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                      jnp.asarray(mask.astype(np.int32)))
out, rounds = run_sharded(op, state, mesh)   # compile+warm
ts = []
for _ in range(3):
    t0 = time.perf_counter()
    out, rounds = run_sharded(op, state, mesh)
    jax.block_until_ready(out)
    ts.append(time.perf_counter() - t0)
print("RESULT", np.median(ts), int(rounds))
"""


def _run_child(ndev, mesh_shape, size):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = _CHILD.format(ndev=ndev, mesh_shape=mesh_shape, size=size)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=560, env=env)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][0]
    _, t, rounds = line.split()
    return float(t), int(rounds)


def main(size: int = 512):
    # Fig 10 analogue: host tile scheduler, 1..4 workers
    from repro.core.scheduler import TileScheduler
    from repro.core.tiles import initial_active_tiles
    from repro.data.images import tissue_image
    from repro.morph.ops import MorphReconstructOp
    from repro.core.tiles import _tile_local_solve
    import jax.numpy as jnp
    import jax
    import time

    marker, mask = tissue_image(size, size, 1.0, seed=0)
    op = MorphReconstructOp(connectivity=8)
    T = 128
    solve = jax.jit(lambda blk: _tile_local_solve(op, blk, max_iters=4 * T))

    def tile_fn(block):
        blk = {k: jnp.asarray(v) for k, v in block.items()}
        out = solve(blk)
        nb = dict(block)
        nb["J"] = np.asarray(out["J"])
        return nb, None

    # warm the jitted tile solver so worker=1 timing excludes compilation
    warm = {"J": jnp.zeros((T + 2, T + 2), jnp.int32),
            "I": jnp.zeros((T + 2, T + 2), jnp.int32),
            "valid": jnp.ones((T + 2, T + 2), bool)}
    jax.block_until_ready(solve(warm))

    base = None
    for workers in (1, 2, 4):
        state = {"J": np.minimum(marker, mask).astype(np.int32),
                 "I": mask.astype(np.int32),
                 "valid": np.ones(mask.shape, bool)}
        active = np.asarray(initial_active_tiles(
            op, {k: jnp.asarray(v) for k, v in state.items()}, T))
        t0 = time.perf_counter()
        TileScheduler(state, T, tile_fn, active, n_workers=workers).run()
        t = time.perf_counter() - t0
        base = base or t
        emit(f"fig10/scheduler/workers={workers}", t,
             f"speedup={base / t:.2f}")

    # Figs 15/16 analogue: mesh scaling via subprocesses
    base = None
    for ndev, mesh_shape in ((1, (1, 1)), (2, (1, 2)), (4, (2, 2)),
                             (8, (2, 4))):
        t, rounds = _run_child(ndev, mesh_shape, size)
        base = base or t
        emit(f"fig15/mesh/devices={ndev}", t,
             f"speedup={base / t:.2f};bp_rounds={rounds}")


if __name__ == "__main__":
    main()
