"""Paper Fig. 11 + Table 2: performance vs tile size.

FH engine (init scans + wavefront phase) and the SR-style full-sweep
baseline on morphological reconstruction, plus the EDT tile sweep.  The
paper's trend: larger tiles amortize launch overheads up to a knee
(16K x 16K on the GPU; scaled down for the CPU-hosted engines here).
"""

from __future__ import annotations

from benchmarks.common import edt_state, emit, morph_state, timeit
from repro.core.frontier import run_dense
from repro.core.tiles import run_tiled


def main(size: int = 512):
    op, state = morph_state(size, coverage=1.0, seed=1, n_sweeps=1)
    t_sr = timeit(lambda: run_dense(op, state, "sweep"))
    emit("fig11/SR_sweep", t_sr, "baseline")
    for tile in (64, 128, 256):
        t = timeit(lambda: run_tiled(op, state, tile=tile, queue_capacity=64))
        emit(f"fig11/FH_tiled/tile={tile}", t, f"speedup_vs_SR={t_sr / t:.2f}")

    op2, st2 = edt_state(size, coverage=0.5, seed=2)
    t_sweep = timeit(lambda: run_dense(op2, st2, "sweep"))
    emit("table2/EDT_sweep", t_sweep, "baseline")
    for tile in (64, 128, 256):
        t = timeit(lambda: run_tiled(op2, st2, tile=tile, queue_capacity=64))
        emit(f"table2/EDT_tiled/tile={tile}", t,
             f"speedup_vs_sweep={t_sweep / t:.2f}")


if __name__ == "__main__":
    main()
