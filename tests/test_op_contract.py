"""Op-contract conformance suite: every op in ``repro.ops.list_ops()`` is
property-checked against the IWPP contract **for free at registration** —
a new op that ships an ``OpSpec.example_state`` gets all three checks with
zero new test code:

  (a) *idempotence* — a second ``solve()`` pass from the fixed point is a
      bit-exact no-op (the fixed point really is fixed);
  (b) *engine equivalence* — sweep vs frontier vs tiled reach bit-identical
      fixed points on random masked inputs (schedule independence, the
      commutative+monotone theorem of DESIGN.md §1);
  (c) *invalid restore* — invalid cells of every output hold their input
      values bit-for-bit (the engine output contract).

Plus unit tests of the registry itself (register/get/list, by-name solve,
the amend shims, and the satellite error messages that name the op, the
engine, and the registered alternatives).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

import repro.solve as solve_mod
from repro.core.pattern import PropagationOp
from repro.ops import OpSpec, get_op, list_ops, register_op, spec_for
from repro.solve import solve

SHAPE = (24, 28)
# Per-rank conformance shapes: 2-D keeps the historical SHAPE (and RNG
# stream) bit-identical; 3-D exercises the N-D geometry path (DESIGN.md
# §2.7) on a volume small enough for the interpret-mode Pallas kernels.
SHAPES = {2: SHAPE, 3: (10, 12, 14)}
OPS = list_ops()


@pytest.fixture(scope="module", params=sorted(SHAPES),
                ids=lambda nd: f"{nd}d")
def example(request):
    """name -> (spec, op, random masked state) for every registered op, at
    the parametrized spatial rank; ops that do not declare the rank in
    ``OpSpec.supported_ndims`` are absent (tests skip via :func:`_case`)."""
    nd = request.param
    out = {}
    for i, name in enumerate(OPS):
        spec = get_op(name)
        assert spec.example_state is not None, (
            f"op {name!r} has no OpSpec.example_state — the conformance "
            "suite cannot check it for free")
        if nd not in spec.supported_ndims:
            continue
        op, state = spec.example_state(np.random.default_rng(100 + i),
                                       SHAPES[nd])
        out[name] = (spec, op, state)
    return out


def _case(example, name):
    if name not in example:
        pytest.skip(f"op {name!r} does not support this spatial rank "
                    "(OpSpec.supported_ndims)")
    return example[name]


def _assert_tree_equal(a, b, msg):
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"{msg}: leaf {k!r}")


@pytest.mark.parametrize("name", OPS)
def test_second_pass_is_noop(example, name):
    _, op, state = _case(example, name)
    out1, _ = solve(op, state, engine="frontier")
    out2, _ = solve(op, out1, engine="frontier")
    _assert_tree_equal(out1, out2, f"{name}: solve() from the fixed point "
                       "must be a bit-exact no-op")


@pytest.mark.parametrize("name", OPS)
def test_engines_reach_identical_fixed_points(example, name):
    """Compared through ``OpSpec.finalize``: the user-facing result is the
    bit-comparable artifact (EDT's raw Voronoi pointers may resolve
    distance *ties* differently per engine — paper §3.4 — while the
    distance map is identical)."""
    spec, op, state = _case(example, name)
    ref, _ = solve(op, state, engine="frontier")
    ref_result = np.asarray(spec.extract(op, ref))
    for engine in ("sweep", "tiled"):
        out, _ = solve(op, state, engine=engine, tile=8, queue_capacity=8)
        np.testing.assert_array_equal(
            np.asarray(spec.extract(op, out)), ref_result,
            err_msg=f"{name}: {engine} vs frontier fixed point")


@pytest.mark.parametrize("name", OPS)
def test_restore_invalid_holds(example, name):
    _, op, state = _case(example, name)
    inv = ~np.asarray(state["valid"])
    assert inv.any(), "example_state must include invalid pixels"
    out, _ = solve(op, state, engine="frontier")
    static = set(op.static_leaves)
    for k in state:
        if k in static:
            continue
        np.testing.assert_array_equal(
            np.asarray(out[k])[..., inv], np.asarray(state[k])[..., inv],
            err_msg=f"{name}: invalid cells of {k!r} must hold input values")


# ---------------------------------------------------------------------------
# Queued kernel path (kernel_queue=True, DESIGN.md §2.5): every registered
# op that ships queue solvers is exercised through the in-kernel multi-level
# queue automatically; ops without them are skipped with the reason named.
# ---------------------------------------------------------------------------

def _queued_or_skip(name):
    spec = get_op(name)
    if spec.pallas_queue_solver is None:
        pytest.skip(f"op {name!r} registers no OpSpec.pallas_queue_solver; "
                    "the queued kernel path (kernel_queue=True) is opt-in")
    return spec


@pytest.mark.parametrize("capacity,drain_batch", [(4, 2), (None, 1)],
                         ids=["cap4-spills-batched", "cap-default"])
@pytest.mark.parametrize("name", OPS)
def test_queued_kernel_path_reaches_identical_fixed_points(example, name,
                                                           capacity,
                                                           drain_batch):
    """kernel_queue=True vs the frontier reference, through OpSpec.finalize.
    capacity=4 starves the per-block queue so most rounds overflow into the
    dense-spill fallback — correctness must survive the spill path too —
    and drain_batch=2 routes it through the batched (grid-over-batch)
    queued kernels."""
    spec = _queued_or_skip(name)
    _, op, state = _case(example, name)
    ref, _ = solve(op, state, engine="frontier")
    ref_result = np.asarray(spec.extract(op, ref))
    out, st = solve(op, state, engine="tiled-pallas", tile=8,
                    queue_capacity=8, drain_batch=drain_batch,
                    kernel_queue=True, kernel_queue_capacity=capacity)
    assert st.kernel_queue is True
    if capacity is not None:
        assert st.kernel_queue_capacity == capacity
    else:
        assert st.kernel_queue_capacity is not None    # resolved default
    np.testing.assert_array_equal(
        np.asarray(spec.extract(op, out)), ref_result,
        err_msg=f"{name}: queued tiled-pallas vs frontier fixed point")


@pytest.mark.parametrize("name", OPS)
def test_queued_restore_invalid_holds(example, name):
    """The engine output contract holds on the queued path: invalid cells
    of every mutable leaf carry their input values bit-for-bit."""
    _queued_or_skip(name)
    _, op, state = _case(example, name)
    inv = ~np.asarray(state["valid"])
    assert inv.any(), "example_state must include invalid pixels"
    out, _ = solve(op, state, engine="tiled-pallas", tile=8,
                   queue_capacity=8, kernel_queue=True)
    static = set(op.static_leaves)
    for k in state:
        if k in static:
            continue
        np.testing.assert_array_equal(
            np.asarray(out[k])[..., inv], np.asarray(state[k])[..., inv],
            err_msg=f"{name}: invalid cells of {k!r} must hold input "
                    "values on the queued kernel path")


# ---------------------------------------------------------------------------
# Registry mechanics.
# ---------------------------------------------------------------------------

def test_builtin_catalog_is_registered():
    assert set(OPS) >= {"morph", "edt", "fill_holes", "label"}


def test_solve_by_name_equals_instance_call(example):
    # connectivity passed explicitly: the 3-D example op is conn26, while
    # the by-name default would build the op's 2-D default (under which a
    # 3-D state legitimately means a batch of 2-D planes).
    spec, op, state = example["morph"]
    by_name, _ = solve("morph", state, engine="frontier",
                       connectivity=op.connectivity)
    by_inst, _ = solve(op, state, engine="frontier")
    _assert_tree_equal(by_name, by_inst, "by-name vs instance solve")


def test_solve_by_name_builds_state_from_raw_input():
    rng = np.random.default_rng(3)
    fg = jnp.asarray(rng.random((20, 22)) < 0.5)
    out, _ = solve("label", fg, engine="frontier")   # raw array, not a state
    spec = get_op("label")
    ref, _ = solve("label", spec.build_state(spec.factory(), fg),
                   engine="frontier")
    _assert_tree_equal(out, ref, "raw-input vs prebuilt-state solve")


def test_unknown_op_name_lists_alternatives():
    with pytest.raises(ValueError, match="registered ops"):
        get_op("warp-drive")
    with pytest.raises(ValueError, match="registered ops"):
        solve("warp-drive", jnp.zeros((4, 4)))


def test_connectivity_kwarg_is_by_name_only(example):
    _, op, state = example["morph"]
    with pytest.raises(ValueError, match="by-name"):
        solve(op, state, engine="frontier", connectivity=4)


# ---------------------------------------------------------------------------
# Satellite: the connectivity knob is validated per op at make_op() time —
# an unknown name, a bad legacy int, or a known neighborhood the op does
# not declare all raise a ValueError naming the op, the requested value,
# and the supported alternatives (never a downstream shape/TypeError).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", OPS)
def test_unknown_connectivity_raises_with_known_neighborhoods(name):
    spec = get_op(name)
    with pytest.raises(ValueError, match="known neighborhoods"):
        spec.make_op("conn7")
    with pytest.raises(ValueError, match="conn4"):
        spec.make_op(5)          # bad legacy int names the valid spellings
    with pytest.raises(ValueError):
        spec.make_op(True)       # bool is an int; rejected explicitly


@pytest.mark.parametrize("name", OPS)
def test_unsupported_connectivity_names_op_and_alternatives(name):
    spec = get_op(name)
    unsupported = [n for n in ("conn4", "conn8", "conn6", "conn18", "conn26")
                   if n not in spec.neighborhoods]
    if not unsupported:
        pytest.skip(f"op {name!r} declares every built-in neighborhood")
    with pytest.raises(ValueError) as ei:
        spec.make_op(unsupported[0])
    msg = str(ei.value)
    assert name in msg and unsupported[0] in msg
    for supported in spec.neighborhoods:
        assert supported in msg, (
            f"{name}: the error must list the supported neighborhoods")


def test_unsupported_connectivity_raises_through_solve_by_name():
    """The validation fires on the by-name dispatch path too, before any
    state building or engine work."""
    fg = jnp.zeros((6, 7), bool)
    with pytest.raises(ValueError, match="fill_holes"):
        solve("fill_holes", fg, connectivity="conn26")
    with pytest.raises(ValueError, match="known neighborhoods"):
        solve("morph", (fg, fg), connectivity="conn9")


class _UnregisteredOp(PropagationOp):
    pass


def test_missing_pallas_solver_error_names_engine_and_alternatives(example):
    """Satellite: a missing kernel is a clear ValueError naming the op
    class, the requested engine, and list_ops() — not a downstream
    TypeError."""
    with pytest.raises(ValueError) as ei:
        solve_mod._pallas_solver_for(_UnregisteredOp(), interpret=True,
                                     engine="tiled-pallas")
    msg = str(ei.value)
    assert "_UnregisteredOp" in msg and "'tiled-pallas'" in msg
    for name in OPS:
        assert name in msg


def test_missing_scheduler_merge_error_names_engine_and_alternatives():
    with pytest.raises(ValueError) as ei:
        solve_mod._scheduler_merge_for(_UnregisteredOp(), "hybrid")
    msg = str(ei.value)
    assert "_UnregisteredOp" in msg and "'hybrid'" in msg
    for name in OPS:
        assert name in msg


def test_legacy_shims_amend_the_class_index():
    """register_pallas_solver / register_scheduler_merge survive as shims
    over the registry: they patch (or create) the class-indexed spec."""
    class _ShimOp(PropagationOp):
        pass

    sentinel = object()
    solve_mod.register_pallas_solver(_ShimOp,
                                     lambda op, interp, mi: sentinel)
    spec = spec_for(_ShimOp())
    assert spec is not None and spec.op_cls is _ShimOp
    assert spec.pallas_solver(None, True, 1) is sentinel
    assert not spec.name and "_ShimOp" not in " ".join(list_ops())

    merge = lambda op: "merge"
    solve_mod.register_scheduler_merge(_ShimOp, merge)
    spec2 = spec_for(_ShimOp())
    assert spec2.scheduler_merge is merge
    # the earlier amendment is preserved, not clobbered
    assert spec2.pallas_solver(None, True, 1) is sentinel


def test_shim_on_subclass_inherits_parent_plug_points():
    """Regression: amending one plug point on a subclass must keep the
    ancestor's other plug points (the old per-plug-point MRO registries'
    semantics) — register_pallas_solver on an EdtOp subclass must NOT
    silently swap EDT's coordinate-aware scheduler merge for the
    elementwise-max default (which corrupts Voronoi pointers)."""
    from repro.edt.ops import EdtOp

    class _MyEdt(EdtOp):
        pass

    sentinel = object()
    solve_mod.register_pallas_solver(_MyEdt, lambda op, i, m: sentinel)
    spec = spec_for(_MyEdt())
    assert spec.op_cls is _MyEdt
    assert spec.pallas_solver(None, True, 1) is sentinel
    assert spec.scheduler_merge is get_op("edt").scheduler_merge
    # and the real merge still resolves through the solve-layer lookup
    assert solve_mod._scheduler_merge_for(_MyEdt(), "scheduler") is not None


def test_cost_hints_flow_into_input_stats(example):
    """OpSpec cost hints surface in collect_input_stats; morph is the
    reference op, so its hints must leave the historical model untouched."""
    from repro.solve import CostModel, EngineConfig, collect_input_stats
    _, mop, mstate = example["morph"]
    _, eop, estate = example["edt"]
    ms = collect_input_stats(mop, mstate)
    es = collect_input_stats(eop, estate)
    assert (ms.bytes_per_pixel, ms.round_cost_weight) == (4.0, 1.0)
    assert es.bytes_per_pixel > ms.bytes_per_pixel
    assert es.round_cost_weight > ms.round_cost_weight
    model = CostModel()
    cfg = EngineConfig("frontier")
    # same probe numbers, heavier op hints -> strictly costlier estimate
    heavier = dataclasses.replace(ms, bytes_per_pixel=8.0,
                                  round_cost_weight=2.0)
    assert model.cost(heavier, cfg) > model.cost(ms, cfg)


def test_run_op_returns_extracted_result(example):
    """run_op = build + solve + finalize; the wrappers delegate to it."""
    from repro.ops import run_op
    spec, op, state = example["edt"]
    rng = np.random.default_rng(9)
    fg = jnp.asarray(rng.random((20, 22)) < 0.9)
    dist, stats = run_op("edt", fg, engine="frontier")
    out, _ = solve("edt", fg, engine="frontier")
    np.testing.assert_array_equal(np.asarray(dist),
                                  np.asarray(spec.extract(op, out)))
    assert stats.engine == "frontier"


def test_reregistration_invalidates_solver_memo():
    """Regression: replacing a Pallas solver via the shim must not keep
    serving the old kernel out of the solve layer's memo."""
    class _MemoOp(PropagationOp):
        pass

    first = lambda op, i, m: "first-solver"
    solve_mod.register_pallas_solver(_MemoOp, first)
    assert solve_mod._pallas_solver_for(_MemoOp(), True) == "first-solver"
    solve_mod.register_pallas_solver(_MemoOp, lambda op, i, m: "second-solver")
    assert solve_mod._pallas_solver_for(_MemoOp(), True) == "second-solver"


def test_register_op_requires_name():
    with pytest.raises(ValueError, match="name"):
        register_op("", OpSpec(op_cls=_UnregisteredOp,
                               factory=_UnregisteredOp))
