"""Per-arch smoke: REDUCED config, one forward + one train step on CPU,
asserting output shapes and no NaNs (the full configs are exercised only by
the dry-run, per the assignment)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeSpec
from repro.configs.registry import ARCHS, input_specs, smoke_config
from repro.data.pipeline import batch_for_step
from repro.models.transformer import init_params
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_smoke(name):
    cfg = smoke_config(name)
    shape = ShapeSpec("smoke", 32, 4, "train")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    batch = {k: jnp.asarray(v) for k, v in
             batch_for_step(cfg, shape, step=0).items()}
    step_fn = jax.jit(make_train_step(cfg, OptConfig(total_steps=10)))
    new_params, new_opt, metrics = step_fn(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_opt["step"]) == 1
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda acc, pq: acc or bool(jnp.any(pq)), jax.tree_util.tree_map(
            lambda a, b: jnp.any(a != b), params, new_params), False)
    assert moved
    # loss is sane for a random model: ~ln(padded_vocab)
    assert float(metrics["loss"]) < np.log(cfg.padded_vocab) + 2.0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_microbatched_step_matches_single(name):
    """Gradient accumulation must not change the update (up to fp noise)."""
    import dataclasses
    cfg = dataclasses.replace(smoke_config(name), dtype="float32")
    if cfg.moe is not None:
        # microbatch split changes routing capacity; compare drop-free
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    shape = ShapeSpec("smoke", 16, 4, "train")
    params = init_params(cfg, jax.random.PRNGKey(1))
    opt = init_opt_state(params)
    batch = {k: jnp.asarray(v) for k, v in
             batch_for_step(cfg, shape, step=0).items()}
    p1, _, m1 = jax.jit(make_train_step(cfg, OptConfig()))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, OptConfig(), microbatches=2))(
        params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-3)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree_util.tree_leaves(d)) < 0.05   # lr-scaled step gap
