"""Morphological reconstruction: every engine must match the paper's own
sequential algorithms exactly (the update is a unique lattice fixed point)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.frontier import run_dense
from repro.core.tiles import run_tiled
from repro.data.images import tissue_image
from repro.kernels.ops import tile_solver_morph
from repro.morph.ops import MorphReconstructOp, fh_init
from repro.morph.ref import reconstruct_fh, reconstruct_naive, reconstruct_sr


def _case(h, w, coverage=0.8, seed=0, dtype=np.uint8):
    marker, mask = tissue_image(h, w, coverage, seed, dtype=dtype)
    return marker, mask


@pytest.mark.parametrize("conn", [4, 8])
def test_sequential_refs_agree(conn):
    marker, mask = _case(40, 52)
    a = reconstruct_naive(marker, mask, conn)
    b = reconstruct_sr(marker, mask, conn)
    c = reconstruct_fh(marker, mask, conn)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)


@pytest.mark.parametrize("conn", [4, 8])
@pytest.mark.parametrize("engine", ["frontier", "sweep"])
def test_dense_engines_match_ref(conn, engine):
    marker, mask = _case(48, 64, coverage=0.7, seed=1)
    ref = reconstruct_fh(marker, mask, conn)
    op = MorphReconstructOp(connectivity=conn)
    state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)))
    out, stats = run_dense(op, state, engine)
    np.testing.assert_array_equal(np.asarray(out["J"]), ref.astype(np.int32))
    assert int(stats.rounds) > 0


def test_frontier_does_less_work_than_sweep():
    """The paper's core claim: wavefront tracking avoids useless work."""
    marker, mask = _case(64, 64, coverage=0.4, seed=2)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)))
    _, s_frontier = run_dense(op, state, "frontier")
    _, s_sweep = run_dense(op, state, "sweep")
    assert float(s_frontier.sources_processed) < float(s_sweep.sources_processed)


@pytest.mark.parametrize("tile,cap", [(32, 64), (32, 4), (64, 16)])
def test_tiled_engine_matches_ref(tile, cap):
    marker, mask = _case(96, 96, coverage=0.6, seed=3)
    ref = reconstruct_fh(marker, mask, 8)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)))
    out, stats = run_tiled(op, state, tile=tile, queue_capacity=cap)
    np.testing.assert_array_equal(np.asarray(out["J"]), ref.astype(np.int32))


def test_tiled_overflow_retains_correctness():
    """paper §5.2.4: exceeding queue capacity only costs re-execution."""
    marker, mask = _case(128, 128, coverage=0.9, seed=4)
    ref = reconstruct_fh(marker, mask, 8)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)))
    out, stats = run_tiled(op, state, tile=32, queue_capacity=2)
    assert int(stats.overflow_events) > 0
    np.testing.assert_array_equal(np.asarray(out["J"]), ref.astype(np.int32))


def test_tiled_with_pallas_solver():
    marker, mask = _case(64, 64, coverage=0.8, seed=5)
    ref = reconstruct_fh(marker, mask, 8)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)))
    out, _ = run_tiled(op, state, tile=32, queue_capacity=32,
                       tile_solver=tile_solver_morph(8, interpret=True))
    np.testing.assert_array_equal(np.asarray(out["J"]), ref.astype(np.int32))


def _dir_recurrence(J, I):
    """Sequential column-direction pass: v[r] = min(I[r], max(J[r], v[r-1]))."""
    out = np.empty_like(J)
    prev = np.full(J.shape[1], np.iinfo(J.dtype).min, J.dtype)
    for r in range(J.shape[0]):
        prev = np.minimum(I[r], np.maximum(J[r], prev))
        out[r] = prev
    return out


def test_fh_init_scan_matches_directional_recurrence():
    """The O(log n) associative clamp-scan equals the sequential directional
    recurrence of paper Algorithm 5 (row pass then column pass)."""
    marker, mask = _case(33, 47, coverage=0.9, seed=6)
    I = mask.astype(np.int32)
    J = np.minimum(marker, mask).astype(np.int32)
    # Algorithm 5 lines 2-8: row-wise forward then column-wise forward.
    ref = _dir_recurrence(_dir_recurrence(J.T, I.T).T, I)
    from repro.morph.ops import raster_pass_scan
    out = raster_pass_scan(jnp.asarray(J), jnp.asarray(I))
    np.testing.assert_array_equal(np.asarray(out), ref)


@pytest.mark.parametrize("n_sweeps", [1, 3])
def test_fh_pipeline_init_plus_wavefront(n_sweeps):
    """End-to-end FH_GPU analogue: scan init + frontier phase == exact FH."""
    marker, mask = _case(48, 48, coverage=0.8, seed=7)
    ref = reconstruct_fh(marker, mask, 8)
    op = MorphReconstructOp(connectivity=8)
    J0 = fh_init(jnp.asarray(marker.astype(np.int32)),
                 jnp.asarray(mask.astype(np.int32)), n_sweeps=n_sweeps)
    state = {"J": J0, "I": jnp.asarray(mask.astype(np.int32)),
             "valid": jnp.ones(J0.shape, bool)}
    out, _ = run_dense(op, state, "frontier")
    np.testing.assert_array_equal(np.asarray(out["J"]), ref.astype(np.int32))


def test_float_and_uint8_dtypes():
    marker, mask = _case(32, 32, dtype=np.uint8)
    ref = reconstruct_fh(marker, mask, 8)
    op = MorphReconstructOp(connectivity=8)
    # float32
    state = op.make_state(jnp.asarray(marker, jnp.float32),
                          jnp.asarray(mask, jnp.float32))
    out, _ = run_dense(op, state, "frontier")
    np.testing.assert_array_equal(np.asarray(out["J"]).astype(np.uint8), ref)
