"""Serving engine behaviour + end-to-end training integration."""

import dataclasses
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeSpec
from repro.configs.registry import smoke_config
from repro.data.pipeline import DataIterator
from repro.models.transformer import (decode_step, forward, init_params,
                                      logits_from_hidden)
from repro.serve.engine import Request, ServeEngine
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _greedy_reference(params, cfg, prompt, n_new):
    """Naive greedy decoding via repeated teacher-forced forward."""
    toks = list(map(int, prompt))
    for _ in range(n_new):
        h, _ = forward(params, cfg, {"tokens": jnp.asarray([toks], jnp.int32)})
        lg = logits_from_hidden(params, cfg, h)
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_naive_greedy():
    cfg = dataclasses.replace(smoke_config("gemma2-27b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.array([5, 9, 2, 7, 11, 3], np.int32)
    n_new = 6
    ref = _greedy_reference(params, cfg, prompt, n_new)
    eng = ServeEngine(params, cfg, n_slots=2, max_len=64)
    req = Request(rid=0, prompt=prompt, max_new=n_new)
    assert eng.add_request(req)
    eng.run_to_completion()
    assert req.out[:n_new] == ref


def test_engine_continuous_batching():
    """Slots recycle: more requests than slots all finish."""
    cfg = dataclasses.replace(smoke_config("xlstm-350m"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(params, cfg, n_slots=2, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(2, 100, 5).astype(np.int32),
                    max_new=3 + i) for i in range(5)]
    pending = list(reqs)
    done = []
    for _ in range(200):
        while pending and eng.add_request(pending[0]):
            pending.pop(0)
        done.extend(eng.step())
        if not pending and not eng.active:
            break
    assert len(done) == 5
    for r in reqs:
        assert len(r.out) >= r.max_new


def test_training_loss_decreases():
    """A tiny model memorizes a repeating synthetic stream."""
    cfg = dataclasses.replace(smoke_config("qwen2-vl-2b"), n_layers=2)
    # token-input variant of the vlm backbone for a pure-LM fit test
    cfg = dataclasses.replace(cfg, embed_inputs="tokens", mrope_sections=None,
                              vocab_size=64, dtype="float32")
    shape = ShapeSpec("t", 32, 8, "train")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(
        cfg, OptConfig(lr=3e-3, warmup_steps=5, total_steps=60)))
    it = DataIterator(cfg, shape)
    first = None
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        batch = {k: (v % 64 if v.dtype == jnp.int32 else v)
                 for k, v in batch.items()}
        params, opt, m = step_fn(params, opt, batch)
        if first is None:
            first = float(m["loss"])
    last = float(m["loss"])
    assert last < first - 0.3, (first, last)


def test_train_cli_checkpoint_restart(tmp_path):
    """launch/train.py restarts from the latest checkpoint."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    args = [sys.executable, "-m", "repro.launch.train", "--arch", "xlstm-350m",
            "--smoke", "--batch", "2", "--seq", "32", "--ckpt-dir",
            str(tmp_path), "--ckpt-every", "5", "--log-every", "5"]
    r1 = subprocess.run(args + ["--steps", "5"], capture_output=True,
                        text=True, timeout=560, env=env)
    assert r1.returncode == 0, r1.stderr
    r2 = subprocess.run(args + ["--steps", "10"], capture_output=True,
                        text=True, timeout=560, env=env)
    assert r2.returncode == 0, r2.stderr
    assert "restored step 5" in r2.stdout
    assert "step 10" in r2.stdout
