"""The hybrid cooperative CPU+device engine (DESIGN.md §2.3).

Covers the cooperative pool shapes (host-only / device-only / mixed),
failure injection (a dead worker's tiles are re-queued and the surviving
worker class finishes the queue with output bit-identical to the E1
reference), the chunk-sizing policy (EWMA converges toward the measured
relative speed), and the `incomplete` surfacing contract.
"""

import threading
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

import repro.solve as solve_mod
from repro.core.scheduler import ChunkPolicy, DeviceWorker, TileScheduler
from repro.core.tiles import default_batched_solver, initial_active_tiles
from repro.data.images import bg_disks, seeded_marker, tissue_image
from repro.edt.ops import EdtOp, distance_map
from repro.edt.ref import edt_wavefront
from repro.morph.ops import MorphReconstructOp
from repro.morph.ref import reconstruct_fh
from repro.solve import solve


@pytest.fixture(scope="module")
def morph_case():
    _, mask = tissue_image(96, 96, coverage=0.8, seed=5)
    marker = seeded_marker(mask, n_seeds=6, seed=5)
    ref = reconstruct_fh(marker.copy(), mask, connectivity=8).astype(np.int32)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)))
    return op, state, ref


@pytest.fixture(scope="module")
def edt_case():
    fg = bg_disks(64, 64, coverage=0.9, n_disks=3, seed=7)
    ref_M, _ = edt_wavefront(fg, connectivity=8)
    op = EdtOp(connectivity=8)
    return op, op.make_state(jnp.asarray(fg)), ref_M


@pytest.fixture
def fail_inject(monkeypatch):
    """Set solve's hybrid fault-injection hook for one test."""
    def _set(spec):
        monkeypatch.setattr(solve_mod, "_HYBRID_FAIL_INJECT", spec)
    yield _set


# ---------------------------------------------------------------------------
# pool shapes: host-only / device-only / mixed all reach the E1 fixed point
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pool", [
    dict(n_workers=2, n_device_workers=0),           # host-only
    dict(n_workers=0, n_device_workers=1),           # device-only
    dict(n_workers=2, n_device_workers=1),           # mixed (the paper's §4)
    dict(n_workers=1, n_device_workers=2),           # mixed, 2 device streams
])
def test_hybrid_pool_shapes_match_morph_ref(morph_case, pool):
    op, state, ref = morph_case
    out, st = solve(op, state, engine="hybrid", tile=16, drain_batch=4, **pool)
    np.testing.assert_array_equal(np.asarray(out["J"]), ref)
    assert st.engine == "hybrid" and not st.incomplete
    assert st.tiles_processed > 0 and st.rounds >= 1


def test_hybrid_pallas_device_drain_matches_ref(morph_case, edt_case):
    op, state, ref = morph_case
    out, st = solve(op, state, engine="hybrid", tile=16, drain_batch=2,
                    n_workers=1, n_device_workers=1, hybrid_pallas=True)
    np.testing.assert_array_equal(np.asarray(out["J"]), ref)
    assert not st.incomplete
    eop, estate, ref_M = edt_case
    out, st = solve(eop, estate, engine="hybrid", tile=16, drain_batch=2,
                    n_workers=1, n_device_workers=1, hybrid_pallas=True)
    np.testing.assert_array_equal(np.asarray(distance_map(out)), ref_M)
    assert not st.incomplete


def test_hybrid_edt_distance_exact(edt_case):
    op, state, ref_M = edt_case
    out, st = solve(op, state, engine="hybrid", tile=16, n_workers=2,
                    n_device_workers=1)
    np.testing.assert_array_equal(np.asarray(distance_map(out)), ref_M)
    assert not st.incomplete


def test_hybrid_empty_pool_raises(morph_case):
    op, state, _ = morph_case
    with pytest.raises(ValueError, match="hybrid"):
        solve(op, state, engine="hybrid", n_workers=0, n_device_workers=0)
    with pytest.raises(ValueError, match="worker"):
        TileScheduler({"J": np.zeros((32, 32), np.int32)}, 16, None,
                      np.ones((2, 2), bool), n_workers=0)


# ---------------------------------------------------------------------------
# failure injection: the surviving worker class finishes the queue
# ---------------------------------------------------------------------------

def test_host_worker_death_device_finishes_bit_identical(morph_case, fail_inject):
    """Kill the (only) host worker mid-run: its tiles are re-queued and the
    device worker drains the rest — output bit-identical to the reference
    (the §5.2.4 idempotence argument on the cooperative pool)."""
    op, state, ref = morph_case
    fail_inject((0, 0))      # worker id 0 = the host thread; dies on 1st tile
    out, st = solve(op, state, engine="hybrid", tile=16, drain_batch=4,
                    n_workers=1, n_device_workers=1)
    np.testing.assert_array_equal(np.asarray(out["J"]), ref)
    assert st.requeues >= 1
    assert not st.incomplete


def test_device_worker_death_hosts_finish_distance_exact(edt_case, fail_inject):
    """Kill the device worker on its first claimed chunk: host threads
    finish the queue, EDT output distance-exact against the wavefront
    reference."""
    op, state, ref_M = edt_case
    fail_inject((2, 0))      # worker ids 0,1 = hosts; 2 = the device worker
    out, st = solve(op, state, engine="hybrid", tile=16, drain_batch=4,
                    n_workers=2, n_device_workers=1)
    np.testing.assert_array_equal(np.asarray(distance_map(out)), ref_M)
    assert st.requeues >= 1
    assert not st.incomplete


def test_hybrid_incomplete_surfaced(morph_case, fail_inject, monkeypatch):
    """Every worker of every wave dying must never be reported as a fixed
    point: SolveStats.incomplete=True plus a RuntimeWarning."""
    op, state, ref = morph_case
    fail_inject(("all", 0))
    monkeypatch.setattr(TileScheduler, "max_survivor_waves", 2)
    with pytest.warns(RuntimeWarning, match="NOT at its fixed point"):
        out, st = solve(op, state, engine="hybrid", tile=16, n_workers=1,
                        n_device_workers=1, max_rounds=1)
    assert st.incomplete
    assert st.tiles_processed == 0
    # the partial state is monotone-valid: below the fixed point, above the
    # (clipped) marker — never corrupted
    J = np.asarray(out["J"])
    assert (J <= ref).all() and (J >= np.asarray(state["J"])).all()


def test_hybrid_total_failure_degrades_to_dense_rounds(fail_inject, monkeypatch):
    """With every scheduler pass losing every worker, the BP verification
    round alone still reaches the exact fixed point (E1-speed degradation:
    one dense round per BP round) — slow, but never wrong."""
    _, mask = tissue_image(32, 32, coverage=0.9, seed=3)
    marker = seeded_marker(mask, n_seeds=1, seed=3)
    ref = reconstruct_fh(marker.copy(), mask, connectivity=8).astype(np.int32)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)))
    fail_inject(("all", 0))
    monkeypatch.setattr(TileScheduler, "max_survivor_waves", 1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        out, st = solve(op, state, engine="hybrid", tile=16, n_workers=1,
                        n_device_workers=0)
    np.testing.assert_array_equal(np.asarray(out["J"]), ref)
    assert not st.incomplete
    assert st.tiles_processed == 0 and st.rounds > 1


# ---------------------------------------------------------------------------
# chunk sizing: cost-model seed, EWMA refinement
# ---------------------------------------------------------------------------

def test_chunk_policy_seed_and_clamp():
    assert ChunkPolicy(rel_speed=4.0, max_chunk=8).chunk() == 4
    assert ChunkPolicy(rel_speed=100.0, max_chunk=8).chunk() == 8   # clamp hi
    # The low clamp is 2, not 1: even a slow device stream claims one tile
    # of look-ahead to amortize its per-claim lock/wakeup overhead (the
    # claim-time half-queue cap handles the endgame).
    assert ChunkPolicy(rel_speed=0.1, max_chunk=8).chunk() == 2     # clamp lo


def test_chunk_policy_ewma_converges_toward_faster_worker():
    """The measured ratio overrides the seed: a device measured 5x faster
    than the host converges the chunk to 5; a device that *slows down*
    below host speed shrinks the chunk back to the look-ahead floor."""
    p = ChunkPolicy(rel_speed=2.0, max_chunk=16, alpha=0.25)
    for _ in range(50):
        p.observe_host(10e-3)
        p.observe_device(2e-3)
    assert abs(p.rel_speed - 5.0) < 0.25
    assert p.chunk() == 5
    for _ in range(100):
        p.observe_device(20e-3)    # device now 2x *slower* than the host
    assert p.rel_speed < 1.0
    assert p.chunk() == 2


def test_chunk_policy_seed_used_until_both_classes_measured():
    p = ChunkPolicy(rel_speed=6.0, max_chunk=16)
    p.observe_host(1e-3)           # device never measured yet
    assert p.chunk() == 6


def test_chunk_policy_is_thread_safe_under_concurrent_observation():
    p = ChunkPolicy(rel_speed=3.0, max_chunk=16)

    def host():
        for _ in range(500):
            p.observe_host(8e-3)

    def dev():
        for _ in range(500):
            p.observe_device(4e-3)

    ts = [threading.Thread(target=host), threading.Thread(target=dev)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert abs(p.rel_speed - 2.0) < 0.1


# ---------------------------------------------------------------------------
# scheduler-level: device workers share the queue with host threads
# ---------------------------------------------------------------------------

def test_device_worker_on_raw_scheduler_matches_ref():
    """A DeviceWorker plugged straight into TileScheduler (no solve() glue):
    batched drains + commutative merge reach the host path's fixed point."""
    marker, mask = tissue_image(64, 64, coverage=0.7, seed=9)
    ref = reconstruct_fh(marker, mask, 8).astype(np.int32)
    op = MorphReconstructOp(connectivity=8)
    state = {"J": np.minimum(marker, mask).astype(np.int32),
             "I": mask.astype(np.int32),
             "valid": np.ones(mask.shape, bool)}
    T = 16
    active = np.asarray(initial_active_tiles(
        op, {k: jnp.asarray(v) for k, v in state.items()}, T))
    batch_fn = default_batched_solver(op, T)
    dev = DeviceWorker(batch_fn, drain_batch=4)
    sched = TileScheduler(state, T, None, active, n_workers=0,
                          mutable=("J",), device_workers=[dev],
                          pad_values={"J": np.iinfo(np.int32).min,
                                      "I": np.iinfo(np.int32).min,
                                      "valid": False})
    st = sched.run()
    np.testing.assert_array_equal(state["J"], ref)
    assert st.tiles_processed > 0 and not st.incomplete
    # all work was done by the device worker (wid 0 is the only worker)
    assert sum(st.per_worker.values()) == st.tiles_processed
