import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolated_autotune_disk(tmp_path, monkeypatch):
    """Point the persisted autotune cache (core.autotune_disk) at a per-test
    tmpdir: tests must neither read winners measured on the developer's
    machine nor pollute ~/.cache with winners measured under test fixtures."""
    monkeypatch.setenv("REPRO_IWPP_CACHE_DIR", str(tmp_path / "autotune-cache"))
