import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolated_autotune_disk(tmp_path, monkeypatch):
    """Point the persisted autotune cache (core.autotune_disk) at a per-test
    tmpdir: tests must neither read winners measured on the developer's
    machine nor pollute ~/.cache with winners measured under test fixtures.
    The process-wide memoized calibration profile (core.calibrate) is reset
    on both sides for the same reason — a profile installed by one test (or
    present on the developer's machine) must not leak into another test's
    engine selection."""
    monkeypatch.setenv("REPRO_IWPP_CACHE_DIR", str(tmp_path / "autotune-cache"))
    from repro.core import calibrate
    calibrate.reset_profile_cache()
    yield
    calibrate.reset_profile_cache()
