"""Per-kernel shape x dtype sweeps against the pure-jnp oracles (interpret
mode executes the Pallas kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.images import binary_blobs, tissue_image
from repro.edt.ops import EdtOp
from repro.edt.ref import SENTINEL
from repro.kernels.edt_tile import edt_tile_solve
from repro.kernels.morph_tile import morph_tile_solve
from repro.kernels.ops import antiraster_pass_kernel, morph_tile_pallas, raster_pass_kernel
from repro.kernels.raster_scan import raster_down
from repro.kernels.ref import edt_tile_ref, morph_tile_ref, raster_down_ref

SHAPES = [(34, 34), (66, 130), (130, 130)]     # (T+2, T+2) halo blocks


def _halo_case(h, w, seed, dtype):
    marker, mask = tissue_image(h, w, 0.8, seed)
    J = jnp.asarray(np.minimum(marker, mask).astype(dtype))
    I = jnp.asarray(mask.astype(dtype))
    valid = jnp.ones((h, w), bool)
    return J, I, valid


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
@pytest.mark.parametrize("conn", [4, 8])
def test_morph_tile_kernel(shape, dtype, conn):
    J, I, valid = _halo_case(*shape, seed=1, dtype=dtype)
    out, iters = morph_tile_solve(J, I, valid, connectivity=conn, interpret=True)
    ref = morph_tile_ref(J, I, valid, connectivity=conn)
    inner = (slice(1, -1), slice(1, -1))
    np.testing.assert_allclose(np.asarray(out)[inner], np.asarray(ref)[inner])
    assert int(iters) >= 1


@pytest.mark.parametrize("dtype", [np.uint8, np.int16])
def test_morph_tile_kernel_small_dtypes(dtype):
    """ops.py upcast policy: uint8/int16 payloads exact through int32."""
    J, I, valid = _halo_case(34, 34, seed=2, dtype=dtype)
    out, _ = morph_tile_pallas(J, I, valid, connectivity=8, interpret=True)
    assert out.dtype == J.dtype
    ref = morph_tile_ref(J.astype(jnp.int32), I.astype(jnp.int32), valid, 8)
    inner = (slice(1, -1), slice(1, -1))
    np.testing.assert_array_equal(np.asarray(out)[inner].astype(np.int32),
                                  np.asarray(ref)[inner])


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("conn", [4, 8])
def test_edt_tile_kernel(shape, conn):
    h, w = shape
    fg = binary_blobs(h, w, 0.5, seed=3)
    op = EdtOp(connectivity=conn)
    st = op.make_state(jnp.asarray(fg))
    o_r, o_c, iters = edt_tile_solve(st["vr"][0], st["vr"][1], st["valid"],
                                     st["row"], st["col"],
                                     connectivity=conn, interpret=True)
    r_r, r_c = edt_tile_ref(st["vr"][0], st["vr"][1], st["valid"],
                            st["row"], st["col"], connectivity=conn)
    inner = (slice(1, -1), slice(1, -1))
    # Compare distances (Voronoi ties may resolve differently)
    def d2(rr, cc):
        return (np.asarray(st["row"]) - np.asarray(rr)) ** 2 \
            + (np.asarray(st["col"]) - np.asarray(cc)) ** 2
    np.testing.assert_array_equal(d2(o_r, o_c)[inner], d2(r_r, r_c)[inner])


@pytest.mark.parametrize("shape", [(32, 64), (128, 128), (40, 512)])
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_raster_down_kernel(shape, dtype):
    J, I, _ = _halo_case(*shape, seed=4, dtype=dtype)
    bw = min(512, shape[1])
    out = raster_down(J, I, block_w=bw, interpret=True)
    ref = raster_down_ref(J, I)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))


def test_raster_pass_kernels_match_scan():
    """Kernel-based directional passes == associative-scan formulation."""
    from repro.morph.ops import antiraster_pass_scan, raster_pass_scan
    J, I, _ = _halo_case(64, 64, seed=5, dtype=np.int32)
    np.testing.assert_array_equal(
        np.asarray(raster_pass_kernel(J, I, interpret=True)),
        np.asarray(raster_pass_scan(J, I)))
    np.testing.assert_array_equal(
        np.asarray(antiraster_pass_kernel(J, I, interpret=True)),
        np.asarray(antiraster_pass_scan(J, I)))


@pytest.mark.parametrize("conn", [4, 8])
def test_morph_tile_kernel_batched_matches_single(conn):
    """Grid-over-batch kernel == K independent single-block drains."""
    blocks = [_halo_case(34, 34, seed=s, dtype=np.int32) for s in range(4)]
    J = jnp.stack([b[0] for b in blocks])
    I = jnp.stack([b[1] for b in blocks])
    valid = jnp.stack([b[2] for b in blocks])
    from repro.kernels.morph_tile import morph_tile_solve_batched
    out, iters = morph_tile_solve_batched(J, I, valid, connectivity=conn,
                                          interpret=True)
    assert iters.shape == (4,)
    for k, (Jk, Ik, vk) in enumerate(blocks):
        ref, _ = morph_tile_solve(Jk, Ik, vk, connectivity=conn, interpret=True)
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(ref))


@pytest.mark.parametrize("conn", [4, 8])
def test_edt_tile_kernel_batched_matches_single(conn):
    from repro.kernels.edt_tile import edt_tile_solve_batched
    op = EdtOp(connectivity=conn)
    states = [op.make_state(jnp.asarray(binary_blobs(34, 34, 0.5, seed=s)))
              for s in range(3)]
    vr_r = jnp.stack([s["vr"][0] for s in states])
    vr_c = jnp.stack([s["vr"][1] for s in states])
    valid = jnp.stack([s["valid"] for s in states])
    row = jnp.stack([s["row"] for s in states])
    col = jnp.stack([s["col"] for s in states])
    o_r, o_c, iters = edt_tile_solve_batched(vr_r, vr_c, valid, row, col,
                                             connectivity=conn, interpret=True)
    assert iters.shape == (3,)
    for k, st in enumerate(states):
        r_r, r_c, _ = edt_tile_solve(st["vr"][0], st["vr"][1], st["valid"],
                                     st["row"], st["col"],
                                     connectivity=conn, interpret=True)
        np.testing.assert_array_equal(np.asarray(o_r[k]), np.asarray(r_r))
        np.testing.assert_array_equal(np.asarray(o_c[k]), np.asarray(r_c))
