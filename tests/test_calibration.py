"""Measured calibration profiles and the selection-regression harness
(DESIGN.md §2.8; ISSUE 9).

The committed ``benchmarks/CALIBRATION.json`` is a full calibration run
recorded on the same machine/commit lineage as the committed
``BENCH_*.json`` records.  The harness here replays every committed bench
group: rebuild the exact workload the record named (via
``repro.ops.workloads`` — the same builders the benchmarks use), rank the
group's engine configs with the :class:`~repro.solve.MeasuredCostModel`
over the committed profile, and assert the model's pick is within
``SELECTION_TOL`` of the measured-fastest config.  This is what keeps
``auto`` honest: any cost-model edit that re-breaks a selection the
benchmarks already measured fails here, by name.

Alongside the harness: the named table1 mis-selection regressions (auto
chose ``frontier`` where tiled measured ~3x faster — failing analytically,
fixed by calibration), Hypothesis properties of the profile interpolation
and the degenerate analytic-agreement construction, the autotune-disk
robustness contract (corrupt cache, schema mismatch, concurrent writers),
and the ``SolveStats.cost_model`` truthfulness + never-calibrate-inside-
``solve()`` guard.
"""

from __future__ import annotations

import json
import threading
import warnings
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np
import pytest

import repro.solve as S
from repro.core import autotune_disk, calibrate
from repro.core.calibrate import CalibrationProfile, Profile
from repro.ops.workloads import (edt_state, edt_state3d, fill_state,
                                 label_state, morph_state, morph_state3d)

REPO = Path(__file__).resolve().parent.parent

# A selection is "honest" when the config the model picks measures within
# this factor of the group's fastest committed config: selection only has
# to avoid the multi-x mistakes the analytic model made (frontier at 3-5x),
# not resolve photo-finishes between near-equal engines.
SELECTION_TOL = 1.5


def _load_bench(name):
    return json.loads((REPO / name).read_text())


@pytest.fixture(scope="module")
def profile():
    prof = calibrate.load_profile_json(str(REPO / "benchmarks"
                                       / "CALIBRATION.json"))
    assert prof is not None, \
        "committed CALIBRATION.json failed to decode (profile_version drift?)"
    return prof


@pytest.fixture(scope="module")
def measured_model(profile):
    return S.MeasuredCostModel(profile, interpret=True)


_STATS_CACHE = {}


def _stats_for(key, builder, tiles):
    """collect_input_stats is an O(N) probe over up-to-1024² grids — cache
    per workload across the parametrized harness cases."""
    if key not in _STATS_CACHE:
        op, state = builder()
        _STATS_CACHE[key] = S.collect_input_stats(op, state, tiles=tiles)
    return _STATS_CACHE[key]


# ---------------------------------------------------------------------------
# The selection-regression harness: replay every committed bench group.
# ---------------------------------------------------------------------------

# group name prefix -> (workload builder, candidate tiles probed)
_OPS2D = {
    "morph": (lambda: morph_state(1024, coverage=1.0, seed=0,
                                  marker_kind="seeded"), (32, 128)),
    "edt": (lambda: edt_state(1024, coverage=0.9, seed=0), (32, 128)),
    "fill_holes": (lambda: fill_state(1024, 0.5, 0), (32, 128)),
    "label": (lambda: label_state(1024, 0.55, 0), (32, 128)),
}
_OPS3D = {
    "morph": (lambda: morph_state3d(128, 0), (32,)),
    "edt": (lambda: edt_state3d(128, 0), (32,)),
}


def _ops_group(records, prefix):
    """(EngineConfig, measured seconds) per engine row of one bench group."""
    out = []
    for r in records:
        if not r["name"].startswith(prefix):
            continue
        eng = r["engine"]
        cfg = S.EngineConfig(eng, r.get("tile"),
                             64 if r.get("tile") else None,
                             r.get("drain_batch"))
        out.append((cfg, r["seconds"]))
    return out


def _assert_honest(model, stats, group, label):
    cands = [cfg for cfg, _ in group]
    secs = {cfg: s for cfg, s in group}
    pick = model.rank(stats, cands)[0][1]
    best = min(secs.values())
    got = secs[pick]
    assert got <= SELECTION_TOL * best, (
        f"{label}: model picked {pick.engine} (tile={pick.tile}, "
        f"db={pick.drain_batch}) measuring {got:.3f}s, but the group's "
        f"fastest committed config measured {best:.3f}s "
        f"(ratio {got / best:.2f} > tol {SELECTION_TOL})")


@pytest.mark.parametrize("op_name", sorted(_OPS2D))
def test_selection_regression_ops2d(measured_model, op_name):
    """BENCH_ops.json 2-D groups: the calibrated model must land within
    tolerance of the measured-fastest of {frontier, tiled, scheduler,
    hybrid} at 1024² — including the groups where the analytic model's
    pick measured 2-4x off (scheduler won every 2-D op)."""
    builder, tiles = _OPS2D[op_name]
    group = _ops_group(_load_bench("BENCH_ops.json"),
                       f"ops/{op_name}/size=1024/")
    assert len(group) == 4, f"expected 4 engine rows, got {group}"
    stats = _stats_for(("ops2d", op_name), builder, tiles)
    _assert_honest(measured_model, stats, group, f"ops/{op_name}")


@pytest.mark.parametrize("op_name", sorted(_OPS3D))
def test_selection_regression_ops3d(measured_model, op_name):
    """BENCH_ops.json 3-D groups (128³, conn26): the 2-D-measured profile
    must extrapolate well enough (linear-in-work rates + neighborhood-size
    ratio) to stay honest on volumetric inputs it never measured."""
    builder, tiles = _OPS3D[op_name]
    group = _ops_group(_load_bench("BENCH_ops.json"),
                       f"ops3d/{op_name}/size=128/")
    assert len(group) == 2, f"expected 2 engine rows, got {group}"
    stats = _stats_for(("ops3d", op_name), builder, tiles)
    _assert_honest(measured_model, stats, group, f"ops3d/{op_name}")


def test_selection_regression_drain_batch(measured_model):
    """BENCH_tiled.json drain_comparison: across drain_batch 1/4/8/16 at
    tile=32 the committed measurements span 5.4x; the measured batch-factor
    curve must keep the pick off the sequential cliff."""
    group = []
    for r in _load_bench("BENCH_tiled.json"):
        if r["name"].startswith("drain/size=1024/tile=32/"):
            group.append((S.EngineConfig("tiled", 32, 64, r["drain_batch"]),
                          r["seconds"]))
    assert len(group) == 4, f"expected 4 drain_batch rows, got {group}"
    stats = _stats_for(
        ("drain", "morph"),
        lambda: morph_state(1024, coverage=1.0, seed=0,
                            marker_kind="seeded"), (32,))
    _assert_honest(measured_model, stats, group, "drain_comparison")


# ---------------------------------------------------------------------------
# Satellite 1 — the named table1 mis-selections, pinned.
# ---------------------------------------------------------------------------

def _table1_case(n_sweeps):
    """(stats, candidates, measured seconds) for one committed table1 row
    set (512², fh_init markers with ``n_sweeps`` raster sweeps)."""
    secs = {}
    for r in _load_bench("BENCH_tiled.json"):
        if r["name"] == f"table1/sweeps={n_sweeps}/E0_sweep":
            secs["sweep"] = r["seconds"]
        elif r["name"] == f"table1/sweeps={n_sweeps}/E1_frontier":
            secs["frontier"] = r["seconds"]
        elif r["name"] == f"table1/sweeps={n_sweeps}/E2_tiled":
            secs["tiled"] = r["seconds"]
    assert len(secs) == 3
    cands = [S.EngineConfig("sweep"), S.EngineConfig("frontier"),
             S.EngineConfig("tiled", 128, 64, 1)]
    stats = _stats_for(
        ("table1", n_sweeps),
        lambda: morph_state(512, coverage=1.0, seed=0, n_sweeps=n_sweeps),
        (32, 128))
    return stats, cands, secs


@pytest.mark.parametrize("n_sweeps", [1, 2, 3])
def test_table1_misselection_fixed_by_calibration(measured_model, n_sweeps):
    """The pinned ISSUE-9 mis-selections: at sweeps=1..3 the committed
    ``auto`` rows picked ``frontier`` while the tiled row measured
    2.5-2.9x faster.  The analytic model must still reproduce the mistake
    (that's what makes this a *regression* pin, not a tautology) and the
    calibrated model must pick the tiled config."""
    stats, cands, secs = _table1_case(n_sweeps)
    analytic_pick = S.CostModel(interpret=True).rank(stats, cands)[0][1]
    assert analytic_pick.engine in ("frontier", "sweep"), (
        "the analytic model no longer mis-selects on table1/sweeps="
        f"{n_sweeps} — retire this pin and record the new behavior")
    measured_pick = measured_model.rank(stats, cands)[0][1]
    assert measured_pick.engine == "tiled", (
        f"calibrated model picked {measured_pick.engine} on "
        f"table1/sweeps={n_sweeps}; committed seconds: {secs}")
    assert secs["tiled"] < secs[analytic_pick.engine], \
        "committed record no longer shows the mis-selection cost"


def test_table1_sweeps4_stays_correct(measured_model):
    """sweeps=4 is the row the analytic model got *right* (it picked a
    tiled config): calibration must not regress it to a dense engine."""
    stats, cands, secs = _table1_case(4)
    pick = measured_model.rank(stats, cands)[0][1]
    assert secs[pick.engine] <= SELECTION_TOL * min(secs.values())


# ---------------------------------------------------------------------------
# Satellite 2 — properties of profiles and the cost model.  Each property
# has a deterministic spot-check that always runs, and a Hypothesis
# generalization that runs where hypothesis is installed (CI dev deps).
# ---------------------------------------------------------------------------

def _synth_stats(n, density, ndim=2):
    area = n ** ndim
    n_sources = max(1, int(density * area))
    shape = (n,) * ndim
    return S.InputStats(
        n, n, n_sources,
        active_tiles={t: max(1, (-(-n // t)) ** ndim) for t in (32, 128)},
        n_devices=1, shape=shape, op_name="morph")


def _check_interp_bounded(points, x):
    p = Profile.from_points(points)
    lo, hi = min(p.ys), max(p.ys)
    assert lo - 1e-12 <= p.interp(x) <= hi + 1e-12
    # and it reproduces every measured point exactly
    for xi, yi in zip(p.xs, p.ys):
        assert p.interp(xi) == pytest.approx(yi)


def _check_scaled_rate_bounded(points, x):
    """scaled() clamps the *rate* y/x, not y: outside the measured range
    the cost stays linear in the work instead of freezing — so the
    per-unit rate is always within the measured rate envelope."""
    p = Profile.from_points(points)
    rates = [y / xi for xi, y in zip(p.xs, p.ys)]
    got = p.scaled(x) / x
    assert min(rates) - 1e-12 <= got <= max(rates) + 1e-12
    for xi, yi in zip(p.xs, p.ys):
        assert p.scaled(xi) == pytest.approx(yi)


def _check_cost_monotone_in_pixels(n, density, scale):
    """At fixed wavefront density, every engine's cost is non-decreasing
    in the pixel count — for the analytic model and for the measured model
    over its degenerate analytic profile alike."""
    small = _synth_stats(n, density)
    big = _synth_stats(n * scale, density)
    analytic = S.CostModel(interpret=True)
    prof = CalibrationProfile.from_analytic(analytic, small, tiles=(32, 128))
    measured = S.MeasuredCostModel(prof, interpret=True)
    for cfg in (S.EngineConfig("frontier"), S.EngineConfig("sweep"),
                S.EngineConfig("tiled", 32, 64, 1),
                S.EngineConfig("scheduler", 128, 64)):
        for model in (analytic, measured):
            assert model.cost(big, cfg) >= model.cost(small, cfg) * (1 - 1e-9)


def _check_cost_monotone_in_rounds(n, d1, d2):
    """Sparser seeds mean deeper propagation (more rounds): at fixed area,
    dense-engine cost is non-increasing in seed density."""
    lo, hi = min(d1, d2), max(d1, d2)
    sparse, dense = _synth_stats(n, lo), _synth_stats(n, hi)
    analytic = S.CostModel(interpret=True)
    prof = CalibrationProfile.from_analytic(analytic, sparse, tiles=(32,))
    measured = S.MeasuredCostModel(prof, interpret=True)
    for cfg in (S.EngineConfig("frontier"), S.EngineConfig("sweep")):
        for model in (analytic, measured):
            assert model.cost(dense, cfg) <= model.cost(sparse, cfg) * (1 + 1e-9)


def _check_degenerate_agreement(n, density, unit):
    """The one-point profile sampled from the analytic model's own
    formulas makes MeasuredCostModel reproduce ``unit x analytic cost``
    exactly at the sampled configs — pinning the measured model's plumbing
    (no double-applied hint scaling, no lost cost terms)."""
    stats = _synth_stats(n, density)
    analytic = S.CostModel(interpret=True)
    prof = CalibrationProfile.from_analytic(analytic, stats, tiles=(32, 128),
                                            unit=unit)
    measured = S.MeasuredCostModel(prof, interpret=True)
    for cfg in (S.EngineConfig("frontier"), S.EngineConfig("sweep"),
                S.EngineConfig("tiled", 32, 64, 1),
                S.EngineConfig("tiled", 128, 64, 1),
                S.EngineConfig("tiled-pallas", 32, 64, 1),
                S.EngineConfig("scheduler", 128, 64)):
        assert measured.cost(stats, cfg) == pytest.approx(
            unit * analytic.cost(stats, cfg), rel=1e-9)


@pytest.mark.parametrize("points,x", [
    ([(1.0, 2.0)], 50.0),
    ([(10.0, 1e-3), (1000.0, 5e-2), (1e6, 40.0)], 3.0),
    ([(10.0, 1e-3), (1000.0, 5e-2), (1e6, 40.0)], 1e9),
    ([(100.0, 7.0), (200.0, 3.0)], 150.0),
])
def test_profile_interp_and_scaled_bounded(points, x):
    _check_interp_bounded(points, x)
    _check_scaled_rate_bounded(points, x)


@pytest.mark.parametrize("n,density,scale", [
    (64, 0.3, 2), (128, 1e-3, 4), (200, 0.05, 3)])
def test_cost_monotone_in_pixels(n, density, scale):
    _check_cost_monotone_in_pixels(n, density, scale)


@pytest.mark.parametrize("n,d1,d2", [
    (64, 1e-4, 0.4), (128, 0.01, 0.3), (320, 0.2, 0.2)])
def test_cost_monotone_in_rounds(n, d1, d2):
    _check_cost_monotone_in_rounds(n, d1, d2)


@pytest.mark.parametrize("n,density,unit", [
    (48, 0.5, 1e-6), (192, 1e-3, 1e-9), (400, 0.9, 1e-3)])
def test_degenerate_profile_agrees_with_analytic(n, density, unit):
    _check_degenerate_agreement(n, density, unit)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # local runs: hypothesis is a CI-only dev dependency
    pass
else:
    _points = st.lists(
        st.tuples(st.floats(1.0, 1e8), st.floats(1e-9, 1e3)),
        min_size=1, max_size=8,
    ).filter(lambda ps: len({round(x, 6) for x, _ in ps}) == len(ps))

    @given(points=_points, x=st.floats(0.1, 1e9))
    @settings(max_examples=50, deadline=None)
    def test_hyp_profile_interp_and_scaled_bounded(points, x):
        _check_interp_bounded(points, x)
        _check_scaled_rate_bounded(points, x)

    @given(n=st.integers(64, 512), density=st.floats(1e-4, 0.5),
           scale=st.integers(2, 4))
    @settings(max_examples=30, deadline=None)
    def test_hyp_cost_monotone_in_pixels(n, density, scale):
        _check_cost_monotone_in_pixels(n, density, scale)

    @given(n=st.integers(64, 512), d1=st.floats(1e-4, 0.5),
           d2=st.floats(1e-4, 0.5))
    @settings(max_examples=30, deadline=None)
    def test_hyp_cost_monotone_in_rounds(n, d1, d2):
        _check_cost_monotone_in_rounds(n, d1, d2)

    @given(n=st.integers(48, 400), density=st.floats(1e-4, 0.9),
           unit=st.floats(1e-9, 1e-3))
    @settings(max_examples=40, deadline=None)
    def test_hyp_degenerate_profile_agrees_with_analytic(n, density, unit):
        _check_degenerate_agreement(n, density, unit)


def test_profile_json_roundtrip(profile):
    """The committed profile survives a to_dict/from_dict cycle intact."""
    again = CalibrationProfile.from_dict(profile.to_dict())
    assert again is not None
    assert again.to_dict() == profile.to_dict()


# ---------------------------------------------------------------------------
# Satellite 3 — autotune_disk robustness.
# ---------------------------------------------------------------------------

def _mk_cfg(engine="tiled", tile=32):
    return S.EngineConfig(engine, tile, 64, 1)


def test_corrupt_cache_warns_and_degrades_to_empty():
    path = Path(autotune_disk.cache_path())
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text('{"schema": 2, "entries": {truncated')
    with pytest.warns(RuntimeWarning, match="corrupt autotune cache"):
        assert autotune_disk.load("morph", ("sig",), S.EngineConfig) is None
    # and the cache is usable again: a store round-trips
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        autotune_disk.store("morph", ("sig",), _mk_cfg(), 0.5)
    got = autotune_disk.load("morph", ("sig",), S.EngineConfig)
    assert got is not None and got[1] == 0.5


def test_schema_mismatch_invalidates_silently():
    path = Path(autotune_disk.cache_path())
    path.parent.mkdir(parents=True, exist_ok=True)
    stale = {"schema": 1,
             "entries": {autotune_disk.entry_key("morph", ("sig",)): {
                 "op": "morph", "config": {"engine": "tiled"},
                 "seconds": 1.0}},
             "profiles": {autotune_disk.profile_key(): {"stale": True}}}
    path.write_text(json.dumps(stale))
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # silent: any warning fails here
        assert autotune_disk.load("morph", ("sig",), S.EngineConfig) is None
        assert autotune_disk.load_profile() is None


def test_concurrent_writers_lose_nothing():
    """N threads storing disjoint entries (plus a profile writer) through
    the locked read-modify-write: every entry must survive — the failure
    mode being pinned is last-writer-wins dropping other writers' keys."""
    sigs = [("sig", i) for i in range(24)]

    def write(i):
        autotune_disk.store("morph", sigs[i], _mk_cfg(tile=32 + i), float(i))

    with ThreadPoolExecutor(max_workers=8) as ex:
        futs = [ex.submit(write, i) for i in range(len(sigs))]
        futs.append(ex.submit(autotune_disk.store_profile,
                              {"profile_version": 0, "marker": True}))
        for f in futs:
            f.result()
    for i in range(len(sigs)):
        got = autotune_disk.load("morph", sigs[i], S.EngineConfig)
        assert got is not None and got[1] == float(i), f"entry {i} lost"
    assert autotune_disk.load_profile() == {"profile_version": 0,
                                            "marker": True}


def test_profile_store_load_roundtrip(profile):
    autotune_disk.store_profile(profile.to_dict())
    assert autotune_disk.load_profile() == profile.to_dict()
    # and the lazy in-process cache picks it up after a reset
    calibrate.reset_profile_cache()
    got = calibrate.current_profile()
    assert got is not None and got.to_dict() == profile.to_dict()


# ---------------------------------------------------------------------------
# Satellite 4 — SolveStats.cost_model truthfulness + the solve() guard.
# ---------------------------------------------------------------------------

def _tiny_morph():
    return morph_state(48, coverage=1.0, seed=0, marker_kind="seeded")


def test_stats_report_analytic_on_cold_start():
    op, state = _tiny_morph()
    _, stc = S.solve(op, state, engine="auto")
    assert stc.cost_model == "analytic"
    _, ste = S.solve(op, state, engine="frontier")
    assert ste.cost_model is None       # nothing decided anything


def test_installing_profile_flips_deciding_model(profile):
    op, state = _tiny_morph()
    _, before = S.solve(op, state, engine="auto")
    assert before.cost_model == "analytic"
    calibrate.install_profile(profile)
    try:
        _, after = S.solve(op, state, engine="auto")
        assert after.cost_model == "measured"
    finally:
        calibrate.install_profile(None)
    _, reverted = S.solve(op, state, engine="auto")
    assert reverted.cost_model == "analytic"


def test_solve_runs_inside_guard_and_calibration_refuses():
    """solve() wraps its engines in the calibration guard, and
    run_calibration refuses to start inside it — the cold-start contract
    (calibration is explicit, never a lazy side effect of a solve)."""
    op, state = _tiny_morph()
    seen = {}

    class SpyModel(S.CostModel):
        def rank(self, stats, candidates=None):
            seen["in_solve"] = calibrate.in_solve()
            try:
                calibrate.run_calibration(ops=["morph"], smoke=True,
                                          save=False)
                seen["raised"] = None
            except RuntimeError as e:
                seen["raised"] = str(e)
            return super().rank(stats, candidates)

    assert not calibrate.in_solve()
    S.solve(op, state, engine="auto", cost_model=SpyModel(interpret=True))
    assert seen["in_solve"] is True
    assert seen["raised"] is not None and "solve()" in seen["raised"]
    assert not calibrate.in_solve()     # guard unwound cleanly


def test_run_calibration_smoke_persists_and_reloads():
    """End-to-end: a (tiny) real calibration run measures every section,
    persists through autotune_disk, and a fresh lazy load hands the
    profile to default_cost_model."""
    prof = calibrate.run_calibration(ops=["morph"], smoke=True, save=True,
                                     cal_size=48, dense_sizes=())
    assert "tiled" in prof.drain["morph"]
    assert "frontier" in prof.dense_round["morph"]
    assert prof.rounds_per_extent["morph"].xs
    assert prof.batch_factor and prof.drain_grid   # per-block-size curves
    assert prof.round_overhead_s > 0
    # simulate a fresh process: drop the memo, reload from disk
    calibrate.reset_profile_cache()
    model = S.default_cost_model(interpret=True)
    assert isinstance(model, S.MeasuredCostModel)
    assert model.kind == "measured"
    op, state = _tiny_morph()
    _, stc = S.solve(op, state, engine="auto")
    assert stc.cost_model == "measured"


def test_chunk_policy_seed_kind_records_deciding_model(profile):
    from repro.core.scheduler import ChunkPolicy
    assert ChunkPolicy(4.0).seed_kind == "analytic"
    mm = S.MeasuredCostModel(profile, interpret=True)
    pol = ChunkPolicy(mm.hybrid_rel_speed(32, 4), seed_kind=mm.kind)
    assert pol.seed_kind == "measured"
    if profile.hybrid_rel_speed:
        assert pol.seed_rel_speed == pytest.approx(
            max(1.0, profile.hybrid_rel_speed))
