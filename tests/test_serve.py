"""Serving-layer suite (DESIGN.md §2.9, docs/SERVING.md): the coalescing
front door must be *invisible* in results and *visible* in metrics.

  (a) batch = solo, bit for bit — ``solve_batch`` over every registered op
      (2-D and 3-D where supported) reproduces per-state solo solves
      exactly, including the round/source counters (the vmapped
      ``lax.while_loop`` freezes converged elements, so extra rounds past
      an element's fixed point are no-ops);
  (b) the service round-trips ``submit()`` futures to the same finalized
      arrays ``run_op`` returns, through pad-to-bucket coalescing;
  (c) result cache: repeat submits return equal arrays without a second
      solve, in-flight duplicates single-flight onto one future;
  (d) admission control rejects at the queue/tenant bounds with a
      ``retry_after_s`` hint and never wedges the queue;
  (e) failure isolation: an exploding batch rejects exactly its own
      futures while later batches keep draining.
"""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ops import get_op, list_ops, run_op
from repro.serve import (Coalescer, IwppService, LatencyReservoir,
                         MetricsRecorder, Rejected, ServeStats,
                         content_fingerprint, request_key, shape_bucket)
from repro.solve import BATCHABLE_ENGINES, solve, solve_batch

SHAPES = {2: (24, 28), 3: (8, 10, 12)}


def _raw_inputs(name, rng, shape):
    """The op's natural ``submit()`` payload (None = op unknown here)."""
    if name == "morph":
        mask = rng.integers(0, 200, shape).astype(np.int32)
        marker = np.where(rng.random(shape) < 0.05, mask, 0).astype(np.int32)
        return (marker, mask)
    if name == "edt":
        return (np.asarray(rng.random(shape) < 0.85),)
    if name == "fill_holes":
        return (np.asarray(rng.random(shape) < 0.45),)
    if name == "label":
        return (np.asarray(rng.random(shape) < 0.55),)
    return None


# ---------------------------------------------------------------------------
# (a) solve_batch == solo, every op, every supported rank
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nd", sorted(SHAPES), ids=lambda nd: f"{nd}d")
@pytest.mark.parametrize("name", list_ops())
def test_solve_batch_bit_identical_to_solo(name, nd):
    spec = get_op(name)
    if nd not in spec.supported_ndims:
        pytest.skip(f"{name} does not support {nd}-D")
    cases = [spec.example_state(np.random.default_rng(200 + i), SHAPES[nd])
             for i in range(3)]
    op = cases[0][0]
    states = [st for _, st in cases]
    batched = solve_batch(op, states, engine="frontier")
    for i, st_in in enumerate(states):
        out_b, stats_b = batched[i]
        out_s, stats_s = solve(op, st_in, engine="frontier")
        assert sorted(out_b) == sorted(out_s)
        for k in out_s:
            np.testing.assert_array_equal(np.asarray(out_b[k]),
                                          np.asarray(out_s[k]))
        assert stats_b.rounds == stats_s.rounds
        assert stats_b.sources_processed == stats_s.sources_processed
        assert stats_b.batch_size == len(states)
        assert stats_b.wall_time_s > 0.0


def test_solve_batch_mixed_signature_raises():
    spec = get_op("morph")
    op, s1 = spec.example_state(np.random.default_rng(0), (24, 28))
    _, s2 = spec.example_state(np.random.default_rng(1), (32, 32))
    with pytest.raises(ValueError, match="tree signature"):
        solve_batch(op, [s1, s2])


def test_solve_batch_by_name_auto_and_sequential():
    rng = np.random.default_rng(3)
    inputs = [_raw_inputs("edt", np.random.default_rng(3 + i), (24, 28))
              for i in range(2)]
    res = solve_batch("edt", inputs, engine="auto")
    assert len(res) == 2 and res[0][1].cost_model is not None
    # host-loop engines take the sequential path but still return
    # per-element stats under the one chosen config
    spec = get_op("edt")
    op = spec.make_op(None)
    states = [spec.build_state(op, jnp.asarray(x[0])) for x in inputs]
    seq = solve_batch(op, states, engine="tiled", tile=32)
    assert seq[0][1].engine == "tiled"
    d_auto = spec.extract(op, res[0][0])
    d_seq = spec.extract(op, seq[0][0])
    np.testing.assert_array_equal(np.asarray(d_auto), np.asarray(d_seq))


def test_wall_time_populated_by_every_solve():
    spec = get_op("morph")
    op, state = spec.example_state(np.random.default_rng(7), (24, 28))
    for engine in ("frontier", "sweep", "tiled"):
        _, st = solve(op, state, engine=engine)
        assert st.wall_time_s > 0.0, f"{engine} left wall_time_s unset"
        assert st.batch_size is None


# ---------------------------------------------------------------------------
# (b) service round trip: submit() == run_op(), coalesced
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list_ops())
def test_service_matches_run_op(name):
    shape = SHAPES[2]
    payloads = [_raw_inputs(name, np.random.default_rng(300 + i), shape)
                for i in range(3)]
    if payloads[0] is None:
        pytest.skip(f"no raw-input builder for op {name!r}")
    want = [np.asarray(run_op(name, *p, engine="frontier")[0])
            for p in payloads]
    svc = IwppService(engine="frontier", max_batch=8, start=False)
    futs = [svc.submit(name, p, tenant=f"t{i}")
            for i, p in enumerate(payloads)]
    svc.start()
    try:
        got = [np.asarray(f.result(timeout=300)) for f in futs]
    finally:
        svc.close()
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    stats = svc.stats()
    assert stats.completed == 3 and stats.failed == 0
    assert stats.batches == 1 and stats.batch_size_hist == {3: 1}
    assert stats.queue_depth == 0 and stats.inflight == 0
    assert stats.latency_p99_s >= stats.latency_p50_s > 0.0


def test_service_pad_to_bucket_coalesces_near_miss_shapes():
    rng = np.random.default_rng(11)
    small = _raw_inputs("edt", rng, (40, 52))
    exact = _raw_inputs("edt", rng, (64, 64))
    want_small = np.asarray(run_op("edt", *small, engine="frontier")[0])
    want_exact = np.asarray(run_op("edt", *exact, engine="frontier")[0])
    svc = IwppService(engine="frontier", bucket_multiple=64, start=False)
    f1 = svc.submit("edt", small)
    f2 = svc.submit("edt", exact)
    svc.start()
    try:
        got_small = np.asarray(f1.result(timeout=300))
        got_exact = np.asarray(f2.result(timeout=300))
    finally:
        svc.close()
    np.testing.assert_array_equal(got_small, want_small)
    np.testing.assert_array_equal(got_exact, want_exact)
    assert got_small.shape == (40, 52), "padding leaked into the result"
    assert svc.stats().batch_size_hist == {2: 1}, \
        "near-miss shapes did not share one batch"


# ---------------------------------------------------------------------------
# (c) result cache + single-flight
# ---------------------------------------------------------------------------

def test_service_cache_hits_and_single_flight():
    payload = _raw_inputs("morph", np.random.default_rng(21), SHAPES[2])
    other = _raw_inputs("morph", np.random.default_rng(22), SHAPES[2])
    svc = IwppService(engine="frontier", start=False)
    f1 = svc.submit("morph", payload, tenant="a")
    f2 = svc.submit("morph", payload, tenant="b")    # in-flight duplicate
    f3 = svc.submit("morph", other, tenant="c")
    assert f2 is f1, "identical in-flight request did not single-flight"
    svc.start()
    base = np.asarray(f1.result(timeout=300))
    batches_before = svc.stats().batches
    f4 = svc.submit("morph", payload)                # post-completion repeat
    got = np.asarray(f4.result(timeout=5))
    np.testing.assert_array_equal(got, base)
    svc.close()
    stats = svc.stats()
    assert stats.batches == batches_before, "cache hit triggered a solve"
    assert stats.cache_hits == 2          # one join + one post-completion hit
    assert stats.cache_misses == 2        # the two distinct payloads
    assert stats.cache_hit_rate == pytest.approx(0.5)
    assert stats.completed == 4


def test_service_cache_lru_eviction():
    svc = IwppService(engine="frontier", cache_capacity=1, start=False)
    a = _raw_inputs("label", np.random.default_rng(31), SHAPES[2])
    b = _raw_inputs("label", np.random.default_rng(32), SHAPES[2])
    fa = svc.submit("label", a)
    fb = svc.submit("label", b)
    svc.start()
    ra, rb = fa.result(300), fb.result(300)
    # capacity 1: `a` was evicted when `b` completed -> resubmitting `a`
    # is a miss, resubmitting `b` is a hit
    misses_before = svc.stats().cache_misses
    np.testing.assert_array_equal(np.asarray(svc.submit("label", b)
                                             .result(300)), np.asarray(rb))
    assert svc.stats().cache_misses == misses_before
    np.testing.assert_array_equal(np.asarray(svc.submit("label", a)
                                             .result(300)), np.asarray(ra))
    assert svc.stats().cache_misses == misses_before + 1
    svc.close()


# ---------------------------------------------------------------------------
# (d) admission control
# ---------------------------------------------------------------------------

def test_service_rejects_past_queue_depth():
    svc = IwppService(engine="frontier", max_queue_depth=2, start=False)
    for i in range(2):
        svc.submit("edt", _raw_inputs("edt", np.random.default_rng(40 + i),
                                      SHAPES[2]))
    with pytest.raises(Rejected) as exc:
        svc.submit("edt", _raw_inputs("edt", np.random.default_rng(49),
                                      SHAPES[2]))
    assert exc.value.retry_after_s > 0.0
    assert svc.stats().rejected == 1
    # the refusal must not wedge the queue: start and drain normally
    svc.start()
    svc.close()
    assert svc.stats().completed == 2


def test_service_per_tenant_inflight_cap():
    svc = IwppService(engine="frontier", max_inflight_per_tenant=1,
                      start=False)
    svc.submit("edt", _raw_inputs("edt", np.random.default_rng(50),
                                  SHAPES[2]), tenant="greedy")
    with pytest.raises(Rejected, match="greedy"):
        svc.submit("edt", _raw_inputs("edt", np.random.default_rng(51),
                                      SHAPES[2]), tenant="greedy")
    # other tenants are unaffected, and duplicates/cache hits stay free
    svc.submit("edt", _raw_inputs("edt", np.random.default_rng(51),
                                  SHAPES[2]), tenant="modest")
    svc.start()
    svc.close()
    assert svc.stats().completed == 2 and svc.stats().rejected == 1


def test_service_unknown_op_raises_before_queueing():
    svc = IwppService(start=False)
    with pytest.raises(ValueError, match="unknown op"):
        svc.submit("not_an_op", np.zeros((4, 4)))
    assert len(svc._coalescer) == 0
    svc.close()


# ---------------------------------------------------------------------------
# (e) failure isolation
# ---------------------------------------------------------------------------

def test_service_failure_injection_rejects_only_affected_batch():
    rng = np.random.default_rng(61)
    svc = IwppService(engine="frontier", start=False)
    svc.fail_injector = lambda batch: batch[0].op_name == "morph"
    doomed = [svc.submit("morph", _raw_inputs("morph",
                                              np.random.default_rng(61 + i),
                                              SHAPES[2]))
              for i in range(2)]
    survivor = svc.submit("edt", _raw_inputs("edt", rng, SHAPES[2]))
    svc.start()
    try:
        for f in doomed:
            with pytest.raises(RuntimeError, match="injected"):
                f.result(timeout=300)
        assert survivor.result(timeout=300) is not None
    finally:
        svc.close()
    stats = svc.stats()
    assert stats.failed == 2 and stats.completed == 1
    assert stats.queue_depth == 0 and stats.inflight == 0, \
        "failed batch left accounting behind"


# ---------------------------------------------------------------------------
# metrics / batching units
# ---------------------------------------------------------------------------

def test_latency_reservoir_percentiles_nearest_rank():
    r = LatencyReservoir(capacity=100)
    for v in range(1, 101):                      # 0.01 .. 1.00
        r.record(v / 100)
    assert r.percentile(50) == pytest.approx(0.50)
    assert r.percentile(95) == pytest.approx(0.95)
    assert r.percentile(99) == pytest.approx(0.99)
    assert r.percentile(100) == pytest.approx(1.00)
    r2 = LatencyReservoir(capacity=4)            # newest-wins bound
    for v in (1.0, 2.0, 3.0, 4.0, 5.0, 6.0):
        r2.record(v)
    assert len(r2) == 4 and r2.percentile(100) == 6.0
    assert LatencyReservoir().percentile(99) == 0.0


def test_serve_stats_derived_properties():
    s = ServeStats(cache_hits=3, cache_misses=1,
                   batch_size_hist={1: 2, 4: 1})
    assert s.cache_hit_rate == pytest.approx(0.75)
    assert s.mean_batch_size == pytest.approx(2.0)
    assert ServeStats().cache_hit_rate == 0.0
    assert ServeStats().mean_batch_size == 0.0


def test_metrics_recorder_thread_safety_smoke():
    m = MetricsRecorder()
    def worker():
        for _ in range(200):
            m.count("submitted")
            m.record_latency(0.01)
    threads = [threading.Thread(target=worker) for _ in range(4)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    assert m.snapshot().submitted == 800


def test_request_key_and_bucket_rules():
    sig = ("auto", True, False, ())
    k1 = request_key("morph", (40, 52), ("int32", "int32"), None, sig, 64)
    k2 = request_key("morph", (64, 64), ("int32", "int32"), None, sig, 64)
    k3 = request_key("morph", (65, 64), ("int32", "int32"), None, sig, 64)
    assert k1 == k2, "near-miss shapes must bucket together"
    assert k2 != k3, "shapes past the bucket boundary must not"
    assert shape_bucket((1, 64, 65), 64) == (64, 64, 128)
    # connectivity aliases canonicalize: 8 and "conn8" are one group
    assert request_key("morph", (64, 64), ("int32",), 8, sig, 64) \
        == request_key("morph", (64, 64), ("int32",), "conn8", sig, 64)
    # distinct content, same key -> coalescible but separate fingerprints
    a = np.zeros((4, 4), np.int32)
    b = np.ones((4, 4), np.int32)
    assert content_fingerprint("morph", (a, a)) \
        != content_fingerprint("morph", (a, b))
    assert content_fingerprint("morph", (a, b)) \
        == content_fingerprint("morph", (a.copy(), b.copy()))


def test_coalescer_fifo_and_key_grouping():
    c = Coalescer()
    def req(rid, key):
        return type("R", (), {"rid": rid, "key": key})()
    for rid, key in [(1, "A"), (2, "B"), (3, "A"), (4, "A"), (5, "B")]:
        c.push(req(rid, key))
    assert len(c) == 5 and c.compatible_pending("A") == 3
    batch = c.take_batch(2)
    assert [r.rid for r in batch] == [1, 3], \
        "batch must lead with the oldest request and keep arrival order"
    assert [r.rid for r in c.take_batch(8)] == [2, 5]
    assert [r.rid for r in c.take_batch(8)] == [4]
    assert c.take_batch(8) == []
