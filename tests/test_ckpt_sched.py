"""Checkpointing (sync/async, retention, restart determinism) and the
demand-driven host tile scheduler (FCFS balance + fault injection)."""

import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (AsyncCheckpointer, latest_step, restore,
                                   retain_last_k, save)
from repro.core.scheduler import TileScheduler
from repro.core.tiles import initial_active_tiles
from repro.data.images import tissue_image
from repro.data.pipeline import DataConfig, batch_for_step
from repro.configs.base import ShapeSpec
from repro.configs.registry import smoke_config
from repro.kernels.ops import morph_tile_pallas
from repro.morph.ops import MorphReconstructOp
from repro.morph.ref import reconstruct_fh


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32), "d": jnp.float32(3.5)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 3, t, extra={"note": "x"})
    step, out, extra = restore(str(tmp_path), like=t)
    assert step == 3 and extra == {"note": "x"}
    chk = jax.tree_util.tree_map(
        lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)), t, out)
    assert all(jax.tree_util.tree_leaves(chk))


def test_latest_and_retention(tmp_path):
    t = _tree()
    for s in (1, 5, 9, 12):
        save(str(tmp_path), s, t)
    assert latest_step(str(tmp_path)) == 12
    retain_last_k(str(tmp_path), 2)
    assert latest_step(str(tmp_path)) == 12
    assert sorted(os.listdir(tmp_path)) == ["step_00000009", "step_00000012"]


def test_restore_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((3, 3)), "b": {"c": jnp.ones((4,), jnp.int32),
                                         "d": jnp.float32(0)}}
    with pytest.raises(ValueError):
        restore(str(tmp_path), like=bad)


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep_last=2)
    for s in range(1, 5):
        ck.save(s, _tree())
    ck.wait()
    assert latest_step(str(tmp_path)) == 4
    assert len(os.listdir(tmp_path)) == 2


def test_data_pipeline_determinism():
    cfg = smoke_config("gemma2-27b")
    sh = ShapeSpec("t", 32, 4, "train")
    a = batch_for_step(cfg, sh, 7)
    b = batch_for_step(cfg, sh, 7)
    c = batch_for_step(cfg, sh, 8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shards draw disjoint slices
    s0 = batch_for_step(cfg, sh, 7, DataConfig(), shard=0, n_shards=2)
    s1 = batch_for_step(cfg, sh, 7, DataConfig(), shard=1, n_shards=2)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


# ---------------------------------------------------------------------------
# host tile scheduler (paper Fig. 8 runtime)
# ---------------------------------------------------------------------------

def _sched_case(n_workers, fail_worker=None):
    marker, mask = tissue_image(96, 96, 0.7, seed=11)
    ref = reconstruct_fh(marker, mask, 8)
    op = MorphReconstructOp(connectivity=8)
    state = {"J": np.minimum(marker, mask).astype(np.int32),
             "I": mask.astype(np.int32),
             "valid": np.ones(mask.shape, bool)}
    T = 32
    active = np.asarray(initial_active_tiles(
        op, {k: jnp.asarray(v) for k, v in state.items()}, T))

    def tile_fn(block):
        out, iters = morph_tile_pallas(
            jnp.asarray(block["J"]), jnp.asarray(block["I"]),
            jnp.asarray(block["valid"]), connectivity=8, interpret=True)
        nb = dict(block)
        nb["J"] = np.asarray(out)
        return nb, None

    sched = TileScheduler(state, T, tile_fn, active, n_workers=n_workers,
                          mutable=("J",), fail_worker=fail_worker)
    stats = sched.run()
    return state["J"], ref.astype(np.int32), stats


def test_scheduler_matches_ref():
    J, ref, stats = _sched_case(n_workers=4)
    np.testing.assert_array_equal(J, ref)
    assert stats.tiles_processed >= 9
    # demand-driven FCFS: every worker took some tiles (prob. 1 for 9+ tiles)
    assert len(stats.per_worker) >= 2


def test_scheduler_fault_injection():
    """A worker dies mid-run; its tile is re-queued and survivors finish —
    the paper's §5.2.4 idempotence argument as a fault-tolerance mechanism."""
    J, ref, stats = _sched_case(n_workers=3, fail_worker=1)
    np.testing.assert_array_equal(J, ref)
    assert stats.requeues_from_failures >= 1


def test_scheduler_survivor_waves_rechecked():
    """Regression: run() used to launch exactly ONE survivor pass after the
    initial workers joined — if the survivors also died (each failure kills
    its worker), run() returned with the queue non-empty and the state not
    at its fixed point.  A tile_fn that fails its first 3 calls kills both
    initial workers and the single survivor; only the re-check loop
    finishes the job."""
    marker, mask = tissue_image(64, 64, 0.7, seed=12)
    ref = reconstruct_fh(marker, mask, 8)
    op = MorphReconstructOp(connectivity=8)
    state = {"J": np.minimum(marker, mask).astype(np.int32),
             "I": mask.astype(np.int32),
             "valid": np.ones(mask.shape, bool)}
    T = 32
    active = np.asarray(initial_active_tiles(
        op, {k: jnp.asarray(v) for k, v in state.items()}, T))
    fails = {"n": 3}
    lock = threading.Lock()

    def flaky_tile_fn(block):
        with lock:
            if fails["n"] > 0:
                fails["n"] -= 1
                raise RuntimeError("injected flaky failure")
        out, _ = morph_tile_pallas(
            jnp.asarray(block["J"]), jnp.asarray(block["I"]),
            jnp.asarray(block["valid"]), connectivity=8, interpret=True)
        nb = dict(block)
        nb["J"] = np.asarray(out)
        return nb, None

    sched = TileScheduler(state, T, flaky_tile_fn, active, n_workers=2,
                          mutable=("J",))
    stats = sched.run()
    assert sched._q.empty() and sched._inflight == 0
    assert stats.requeues_from_failures == 3
    assert not stats.incomplete
    np.testing.assert_array_equal(state["J"], ref.astype(np.int32))


def test_scheduler_deterministic_failure_is_not_silent():
    """A tile_fn that fails forever must never be reported as a fixed
    point: run() flags stats.incomplete and warns when it gives up."""
    state = {"J": np.zeros((32, 32), np.int32),
             "I": np.zeros((32, 32), np.int32),
             "valid": np.ones((32, 32), bool)}

    def always_fails(block):
        raise RuntimeError("deterministic failure")

    sched = TileScheduler(state, 32, always_fails, np.ones((1, 1), bool),
                          n_workers=1, mutable=("J",))
    sched.max_survivor_waves = 2
    with pytest.warns(RuntimeWarning, match="NOT at its fixed point"):
        stats = sched.run()
    assert stats.incomplete
    assert stats.tiles_processed == 0
