"""The solve() dispatch layer: every named engine must reach the reference
fixed point on shared fixtures, and the cost model must route sparse-seed
inputs to the tiled hierarchy and near-full frontiers to a dense engine."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

import repro.solve as solve_mod
from repro.data.images import bg_disks, seeded_marker, tissue_image
from repro.edt.ops import EdtOp, distance_map, edt
from repro.edt.ref import edt_wavefront
from repro.morph.ops import MorphReconstructOp, reconstruct
from repro.morph.ref import reconstruct_fh
from repro.solve import (CostModel, ENGINES, EngineConfig, SolveStats,
                         autotune_signature, clear_autotune_cache,
                         collect_input_stats, solve)

NAMED_ENGINES = [e for e in ENGINES if e != "auto"]
# Small tiles keep the per-engine runtime (incl. Pallas interpret) test-sized.
ENGINE_KW = dict(tile=16, queue_capacity=8, n_workers=2)


@pytest.fixture(scope="module")
def morph_case():
    _, mask = tissue_image(48, 56, coverage=0.8, seed=0)
    marker = seeded_marker(mask, n_seeds=4, seed=0)
    ref = reconstruct_fh(marker.copy(), mask, connectivity=8).astype(np.int32)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)))
    return op, state, ref


@pytest.fixture(scope="module")
def edt_case():
    fg = bg_disks(48, 48, coverage=0.9, n_disks=2, seed=1)
    ref_M, _ = edt_wavefront(fg, connectivity=8)
    op = EdtOp(connectivity=8)
    return op, op.make_state(jnp.asarray(fg)), ref_M


@pytest.mark.parametrize("engine", NAMED_ENGINES)
def test_every_engine_matches_morph_ref(morph_case, engine):
    op, state, ref = morph_case
    out, stats = solve(op, state, engine=engine, **ENGINE_KW)
    np.testing.assert_array_equal(np.asarray(out["J"]), ref)
    assert stats.engine == engine


@pytest.mark.parametrize("engine", NAMED_ENGINES)
def test_every_engine_matches_edt_ref(edt_case, engine):
    op, state, ref_M = edt_case
    out, stats = solve(op, state, engine=engine, **ENGINE_KW)
    np.testing.assert_array_equal(np.asarray(distance_map(out)), ref_M)
    assert stats.engine == engine


def test_auto_matches_ref_and_records_cost(morph_case):
    op, state, ref = morph_case
    out, stats = solve(op, state, engine="auto")
    np.testing.assert_array_equal(np.asarray(out["J"]), ref)
    assert stats.engine in NAMED_ENGINES
    assert stats.predicted_cost is not None and stats.predicted_cost > 0


def test_stats_are_normalized(morph_case):
    """Every engine reports the same SolveStats record (comparable rows)."""
    op, state, _ = morph_case
    for engine in NAMED_ENGINES:
        _, stats = solve(op, state, engine=engine, **ENGINE_KW)
        assert isinstance(stats, SolveStats)
        assert stats.rounds >= 1
        if engine in ("tiled", "tiled-pallas", "scheduler"):
            assert stats.tiles_processed > 0
        if engine in ("sweep", "frontier"):
            assert stats.sources_processed > 0


def test_auto_picks_tiled_for_sparse_seeds():
    _, mask = tissue_image(64, 64, coverage=1.0, seed=0)
    marker = seeded_marker(mask, n_seeds=2, seed=0)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)))
    stats_in = collect_input_stats(op, state)
    assert stats_in.density < 0.05            # the premise: sparse wavefront
    _, stats = solve(op, state, engine="auto")
    # any member of the tiled hierarchy (incl. its cooperative consumer)
    assert stats.engine in ("tiled", "tiled-pallas", "scheduler", "hybrid")


def test_auto_picks_dense_for_near_full_frontier():
    marker, mask = tissue_image(64, 64, coverage=1.0, seed=0)  # mask - h marker
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)))
    stats_in = collect_input_stats(op, state)
    assert stats_in.density > 0.5             # the premise: near-full frontier
    _, stats = solve(op, state, engine="auto")
    assert stats.engine in ("sweep", "frontier", "shard_map")


def test_cost_model_is_pluggable(morph_case):
    """A subclassed model (MATCH-style override) steers the selection."""
    op, state, ref = morph_case

    class FrontierAlways(CostModel):
        def cost(self, stats, cfg):
            return 0.0 if cfg.engine == "frontier" else 1e18

    out, stats = solve(op, state, engine="auto", cost_model=FrontierAlways())
    assert stats.engine == "frontier"
    np.testing.assert_array_equal(np.asarray(out["J"]), ref)


def test_autotune_caches_winner(morph_case):
    op, state, ref = morph_case
    clear_autotune_cache()
    out, s1 = solve(op, state, engine="auto", autotune=True,
                    autotune_top_k=2, autotune_repeats=1)
    np.testing.assert_array_equal(np.asarray(out["J"]), ref)
    assert s1.autotuned
    assert len(solve_mod._AUTOTUNE_CACHE) == 1
    _, s2 = solve(op, state, engine="auto", autotune=True)
    assert len(solve_mod._AUTOTUNE_CACHE) == 1          # cache hit, no growth
    assert s2.engine == s1.engine
    sig = autotune_signature(op, collect_input_stats(op, state),
                             restrictions=(None, None, None, None, None))
    assert sig in solve_mod._AUTOTUNE_CACHE
    # a caller restriction is a different cache row, never a stale hit
    _, s3 = solve(op, state, engine="auto", autotune=True,
                  autotune_top_k=1, autotune_repeats=1, tile=16)
    assert s3.tile in (None, 16)
    assert len(solve_mod._AUTOTUNE_CACHE) == 2
    clear_autotune_cache()


def test_unknown_engine_raises(morph_case):
    op, state, _ = morph_case
    with pytest.raises(ValueError, match="engine"):
        solve(op, state, engine="warp-drive")


def test_non_tile_aligned_grids(edt_case):
    """Padding adapters: scheduler/shard_map on a grid no tile divides."""
    fg = bg_disks(37, 51, coverage=0.9, n_disks=2, seed=3)
    ref_M, _ = edt_wavefront(fg, connectivity=8)
    op = EdtOp(connectivity=8)
    state = op.make_state(jnp.asarray(fg))
    for engine in ("scheduler", "shard_map", "tiled"):
        out, _ = solve(op, state, engine=engine, tile=16, n_workers=2)
        np.testing.assert_array_equal(np.asarray(distance_map(out)), ref_M)


def test_convenience_wrappers_match_refs():
    _, mask = tissue_image(40, 40, coverage=0.8, seed=2)
    marker = seeded_marker(mask, n_seeds=3, seed=2)
    ref = reconstruct_fh(marker.copy(), mask, connectivity=8).astype(np.int32)
    J, stats = reconstruct(marker.astype(np.int32), mask.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(J), ref)
    assert stats.engine in NAMED_ENGINES

    fg = bg_disks(40, 40, coverage=0.9, n_disks=2, seed=2)
    ref_M, _ = edt_wavefront(fg, connectivity=8)
    M, _ = edt(fg)
    np.testing.assert_array_equal(np.asarray(M), ref_M)


def test_candidates_respect_devices_and_tiles():
    _, mask = tissue_image(32, 32, coverage=0.9, seed=0)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(mask.astype(np.int32)) // 2,
                          jnp.asarray(mask.astype(np.int32)))
    stats1 = collect_input_stats(op, state, n_devices=1)
    cands1 = CostModel().candidates(stats1)
    assert all(c.engine != "shard_map" for c in cands1)
    stats8 = dataclasses.replace(stats1, n_devices=8)
    cands8 = CostModel().candidates(stats8)
    assert any(c.engine == "shard_map" for c in cands8)


def test_autotune_surfaces_failed_candidates(morph_case):
    """A candidate that raises must be warned about and recorded, so a
    fully-failing candidate set is distinguishable from a fast one."""
    op, state, ref = morph_case
    clear_autotune_cache()

    class ZeroModel(CostModel):
        def cost(self, stats, cfg):
            # rank the broken candidate first, the good one second
            return 0.0 if cfg.engine == "tiled" else 1.0

    broken = EngineConfig("tiled", tile=-7)  # negative tile -> pad ValueError
    good = EngineConfig("frontier")
    stats_in = collect_input_stats(op, state)
    with pytest.warns(RuntimeWarning, match="candidate .* failed"):
        cfg = solve_mod._autotune(op, state, stats_in, ZeroModel(),
                                  [broken, good], (), 2, 1,
                                  max_rounds=10_000, devices=None,
                                  interpret=True, n_workers=2)
    assert cfg == good
    sig = autotune_signature(op, stats_in, ())
    assert sig in solve_mod._AUTOTUNE_FAILURES
    (failed_cfg, err), = solve_mod._AUTOTUNE_FAILURES[sig]
    assert failed_cfg == broken and err
    # all-failing candidate set: fall back to the ranking, but warn and
    # record nan so the cache row is visibly unmeasured
    clear_autotune_cache()
    with pytest.warns(RuntimeWarning, match="all .* candidates failed"):
        cfg = solve_mod._autotune(op, state, stats_in, ZeroModel(),
                                  [broken], (), 1, 1,
                                  max_rounds=10_000, devices=None,
                                  interpret=True, n_workers=2)
    assert cfg == broken
    assert np.isnan(solve_mod._AUTOTUNE_CACHE[autotune_signature(op, stats_in, ())][1])
    clear_autotune_cache()


def test_drain_batch_knob_threads_through(morph_case):
    op, state, ref = morph_case
    out, stats = solve(op, state, engine="tiled", tile=16, queue_capacity=8,
                       drain_batch=4)
    np.testing.assert_array_equal(np.asarray(out["J"]), ref)
    assert stats.drain_batch == 4
    out, stats = solve(op, state, engine="tiled", tile=16, queue_capacity=8,
                       drain_batch=1)
    np.testing.assert_array_equal(np.asarray(out["J"]), ref)
    assert stats.drain_batch == 1


def test_source_counter_exact_past_float32():
    """sources_processed must stay exact beyond 2^24 (float32's integer
    cliff) without x64: the counter is a (lo, hi) uint32 pair."""
    from repro.core.frontier import RunStats, accumulate_u64
    lo = jnp.uint32(2**32 - 5)
    hi = jnp.uint32(3)
    lo, hi = accumulate_u64(lo, hi, jnp.uint32(7))       # wraps the low word
    stats = RunStats(jnp.int32(1), lo, hi)
    assert stats.sources_processed == (3 << 32) + (2**32 - 5) + 7
    # float32 would round this neighborhood; ints must not
    big = (1 << 24) + 1
    lo, hi = accumulate_u64(jnp.uint32(big), jnp.uint32(0), jnp.uint32(1))
    assert (int(hi) << 32 | int(lo)) == big + 1
