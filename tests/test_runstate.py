"""Persistent round state (DESIGN.md §2.6).

Four layers:

* ``SolveStats.recompiles`` — the §2.6 contract: compile-cache misses are
  constant in the round count (a warm re-solve reports 0, and an input that
  needs MORE BP rounds at the same shapes adds no new compiles), checked
  in-process for tiled/hybrid and in a forced-multi-device subprocess for
  the composed shard_map-tiled engine;
* bit-equality of the RunState-carrying engines against the dense frontier
  reference on masked and truncation-forcing fixtures (the invalid-cell and
  truncated-drain contracts survive the persistent-carrier refactor);
* the resident in-kernel queue seam (``queued_fixed_point(initial_queue=…)``
  + ``fit_seed``): a caller-seeded queue reaches the same fixed point as
  the kernel's own dense seeding round, including the count-overflow spill
  and count==0 fast paths, single and batched, morph and EDT;
* the disk autotune cache (core.autotune_disk): round-trip, the disk hit
  short-circuiting re-measurement, spec-change invalidation, and the
  code-version key.
"""

import dataclasses
import os

import jax.numpy as jnp
import numpy as np
import pytest

import repro.solve as solve_mod
from repro.core import autotune_disk, compile_cache
from repro.core.frontier import run_dense
from repro.data.images import binary_blobs, tissue_image
from repro.edt.ops import EdtOp, distance_map
from repro.edt.ref import SENTINEL
from repro.kernels.morph_tile import (morph_tile_solve,
                                      morph_tile_solve_queued,
                                      morph_tile_solve_queued_batched)
from repro.kernels.edt_tile import (edt_tile_solve, edt_tile_solve_queued,
                                    edt_tile_solve_queued_batched)
from repro.kernels.queue import fit_seed
from repro.morph.ops import MorphReconstructOp
from repro.solve import EngineConfig, solve

from test_distributed import run_sub


# ---------------------------------------------------------------------------
# SolveStats.recompiles: constant in rounds, zero when warm.
# ---------------------------------------------------------------------------

def _masked_morph_case(shape=(40, 52), seed=0, coverage=0.8):
    marker, mask = tissue_image(*shape, coverage, seed)
    op = MorphReconstructOp(connectivity=8)
    H, W = shape
    yy, xx = np.mgrid[:H, :W]
    valid = ((yy - H / 2) ** 2 + (xx - W / 2) ** 2) < (0.48 * max(H, W)) ** 2
    state = op.make_state(jnp.asarray(np.minimum(marker, mask).astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)),
                          jnp.asarray(valid))
    return op, state


def test_recompiles_zero_on_warm_resolve_tiled():
    op, state = _masked_morph_case()
    compile_cache.clear()
    _, cold = solve(op, state, engine="tiled", tile=16, queue_capacity=8)
    assert cold.recompiles > 0           # the cold run did compile something
    out, warm = solve(op, state, engine="tiled", tile=16, queue_capacity=8)
    assert warm.recompiles == 0, warm.recompiles
    ref, _ = run_dense(op, state, "frontier")
    np.testing.assert_array_equal(np.asarray(out["J"]), np.asarray(ref["J"]))


def test_recompiles_flat_in_rounds_hybrid():
    """More propagation rounds at the same shapes must add ZERO compiles:
    every hybrid worker drains through the shared scheduler-drain entry."""
    op, near = _masked_morph_case(seed=1)
    # same shapes, one far corner seed -> strictly more propagation work
    _, mask = tissue_image(40, 52, 0.8, 1)
    marker = np.zeros((40, 52), np.int32)
    marker[0, 0] = int(mask[0, 0])
    far = op.make_state(jnp.asarray(marker),
                        jnp.asarray(mask.astype(np.int32)))
    kw = dict(engine="hybrid", tile=16, n_workers=1, n_device_workers=1,
              drain_batch=2)
    compile_cache.clear()
    _, cold = solve(op, near, **kw)
    assert cold.recompiles > 0
    _, warm = solve(op, near, **kw)
    assert warm.recompiles == 0, warm.recompiles
    out, warm2 = solve(op, far, **kw)
    assert warm2.recompiles == 0, warm2.recompiles
    ref, _ = run_dense(op, far, "frontier")
    np.testing.assert_array_equal(np.asarray(out["J"]), np.asarray(ref["J"]))


def test_recompiles_flat_across_bp_rounds_shard_map_tiled():
    """The composed engine's acceptance bar: a warm re-solve reports
    recompiles == 0 even on an input needing MORE BP rounds (one corner
    seed crossing every shard boundary vs seeds in every quadrant)."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.frontier import run_dense
        from repro.morph.ops import MorphReconstructOp
        from repro.solve import solve
        op = MorphReconstructOp(connectivity=8)
        H, W = 48, 64
        mask = np.full((H, W), 200, np.int32)
        def case(seeds):
            marker = np.zeros((H, W), np.int32)
            for r, c in seeds:
                marker[r, c] = 200
            return op.make_state(jnp.asarray(marker), jnp.asarray(mask))
        near = case([(r, c) for r in (6, 42) for c in (6, 26, 44, 60)])
        far = case([(0, 0)])
        kw = dict(engine="shard_map-tiled", tile=16, queue_capacity=8)
        _, cold = solve(op, near, **kw)
        assert cold.recompiles > 0, cold
        _, warm = solve(op, near, **kw)
        assert warm.recompiles == 0, warm.recompiles
        out, warm2 = solve(op, far, **kw)
        assert warm2.rounds > warm.rounds        # genuinely more BP rounds
        assert warm2.recompiles == 0, warm2.recompiles
        ref, _ = run_dense(op, far, "frontier")
        np.testing.assert_array_equal(np.asarray(out["J"]),
                                      np.asarray(ref["J"]))
        print("OK", cold.recompiles, warm.rounds, warm2.rounds)
    """, devices=4)


# ---------------------------------------------------------------------------
# Bit-equality of the RunState engines on masked / truncation fixtures.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,kw", [
    ("tiled", dict(tile=16, queue_capacity=8)),
    ("tiled-pallas", dict(tile=16, queue_capacity=8)),
    ("scheduler", dict(tile=16, n_workers=2)),
    ("hybrid", dict(tile=16, n_workers=1, n_device_workers=1, drain_batch=2)),
])
def test_engines_bit_equal_on_masked_fixture(engine, kw):
    op, state = _masked_morph_case(seed=2)
    ref, _ = run_dense(op, state, "frontier")
    out, st = solve(op, state, engine=engine, **kw)
    np.testing.assert_array_equal(np.asarray(out["J"]), np.asarray(ref["J"]))
    # invalid cells hold their input values (the restore_invalid contract)
    inv = ~np.asarray(state["valid"])
    np.testing.assert_array_equal(np.asarray(out["J"])[inv],
                                  np.asarray(state["J"])[inv])


def test_truncated_drains_still_exact():
    """queue_capacity=2 + tile=8 forces overflow re-seeds and unconverged
    re-queues on the serpentine corridor; the fixed point stays exact."""
    from test_truncation import serpentine_case
    marker, mask, expected = serpentine_case(32)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                          jnp.asarray(mask.astype(np.int32)))
    out, st = solve(op, state, engine="tiled", tile=8, queue_capacity=2)
    np.testing.assert_array_equal(np.asarray(out["J"]), expected)
    assert st.overflow_events > 0 or st.tiles_requeued > 0


# ---------------------------------------------------------------------------
# The resident in-kernel queue seam (§2.6): caller-provided initial queues.
# ---------------------------------------------------------------------------

def _seeded_morph_block(h=34, w=34, seed=9):
    marker, mask = tissue_image(h, w, 0.8, seed)
    J = jnp.asarray(np.minimum(marker, mask).astype(np.int32))
    I = jnp.asarray(mask.astype(np.int32))
    rng = np.random.default_rng(seed)
    valid = jnp.asarray(rng.random((h, w)) < 0.9)
    return J, I, valid


def _true_frontier(J, valid):
    """Every valid pixel holding a non-neutral value — a superset of the
    pixels the kernel's own dense seeding round would enqueue."""
    m = np.asarray(jnp.where(valid, J, 0)) > 0
    idx = np.flatnonzero(m.reshape(-1)).astype(np.int32)
    return jnp.asarray(idx), np.int32(idx.size)


def test_fit_seed_layout():
    idx = jnp.asarray([3, 7, 11], jnp.int32)
    np.testing.assert_array_equal(np.asarray(fit_seed(idx, 6)),
                                  [3, 7, 11, -1, -1, -1])
    # truncation is safe ONLY alongside a count > capacity (dense spill)
    np.testing.assert_array_equal(np.asarray(fit_seed(idx, 2)), [3, 7])


def test_seeded_queue_reaches_dense_fixed_point():
    J, I, valid = _seeded_morph_block()
    ref, _ = morph_tile_solve(J, I, valid, connectivity=8, interpret=True)
    idx, count = _true_frontier(J, valid)
    out, iters, spills = morph_tile_solve_queued(
        J, I, valid, (idx, count), connectivity=8, queue_capacity=1200,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert int(iters) >= 1


def test_seeded_queue_count_overflow_spills_dense_and_stays_exact():
    J, I, valid = _seeded_morph_block(seed=10)
    ref, _ = morph_tile_solve(J, I, valid, connectivity=8, interpret=True)
    idx, _ = _true_frontier(J, valid)
    # a count far above capacity: round 0 must spill to a dense sweep
    out, iters, spills = morph_tile_solve_queued(
        J, I, valid, (idx, np.int32(10_000)), connectivity=8,
        queue_capacity=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert int(spills) >= 1


def test_seeded_queue_zero_count_converges_immediately():
    J, I, valid = _seeded_morph_block(seed=11)
    out, iters, spills = morph_tile_solve_queued(
        J, I, valid, (jnp.full((4,), -1, jnp.int32), np.int32(0)),
        connectivity=8, queue_capacity=16, interpret=True)
    # valid cells untouched (invalid ones hold kernel-internal sanitized
    # fills — the ENGINE layer restores those, not the raw kernel)
    v = np.asarray(valid)
    np.testing.assert_array_equal(np.asarray(out)[v], np.asarray(J)[v])
    assert int(iters) == 0 and int(spills) == 0


def test_seeded_queue_batched_matches_unbatched():
    blocks = [_seeded_morph_block(seed=s) for s in (20, 21, 22)]
    J = jnp.stack([b[0] for b in blocks])
    I = jnp.stack([b[1] for b in blocks])
    valid = jnp.stack([b[2] for b in blocks])
    seeds = [_true_frontier(b[0], b[2]) for b in blocks]
    cap = 1200
    sq = jnp.stack([fit_seed(s[0], cap) for s in seeds])
    cnt = jnp.asarray([s[1] for s in seeds], jnp.int32)
    out, iters, spills = morph_tile_solve_queued_batched(
        J, I, valid, (sq, cnt), connectivity=8, queue_capacity=cap,
        interpret=True)
    for k, (Jk, Ik, vk) in enumerate(blocks):
        ref, ri, _ = morph_tile_solve_queued(
            Jk, Ik, vk, seeds[k], connectivity=8, queue_capacity=cap,
            interpret=True)
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(ref))
        assert int(iters[k]) == int(ri)


def test_seeded_queue_edt_exact():
    op = EdtOp(connectivity=8)
    st_ = op.make_state(jnp.asarray(binary_blobs(34, 34, 0.5, seed=6)))
    args = (st_["vr"][0], st_["vr"][1], st_["valid"], st_["row"], st_["col"])
    dr, dc, _ = edt_tile_solve(*args, connectivity=8, interpret=True)
    m = np.asarray(st_["vr"][0]) != SENTINEL     # every already-claimed pixel
    idx = jnp.asarray(np.flatnonzero(m.reshape(-1)).astype(np.int32))
    qr, qc, qi, _ = edt_tile_solve_queued(
        *args, (idx, np.int32(idx.size)), connectivity=8,
        queue_capacity=1200, interpret=True)
    np.testing.assert_array_equal(np.asarray(dr), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(dc), np.asarray(qc))


def test_seeded_queue_edt_batched_exact():
    op = EdtOp(connectivity=8)
    states = [op.make_state(jnp.asarray(binary_blobs(20, 20, 0.5, seed=s)))
              for s in (7, 8)]
    cap = 420
    seeds = []
    for st_ in states:
        m = np.asarray(st_["vr"][0]) != SENTINEL
        idx = jnp.asarray(np.flatnonzero(m.reshape(-1)).astype(np.int32))
        seeds.append((fit_seed(idx, cap), np.int32(idx.size)))
    stack = lambda k: jnp.stack([s[k] for s in states])
    sq = jnp.stack([s[0] for s in seeds])
    cnt = jnp.asarray([s[1] for s in seeds], jnp.int32)
    br, bc, _, _ = edt_tile_solve_queued_batched(
        stack("vr")[:, 0], stack("vr")[:, 1], stack("valid"), stack("row"),
        stack("col"), (sq, cnt), connectivity=8, queue_capacity=cap,
        interpret=True)
    for k, st_ in enumerate(states):
        dr, dc, _ = edt_tile_solve(st_["vr"][0], st_["vr"][1], st_["valid"],
                                   st_["row"], st_["col"], connectivity=8,
                                   interpret=True)
        np.testing.assert_array_equal(np.asarray(br[k]), np.asarray(dr))
        np.testing.assert_array_equal(np.asarray(bc[k]), np.asarray(dc))


# ---------------------------------------------------------------------------
# Disk autotune cache (core.autotune_disk).
# ---------------------------------------------------------------------------

def test_disk_cache_round_trip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_IWPP_CACHE_DIR", str(tmp_path))
    sig = ("MorphReconstructOp", 8, 40, 52, -2, 1)
    cfg = EngineConfig("tiled", tile=16, queue_capacity=8)
    assert autotune_disk.load("MorphReconstructOp", sig, EngineConfig) is None
    autotune_disk.store("MorphReconstructOp", sig, cfg, 0.0125)
    got = autotune_disk.load("MorphReconstructOp", sig, EngineConfig)
    assert got is not None
    assert got[0] == cfg and got[1] == 0.0125
    # a different signature misses
    assert autotune_disk.load("MorphReconstructOp", sig[:-1] + (8,),
                              EngineConfig) is None
    # invalidation by op name drops it
    assert autotune_disk.invalidate_op({"MorphReconstructOp"}) == 1
    assert autotune_disk.load("MorphReconstructOp", sig, EngineConfig) is None


def test_disk_cache_rejects_foreign_schema(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_IWPP_CACHE_DIR", str(tmp_path))
    sig = ("EdtOp", 8, 10, 10, -1, 1)
    # an entry whose config carries an unknown field (written by a future
    # EngineConfig) must be ignored, not crash the load
    autotune_disk.store("EdtOp", sig, EngineConfig("frontier"), 0.5)
    key = autotune_disk.entry_key("EdtOp", sig)
    doc = autotune_disk._load_doc()
    doc["entries"][key]["config"]["not_a_field"] = 1
    autotune_disk._store_doc(doc)
    assert autotune_disk.load("EdtOp", sig, EngineConfig) is None


def test_autotune_hits_disk_across_cache_clear(tmp_path, monkeypatch):
    """A persisted winner short-circuits the whole measurement sweep: after
    clearing the in-process cache, _autotune returns without ranking."""
    monkeypatch.setenv("REPRO_IWPP_CACHE_DIR", str(tmp_path))
    rng = np.random.default_rng(0)
    mask = rng.integers(0, 200, (24, 24)).astype(np.int32)
    marker = np.where(rng.random((24, 24)) < 0.05, mask, 0).astype(np.int32)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker), jnp.asarray(mask))
    stats = solve_mod.collect_input_stats(op, state)
    cands = [EngineConfig("frontier"), EngineConfig("tiled", 8, 16, 1)]
    model = solve_mod.CostModel()
    solve_mod.clear_autotune_cache(disk=True)
    cfg = solve_mod._autotune(op, state, stats, model, cands, (), 2, 1,
                              max_rounds=10_000)
    assert cfg in cands
    assert os.path.exists(autotune_disk.cache_path())

    solve_mod.clear_autotune_cache(disk=False)       # keep only the disk copy

    class _NoRank(solve_mod.CostModel):
        def rank(self, *a, **k):
            raise AssertionError("disk hit must skip the measurement sweep")

    cfg2 = solve_mod._autotune(op, state, stats, _NoRank(), cands, (), 2, 1,
                               max_rounds=10_000)
    assert cfg2 == cfg
    sig = solve_mod.autotune_signature(op, stats, ())
    assert sig in solve_mod._AUTOTUNE_CACHE          # promoted back in-process


def test_entry_key_carries_code_version(monkeypatch):
    sig = ("MorphReconstructOp", 8, 1, 1, -1, 1)
    k1 = autotune_disk.entry_key("MorphReconstructOp", sig)
    assert autotune_disk.code_version() in k1
    monkeypatch.setattr(autotune_disk, "_code_version_memo", "deadbeef")
    assert autotune_disk.entry_key("MorphReconstructOp", sig) != k1
