"""The loop-aware HLO cost model (launch/hlocost.py) against known ground
truth — this is the instrument every roofline number relies on."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlocost import analyze


def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_single_matmul_exact():
    a = jax.ShapeDtypeStruct((96, 200), jnp.float32)
    b = jax.ShapeDtypeStruct((200, 56), jnp.float32)
    r = analyze(_compiled(lambda a, b: a @ b, a, b).as_text())
    assert r["flops"] == 2 * 96 * 200 * 56
    assert r["n_warnings"] == 0


def test_scanned_matmul_trip_weighted():
    """cost_analysis counts the body once; hlocost must multiply by trips."""
    T = 9

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((T, 64, 64), jnp.float32)
    c = _compiled(f, x, ws)
    r = analyze(c.as_text())
    dot_flops = T * 2 * 32 * 64 * 64
    assert r["flops"] >= dot_flops                    # dots fully counted
    assert r["flops"] <= 1.5 * dot_flops              # no runaway overcount
    xla = c.cost_analysis()
    if isinstance(xla, (list, tuple)):  # older jax: one dict per program
        xla = xla[0]
    assert xla["flops"] < dot_flops / 2               # the bug being fixed


def test_nested_scan_multiplies():
    def f(x, ws):
        def outer(c, _):
            def inner(ci, w):
                return jnp.tanh(ci @ w), None
            c, _ = jax.lax.scan(inner, c, ws)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    r = analyze(_compiled(f, x, ws).as_text())
    dot_flops = 3 * 5 * 2 * 16 * 32 * 32
    assert dot_flops <= r["flops"] <= 1.5 * dot_flops


def test_dus_counts_slice_not_buffer():
    """Scan output stacking writes a slice per iteration, not the buffer."""
    N, S, D = 64, 128, 128

    def f(x):
        def body(c, _):
            c = jnp.tanh(c)
            return c, c
        _, ys = jax.lax.scan(body, x, None, length=N)
        return ys

    x = jax.ShapeDtypeStruct((S, D), jnp.float32)
    r = analyze(_compiled(f, x).as_text())
    buf = N * S * D * 4
    # naive accounting: ~N x the full (N,S,D) buffer per iteration
    assert r["bytes"] < 0.5 * N * buf, (r["bytes"], N * buf)


def test_collectives_weighted_by_trips():
    import os
    import subprocess
    import sys
    import textwrap
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlocost import analyze
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, ws)
            return out.sum()
        x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
        c = jax.jit(jax.grad(f), in_shardings=(
            NamedSharding(mesh, P("data", None)),
            NamedSharding(mesh, P(None, None, "model")))).lower(x, ws).compile()
        r = analyze(c.as_text())
        kinds = {k: v["count"] for k, v in r["coll"].items()
                 if isinstance(v, dict) and v["count"]}
        # at least one collective kind must be counted ~6x (once per trip)
        assert any(v >= 6 for v in kinds.values()), kinds
        print("OK", kinds)
    """)], capture_output=True, text=True, timeout=300, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
