"""The two registry-proving workloads (ISSUE: fill-holes + labeling) against
scipy.ndimage and the repo's own sequential references, across the tiled /
tiled-pallas / scheduler / hybrid engines — all reached purely through the
``repro.ops`` plugin registry, with zero edits to engine code.

Conventions under test:
* fill-holes: ``connectivity`` is the *background flood* connectivity;
  scipy's default cross structure == 4.
* labeling: the IWPP fixed point carries max-linear-index labels
  (bit-comparable to ``label_wavefront``); scipy's label *values* are
  scan-order artifacts, so the scipy comparison is component-membership
  equality up to relabeling (``same_components``).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.fill.ops import FillHolesOp, fill_holes
from repro.fill.ref import fill_holes_bfs
from repro.label.ops import LabelPropagationOp, label
from repro.label.ref import label_wavefront, relabel_sequential, same_components
from repro.solve import solve

ndi = pytest.importorskip("scipy.ndimage")

ENGINES_UNDER_TEST = ("tiled", "tiled-pallas", "scheduler", "hybrid")
ENGINE_KW = dict(tile=16, queue_capacity=8, n_workers=2)


def _blobby(shape, density, seed):
    rng = np.random.default_rng(seed)
    img = rng.random(shape) < density
    # stamp a guaranteed hole so every fixture exercises actual filling
    img[4:12, 5:13] = True
    img[7:9, 8:10] = False
    return img


@pytest.fixture(scope="module")
def fill_case():
    img = _blobby((48, 56), 0.45, seed=0)
    return img, fill_holes_bfs(img, connectivity=4)


@pytest.fixture(scope="module")
def label_case():
    fg = np.random.default_rng(1).random((48, 56)) < 0.55
    return fg, label_wavefront(fg, connectivity=8)


def test_refs_agree_with_scipy(fill_case, label_case):
    img, ref_fill = fill_case
    fg, ref_lab = label_case
    np.testing.assert_array_equal(ref_fill, ndi.binary_fill_holes(img))
    scipy_lab, n = ndi.label(fg, structure=np.ones((3, 3)))
    assert same_components(ref_lab, scipy_lab)
    assert len(np.unique(ref_lab[ref_lab > 0])) == n


@pytest.mark.parametrize("engine", ENGINES_UNDER_TEST)
def test_fill_holes_matches_scipy_on_every_engine(fill_case, engine):
    img, ref = fill_case
    out, stats = fill_holes(img, engine=engine, **ENGINE_KW)
    np.testing.assert_array_equal(np.asarray(out), ref)
    assert stats.engine == engine


@pytest.mark.parametrize("engine", ENGINES_UNDER_TEST)
def test_label_matches_scipy_on_every_engine(label_case, engine):
    fg, ref = label_case
    out, stats = label(fg, engine=engine, **ENGINE_KW)
    lab = np.asarray(out)
    np.testing.assert_array_equal(lab, ref)        # bit-exact vs IWPP ref
    scipy_lab, _ = ndi.label(fg, structure=np.ones((3, 3)))
    assert same_components(lab, scipy_lab)         # membership vs scipy
    assert stats.engine == engine


@pytest.mark.parametrize("engine", ENGINES_UNDER_TEST)
def test_by_name_solve_reaches_every_engine(fill_case, engine):
    """Acceptance bar: solve('fill_holes'/'label', raw_input) by name."""
    img, ref = fill_case
    out, stats = solve("fill_holes", jnp.asarray(img), engine=engine,
                       **ENGINE_KW)
    np.testing.assert_array_equal(np.asarray(out["J"] == 0), ref)
    assert stats.engine == engine
    fg = jnp.asarray(img)   # any bool image labels fine
    lout, lstats = solve("label", fg, engine=engine, **ENGINE_KW)
    np.testing.assert_array_equal(
        np.asarray(lout["lab"]),
        label_wavefront(np.asarray(fg), connectivity=8))
    assert lstats.engine == engine


def test_by_name_solve_covers_all_remaining_engines(fill_case, label_case):
    """Acceptance bar, completed: together with the engine-parametrized
    tests above, both new ops run by name on every member of ENGINES."""
    from repro.solve import ENGINES
    covered = set(ENGINES) - {"auto"} - set(ENGINES_UNDER_TEST)
    assert covered == {"sweep", "frontier", "shard_map", "shard_map-tiled"}
    img, ref = fill_case
    fg, ref_lab = label_case
    for engine in sorted(covered) + ["auto"]:
        kw = dict(tile=16, queue_capacity=8) if "tiled" in engine else {}
        out, _ = solve("fill_holes", jnp.asarray(img), engine=engine, **kw)
        np.testing.assert_array_equal(np.asarray(out["J"] == 0), ref,
                                      err_msg=f"fill_holes via {engine}")
        lout, _ = solve("label", jnp.asarray(fg), engine=engine, **kw)
        np.testing.assert_array_equal(np.asarray(lout["lab"]), ref_lab,
                                      err_msg=f"label via {engine}")


def test_fill_connectivity_matches_scipy_structures(fill_case):
    img, _ = fill_case
    # conn=4 == scipy default cross structure; conn=8 == full 3x3 structure
    out4, _ = fill_holes(img, connectivity=4, engine="frontier")
    np.testing.assert_array_equal(np.asarray(out4), ndi.binary_fill_holes(img))
    out8, _ = fill_holes(img, connectivity=8, engine="frontier")
    np.testing.assert_array_equal(
        np.asarray(out8),
        ndi.binary_fill_holes(img, structure=np.ones((3, 3))))


def test_label_connectivity_4(label_case):
    fg, _ = label_case
    out, _ = label(fg, connectivity=4, engine="frontier")
    np.testing.assert_array_equal(np.asarray(out),
                                  label_wavefront(fg, connectivity=4))
    scipy_lab, _ = ndi.label(fg)                    # scipy default = cross
    assert same_components(np.asarray(out), scipy_lab)


def test_fill_and_label_edge_cases():
    # all-foreground: nothing to flood, everything stays foreground
    full = np.ones((12, 14), bool)
    out, _ = fill_holes(full, engine="frontier")
    np.testing.assert_array_equal(np.asarray(out), full)
    lab, _ = label(full, engine="frontier")
    assert len(np.unique(np.asarray(lab))) == 1     # one component
    # all-background: border flood reaches everything, nothing is filled
    empty = np.zeros((12, 14), bool)
    out, _ = fill_holes(empty, engine="frontier")
    np.testing.assert_array_equal(np.asarray(out), empty)
    lab, _ = label(empty, engine="frontier")
    assert not np.asarray(lab).any()


def test_fill_invalid_cells_report_input_values():
    """Regression: invalid cells of the *extracted* filled image hold the
    input image values (bg never filled, fg preserved) — `filled()` must
    not read the restored J==0 of invalid background as 'hole'."""
    img = np.zeros((16, 16), bool)
    img[10:13, 10:13] = True                   # some fg inside the invalid patch
    valid = np.ones((16, 16), bool)
    valid[9:14, 9:14] = False
    op = FillHolesOp(connectivity=4)
    state = op.make_state(jnp.asarray(img), jnp.asarray(valid))
    out, _ = solve(op, state, engine="frontier")
    filled = np.asarray(op.filled(out))
    np.testing.assert_array_equal(filled[~valid], img[~valid])
    assert not filled[valid].any()             # open background, no holes


def test_label_seeds_enforce_cap():
    """Regression: grids whose max label would exceed LABEL_CAP (the Pallas
    solver's mask value, which would silently clamp and merge components)
    must be rejected up front, on every engine path."""
    from repro.kernels.ops import LABEL_CAP as KERNEL_CAP
    from repro.label.ops import LABEL_CAP, label_seeds

    assert KERNEL_CAP == LABEL_CAP   # one invariant, not two constants

    class _HugeFake:                 # guard fires on .shape, before any alloc
        shape = (1 << 16, 1 << 15)   # 2^31 pixels > LABEL_CAP

    with pytest.raises(ValueError, match="LABEL_CAP"):
        label_seeds(_HugeFake())


def test_relabel_sequential_compacts():
    lab = np.array([[0, 7, 7], [0, 0, 3]])
    np.testing.assert_array_equal(relabel_sequential(lab),
                                  [[0, 1, 1], [0, 0, 2]])


def test_non_tile_aligned_fill_and_label():
    """Padding adapters on a grid no tile divides, both new ops."""
    img = _blobby((37, 51), 0.45, seed=5)
    ref = fill_holes_bfs(img, connectivity=4)
    fg = np.random.default_rng(6).random((37, 51)) < 0.55
    ref_lab = label_wavefront(fg, connectivity=8)
    for engine in ("tiled", "scheduler"):
        out, _ = fill_holes(img, engine=engine, tile=16, n_workers=2)
        np.testing.assert_array_equal(np.asarray(out), ref)
        lab, _ = label(fg, engine=engine, tile=16, n_workers=2)
        np.testing.assert_array_equal(np.asarray(lab), ref_lab)
