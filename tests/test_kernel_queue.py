"""Kernel-level suite for the in-kernel multi-level queue (DESIGN.md §2.5).

Three layers:

* unit tests of the scan-compaction primitive (`kernels/queue.py`) — empty
  queue, single pixel, all-active block, the exact-capacity boundary, the
  overflow/spill path, and duplicate-enqueue idempotence;
* equivalence of the queued and dense tile solvers: bit-equal planes *and*
  bit-equal iteration counts for morph/label, bit-equal Voronoi pointers
  (stronger than distance-equality) for EDT — on seeded random masked
  blocks always, and on hypothesis-generated ones when available;
* the solve()-level plumbing (`kernel_queue=True` stats echo, knob guards)
  and the autotune-failure invalidation regression (a failed queued-kernel
  candidate must be retried after its spec is fixed, ISSUE 6 satellite).
"""

import warnings

import jax.numpy as jnp
import numpy as np
import pytest

import repro.solve as solve_mod
from repro.core.pattern import offsets_for
from repro.data.images import binary_blobs, tissue_image
from repro.edt.ops import EdtOp
from repro.kernels.edt_tile import edt_tile_solve, edt_tile_solve_queued
from repro.kernels.morph_tile import (morph_tile_solve,
                                      morph_tile_solve_queued,
                                      morph_tile_solve_queued_batched)
from repro.kernels.ops import default_kernel_queue_capacity
from repro.kernels.queue import compact_mask, dilate
from repro.morph.ops import MorphReconstructOp
from repro.solve import CostModel, clear_autotune_cache, solve

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                               # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# compact_mask: the scan-compaction primitive.
# ---------------------------------------------------------------------------

def _compact(mask, capacity):
    q, count, overflow = compact_mask(jnp.asarray(mask), capacity)
    return np.asarray(q), int(count), bool(overflow)


def test_compact_empty_mask():
    q, count, overflow = _compact(np.zeros((4, 6), bool), 8)
    assert count == 0 and not overflow
    assert (q == -1).all()


def test_compact_single_pixel():
    m = np.zeros((4, 6), bool)
    m[2, 3] = True
    q, count, overflow = _compact(m, 8)
    assert count == 1 and not overflow
    assert q[0] == 2 * 6 + 3
    assert (q[1:] == -1).all()


def test_compact_all_active_block():
    m = np.ones((3, 5), bool)
    q, count, overflow = _compact(m, 15)
    assert count == 15 and not overflow
    np.testing.assert_array_equal(q, np.arange(15))


def test_compact_exact_capacity_boundary():
    """count == capacity packs everything with no overflow — off-by-one
    here would either drop the last index or spill a fitting round."""
    m = np.zeros((4, 4), bool)
    m.reshape(-1)[[1, 5, 7, 11]] = True
    q, count, overflow = _compact(m, 4)
    assert count == 4 and not overflow
    np.testing.assert_array_equal(q, [1, 5, 7, 11])


def test_compact_overflow_reports_and_keeps_raster_prefix():
    m = np.ones((4, 4), bool)
    q, count, overflow = _compact(m, 5)
    assert count == 16 and overflow
    # first `capacity` indices in raster order; none dropped mid-queue
    np.testing.assert_array_equal(q, np.arange(5))


def test_compact_is_idempotent_on_duplicates():
    """The queue is index-compaction of a *set* (a boolean mask): enqueuing
    the same pixel 'twice' (mask | mask) is the identity, so a duplicate
    candidate can never occupy two slots."""
    rng = np.random.default_rng(7)
    m = rng.random((6, 6)) < 0.4
    q1, c1, o1 = _compact(m, 12)
    q2, c2, o2 = _compact(m | m, 12)
    np.testing.assert_array_equal(q1, q2)
    assert (c1, o1) == (c2, o2)
    assert len(set(q1[q1 >= 0])) == (q1 >= 0).sum()   # slots are distinct


def test_dilate_marks_neighbors():
    m = np.zeros((5, 5), bool)
    m[2, 2] = True
    d8 = np.asarray(dilate(jnp.asarray(m), offsets_for(8)))
    assert d8.sum() == 8 and not d8[2, 2]             # ring, not the center
    d4 = np.asarray(dilate(jnp.asarray(m), offsets_for(4)))
    assert d4.sum() == 4


def test_default_capacity_is_band_sized():
    assert default_kernel_queue_capacity(10) == 64        # floor
    assert default_kernel_queue_capacity(130) == 130      # wavefront band ~ T+2
    assert default_kernel_queue_capacity(4) == 16         # capped at block


# ---------------------------------------------------------------------------
# Queued vs dense tile solvers.
# ---------------------------------------------------------------------------

def _morph_block(h, w, seed, density=0.8):
    marker, mask = tissue_image(h, w, density, seed)
    J = jnp.asarray(np.minimum(marker, mask).astype(np.int32))
    I = jnp.asarray(mask.astype(np.int32))
    rng = np.random.default_rng(seed + 1000)
    valid = jnp.asarray(rng.random((h, w)) < 0.9)
    return J, I, valid


def _assert_morph_equiv(J, I, valid, capacity, conn=8):
    d, di = morph_tile_solve(J, I, valid, connectivity=conn, interpret=True)
    q, qi, spills = morph_tile_solve_queued(
        J, I, valid, connectivity=conn, queue_capacity=capacity,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(q))
    assert int(di) == int(qi)
    return int(qi), int(spills)


@pytest.mark.parametrize("conn", [4, 8])
@pytest.mark.parametrize("capacity", [1, 33, 256])
def test_queued_morph_bit_equals_dense(conn, capacity):
    J, I, valid = _morph_block(34, 34, seed=conn)
    _assert_morph_equiv(J, I, valid, capacity, conn)


def test_overflow_spill_path_never_drops_work():
    """capacity=1 forces dense spills whenever a round improves more than
    one pixel: results and round counts still match the dense kernel
    exactly, and the spill counter reports the fallbacks."""
    J, I, valid = _morph_block(34, 34, seed=5)
    iters, spills = _assert_morph_equiv(J, I, valid, capacity=1)
    assert 1 <= spills <= iters - 1   # spills exercised; round 1 never spills

    # generous capacity: same fixed point, and queued rounds dominate.  The
    # queue count is per-*contribution* (duplicate targets included — a
    # conservative overflow trigger), so a handful of early wide rounds may
    # still spill even at 8·n slots; every spill is just a dense round.
    iters2, spills2 = _assert_morph_equiv(J, I, valid, capacity=8 * 34 * 34)
    assert iters2 == iters and spills2 < spills and spills2 <= 2


def test_queued_edt_bit_equals_dense():
    for conn in (4, 8):
        op = EdtOp(connectivity=conn)
        st_ = op.make_state(jnp.asarray(binary_blobs(34, 34, 0.5, seed=3)))
        args = (st_["vr"][0], st_["vr"][1], st_["valid"], st_["row"],
                st_["col"])
        dr, dc, di = edt_tile_solve(*args, connectivity=conn, interpret=True)
        qr, qc, qi, _ = edt_tile_solve_queued(
            *args, connectivity=conn, queue_capacity=48, interpret=True)
        # bit-equal *pointers* (not just distances): the queued round runs
        # the same strict-< offset scan, so even ties resolve identically
        np.testing.assert_array_equal(np.asarray(dr), np.asarray(qr))
        np.testing.assert_array_equal(np.asarray(dc), np.asarray(qc))
        assert int(di) == int(qi)


def test_queued_batched_matches_single():
    blocks = [_morph_block(34, 34, seed=s) for s in range(4)]
    J = jnp.stack([b[0] for b in blocks])
    I = jnp.stack([b[1] for b in blocks])
    valid = jnp.stack([b[2] for b in blocks])
    out, iters, spills = morph_tile_solve_queued_batched(
        J, I, valid, connectivity=8, queue_capacity=48, interpret=True)
    assert iters.shape == (4,) and spills.shape == (4,)
    for k, (Jk, Ik, vk) in enumerate(blocks):
        ref, ri, _ = morph_tile_solve_queued(
            Jk, Ik, vk, connectivity=8, queue_capacity=48, interpret=True)
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(ref))
        assert int(iters[k]) == int(ri)


def test_serpentine_rounds_parity():
    """The property behind the CI rounds-guard (bench_queue_variants):
    queued rounds-to-converge on the serpentine corridor never exceed the
    dense kernel's — a silently dropped enqueue would stall the wavefront
    and break the equality."""
    from test_truncation import serpentine_case, _as_block
    marker, mask, expected = serpentine_case(32)
    J, I, valid = _as_block(marker, mask)
    d, di = morph_tile_solve(J, I, valid, connectivity=8, max_iters=34 ** 2,
                             interpret=True)
    q, qi, _ = morph_tile_solve_queued(J, I, valid, connectivity=8,
                                       max_iters=34 ** 2, queue_capacity=64,
                                       interpret=True)
    np.testing.assert_array_equal(
        np.asarray(q)[1:-1, 1:-1], expected)
    assert int(qi) <= int(di)


# ---------------------------------------------------------------------------
# Hypothesis property tests (skipped without the dependency; the seeded
# sweeps above keep the invariant pinned either way).
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=15, deadline=None)

    @st.composite
    def masked_block(draw, max_side=20):
        h = draw(st.integers(4, max_side))
        w = draw(st.integers(4, max_side))
        seed = draw(st.integers(0, 2**31 - 1))
        capacity = draw(st.integers(1, h * w + 8))
        rng = np.random.default_rng(seed)
        valid = rng.random((h, w)) < draw(st.floats(0.3, 1.0))
        return h, w, seed, capacity, valid

    @given(masked_block())
    @settings(**SETTINGS)
    def test_property_queued_morph_equals_dense(case):
        h, w, seed, capacity, valid = case
        rng = np.random.default_rng(seed)
        mask = rng.integers(0, 200, (h, w)).astype(np.int32)
        marker = np.where(rng.random((h, w)) < 0.1, mask, 0).astype(np.int32)
        _assert_morph_equiv(jnp.asarray(marker), jnp.asarray(mask),
                            jnp.asarray(valid), capacity)

    @given(masked_block())
    @settings(**SETTINGS)
    def test_property_queued_label_equals_dense(case):
        """Label = the morph kernel parametrized (I = fg ? CAP : 0): the
        queued variant must agree under that parametrization too."""
        from repro.label.ops import LABEL_CAP
        h, w, seed, capacity, valid = case
        rng = np.random.default_rng(seed)
        fg = rng.random((h, w)) < 0.55
        I = np.where(fg, LABEL_CAP, 0).astype(np.int32)
        lab = np.where(fg, np.arange(1, h * w + 1).reshape(h, w), 0)
        _assert_morph_equiv(jnp.asarray(lab.astype(np.int32)),
                            jnp.asarray(I), jnp.asarray(valid), capacity)

    @given(masked_block())
    @settings(**SETTINGS)
    def test_property_queued_edt_distance_equals_dense(case):
        h, w, seed, capacity, valid = case
        rng = np.random.default_rng(seed)
        fg = rng.random((h, w)) < 0.5
        op = EdtOp(connectivity=8)
        st_ = op.make_state(jnp.asarray(fg), jnp.asarray(valid))
        args = (st_["vr"][0], st_["vr"][1], st_["valid"], st_["row"],
                st_["col"])
        dr, dc, di = edt_tile_solve(*args, connectivity=8, interpret=True)
        qr, qc, qi, _ = edt_tile_solve_queued(
            *args, connectivity=8, queue_capacity=capacity, interpret=True)

        def d2(rr, cc):
            return ((np.asarray(st_["row"]) - np.asarray(rr)) ** 2
                    + (np.asarray(st_["col"]) - np.asarray(cc)) ** 2)

        np.testing.assert_array_equal(d2(dr, dc), d2(qr, qc))
        assert int(di) == int(qi)


# ---------------------------------------------------------------------------
# solve()-level plumbing.
# ---------------------------------------------------------------------------

def _morph_case(shape=(40, 44), seed=0):
    rng = np.random.default_rng(seed)
    mask = rng.integers(0, 200, shape).astype(np.int32)
    marker = np.where(rng.random(shape) < 0.02, mask, 0).astype(np.int32)
    op = MorphReconstructOp(connectivity=8)
    return op, op.make_state(jnp.asarray(marker), jnp.asarray(mask))


def test_solve_kernel_queue_stats_echo_resolved_knobs():
    op, state = _morph_case()
    dense, ds = solve(op, state, engine="tiled-pallas", tile=16)
    assert ds.kernel_queue is False and ds.kernel_queue_capacity is None
    out, st_ = solve(op, state, engine="tiled-pallas", tile=16,
                     kernel_queue=True)
    assert st_.kernel_queue is True
    assert st_.kernel_queue_capacity == default_kernel_queue_capacity(18)
    np.testing.assert_array_equal(np.asarray(out["J"]),
                                  np.asarray(dense["J"]))
    assert st_.rounds == ds.rounds and st_.tiles_processed == ds.tiles_processed


def test_kernel_queue_knob_rejected_off_pallas():
    op, state = _morph_case()
    with pytest.raises(ValueError, match="tiled-pallas"):
        solve(op, state, engine="tiled", kernel_queue=True)
    with pytest.raises(ValueError, match="tiled-pallas"):
        solve(op, state, engine="frontier", kernel_queue_capacity=32)


def test_cost_model_candidates_include_queued_variant():
    op, state = _morph_case()
    stats = solve_mod.collect_input_stats(op, state)
    cands = CostModel().candidates(stats)
    queued = [c for c in cands if c.kernel_queue]
    assert queued and all(c.engine == "tiled-pallas" for c in queued)
    dense = [c for c in cands
             if c.engine == "tiled-pallas" and not c.kernel_queue]
    assert len(dense) == len(queued)    # both variants compete per tile


def test_auto_kernel_queue_restricts_candidates():
    op, state = _morph_case(shape=(24, 24))
    out, st_ = solve(op, state, engine="auto", tile=8, kernel_queue=True)
    assert st_.engine != "tiled-pallas" or st_.kernel_queue
    ref, _ = solve(op, state, engine="frontier")
    np.testing.assert_array_equal(np.asarray(out["J"]), np.asarray(ref["J"]))


# ---------------------------------------------------------------------------
# Autotune-failure invalidation (ISSUE 6 satellite): a broken queued-kernel
# candidate recorded in _AUTOTUNE_FAILURES/_AUTOTUNE_CACHE must be retried
# once its spec is fixed — on_spec_change purges both caches.
# ---------------------------------------------------------------------------

def test_autotune_retries_after_failed_candidate_is_fixed():
    class _RetryOp(MorphReconstructOp):
        pass

    morph_spec = solve_mod.spec_for(MorphReconstructOp(connectivity=8))

    def broken(op, interpret, max_iters):
        raise RuntimeError("injected kernel failure")

    solve_mod.register_pallas_solver(_RetryOp, broken, broken)
    op = _RetryOp(connectivity=8)
    _, state = _morph_case(shape=(24, 24), seed=3)

    # Force the broken tiled-pallas candidate into the measured set: rank
    # it alone so the autotune loop must try (and fail) it.
    cands = [solve_mod.EngineConfig("tiled-pallas", 8, 16, 1),
             solve_mod.EngineConfig("frontier")]
    stats = solve_mod.collect_input_stats(op, state)
    model = CostModel()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        cfg = solve_mod._autotune(op, state, stats, model, cands, (), 2, 1,
                                  max_rounds=1000)
    sig = solve_mod.autotune_signature(op, stats, ())
    assert sig in solve_mod._AUTOTUNE_FAILURES      # the failure was recorded
    assert cfg.engine == "frontier"                  # winner = the survivor

    # Fix the spec: the change hook must purge the poisoned entries ...
    solve_mod.register_pallas_solver(_RetryOp, morph_spec.pallas_solver,
                                     morph_spec.pallas_batch_solver)
    assert sig not in solve_mod._AUTOTUNE_FAILURES
    assert sig not in solve_mod._AUTOTUNE_CACHE

    # ... so a re-autotune measures the fixed candidate cleanly.
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        cfg2 = solve_mod._autotune(op, state, stats, model, cands, (), 2, 1,
                                   max_rounds=1000)
    assert sig in solve_mod._AUTOTUNE_CACHE
    assert sig not in solve_mod._AUTOTUNE_FAILURES
    assert cfg2 in cands


def test_clear_autotune_cache_still_clears_everything():
    clear_autotune_cache()
    assert not solve_mod._AUTOTUNE_CACHE and not solve_mod._AUTOTUNE_FAILURES
