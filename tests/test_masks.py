"""Non-rectangular `valid`-mask regressions.

Two historical bugs motivate these tests: the morph Pallas kernel accepted
``valid`` but never read it (invalid in-block pixels could source/receive
propagation), and the host scheduler's halo slices filled out-of-array
cells with dtype-min instead of the op's neutral pad values (wrong for
EDT's coordinate planes).  Every engine must now agree with the dense
sequential reference (E1 `frontier`) on the valid region, with the invalid
region deliberately *poisoned* with values that would leak if any path
read them as propagation sources.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.frontier import run_dense
from repro.core.scheduler import TileScheduler
from repro.data.images import bg_disks, seeded_marker, tissue_image
from repro.edt.ops import EdtOp, distance_map
from repro.edt.ref import SENTINEL
from repro.kernels.morph_tile import morph_tile_solve
from repro.morph.ops import MorphReconstructOp
from repro.solve import ENGINES, solve

MASK_ENGINES = [e for e in ENGINES if e not in ("auto", "frontier")]
ENGINE_KW = dict(tile=16, queue_capacity=8, n_workers=2)


def _disk_valid(H, W):
    yy, xx = np.mgrid[:H, :W]
    return ((yy - H / 2) ** 2 + (xx - W / 2) ** 2) < (0.45 * max(H, W)) ** 2


@pytest.fixture(scope="module")
def morph_masked_case():
    H, W = 49, 57
    valid = _disk_valid(H, W)
    _, mask = tissue_image(H, W, coverage=0.8, seed=3)
    marker = seeded_marker(mask, n_seeds=4, seed=3)
    # Poison the invalid region with maximal values: any engine that lets an
    # invalid pixel source propagation will visibly corrupt the valid region.
    marker = np.where(valid, marker, 255).astype(np.int32)
    mask = np.where(valid, mask, 255).astype(np.int32)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker), jnp.asarray(mask),
                          jnp.asarray(valid))
    ref_out, _ = run_dense(op, state, "frontier")
    ref = np.where(valid, np.asarray(ref_out["J"]), 0)
    return op, state, valid, ref


@pytest.fixture(scope="module")
def edt_masked_case():
    H, W = 49, 57
    valid = _disk_valid(H, W)
    # Background pixels outside the valid region must offer no distance-0
    # sites; with the mask applied, the only background sources are in-disk.
    fg = bg_disks(H, W, coverage=0.9, n_disks=2, seed=4)
    op = EdtOp(connectivity=8)
    state = op.make_state(jnp.asarray(fg), jnp.asarray(valid))
    ref_out, _ = run_dense(op, state, "frontier")
    ref = np.where(valid, np.asarray(distance_map(ref_out)), 0)
    return op, state, valid, ref


@pytest.mark.parametrize("engine", MASK_ENGINES)
def test_masked_morph_every_engine(morph_masked_case, engine):
    op, state, valid, ref = morph_masked_case
    out, _ = solve(op, state, engine=engine, **ENGINE_KW)
    got = np.where(valid, np.asarray(out["J"]), 0)
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("engine", MASK_ENGINES)
def test_masked_edt_every_engine(edt_masked_case, engine):
    op, state, valid, ref = edt_masked_case
    out, _ = solve(op, state, engine=engine, **ENGINE_KW)
    got = np.where(valid, np.asarray(distance_map(out)), 0)
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# Invalid-pixel output contract: engine outputs are bit-comparable over the
# WHOLE array, not just the valid region.  Historically the dense rounds
# could grow an invalid *receiver* one step toward the mask while the Pallas
# writeback pinned invalid interiors to dtype-min/sentinel — three different
# leftovers for the same input.  The contract (enforced by every engine via
# `pattern.restore_invalid`): invalid cells hold their INPUT values.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", MASK_ENGINES)
def test_invalid_pixel_contract_morph(morph_masked_case, engine):
    op, state, valid, _ = morph_masked_case
    ref_out, _ = run_dense(op, state, "frontier")
    out, _ = solve(op, state, engine=engine, **ENGINE_KW)
    # invalid cells hold the (poisoned) input values, bit-for-bit...
    np.testing.assert_array_equal(np.asarray(out["J"])[~valid],
                                  np.asarray(state["J"])[~valid])
    # ...so the full array equals the E1 reference output, no masking needed
    np.testing.assert_array_equal(np.asarray(out["J"]),
                                  np.asarray(ref_out["J"]))


@pytest.mark.parametrize("engine", MASK_ENGINES)
def test_invalid_pixel_contract_edt(edt_masked_case, engine):
    op, state, valid, _ = edt_masked_case
    ref_out, _ = run_dense(op, state, "frontier")
    out, _ = solve(op, state, engine=engine, **ENGINE_KW)
    np.testing.assert_array_equal(np.asarray(out["vr"])[:, ~valid],
                                  np.asarray(state["vr"])[:, ~valid])
    # distances are unique at the fixed point (pointers may tie-break
    # differently), so the distance map is the full-array comparison
    np.testing.assert_array_equal(np.asarray(distance_map(out)),
                                  np.asarray(distance_map(ref_out)))


def test_morph_kernel_invalid_pixels_cannot_source():
    """Direct kernel regression: an invalid pixel holding the dtype max must
    not dilate into its valid neighbors (the kernel used to ignore valid)."""
    Hp = Wp = 18
    J = jnp.zeros((Hp, Wp), jnp.int32)
    I = jnp.full((Hp, Wp), 100, jnp.int32)
    valid = jnp.ones((Hp, Wp), bool)
    J = J.at[8, 8].set(2**20)          # poisoned pixel...
    valid = valid.at[8, 8].set(False)  # ...that is not part of the domain
    out, _ = morph_tile_solve(J, I, valid, connectivity=8, interpret=True)
    out = np.asarray(out)
    vm = np.asarray(valid)
    assert (out[vm] == 0).all()        # nothing to propagate: all-zero marker
    assert out[8, 8] == np.iinfo(np.int32).min  # pinned to neutral


def test_scheduler_slice_block_uses_op_pad_values():
    """Out-of-array halo cells must hold the op's neutral fills, not
    dtype-min — EDT's coordinate planes need the far sentinel."""
    op = EdtOp(connectivity=8)
    state = op.make_state(jnp.asarray(np.ones((8, 8), bool)))
    np_state = {k: np.array(v) for k, v in state.items()}
    pad_values = {k: np.asarray(v).item() for k, v in op.pad_value(state).items()}
    sched = TileScheduler(np_state, 8, lambda b: (b, None),
                          np.ones((1, 1), bool), n_workers=1,
                          mutable=("vr",), pad_values=pad_values)
    blk = sched._slice_block((0, 0))
    assert blk["row"][0, 0] == SENTINEL      # not iinfo(int32).min
    assert blk["col"][0, 0] == SENTINEL
    assert (blk["vr"][:, 0, 0] == SENTINEL).all()
    assert not blk["valid"][0, 0]
    # and without pad_values the legacy dtype-min fallback still applies
    legacy = TileScheduler({"J": np.zeros((8, 8), np.int32)}, 8,
                           lambda b: (b, None), np.ones((1, 1), bool),
                           n_workers=1)
    assert legacy._slice_block((0, 0))["J"][0, 0] == np.iinfo(np.int32).min
