"""Hypothesis property tests for the system's invariants.

The IWPP contract (paper §3.1): updates are commutative + monotone, so any
processing order / tiling / schedule reaches the same fixed point.  These
tests generate adversarial small images and check the invariants the
engines rely on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install via requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core.frontier import run_dense
from repro.core.tiles import run_tiled
from repro.distributed.compression import compress, decompress
from repro.edt.ops import EdtOp, distance_map
from repro.edt.ref import edt_wavefront
from repro.morph.ops import MorphReconstructOp, _clamp_compose
from repro.morph.ref import reconstruct_fh

SETTINGS = dict(max_examples=20, deadline=None)


@st.composite
def image_pair(draw, max_h=24, max_w=24):
    h = draw(st.integers(4, max_h))
    w = draw(st.integers(4, max_w))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    mask = rng.integers(0, 256, (h, w), dtype=np.int32)
    marker = np.minimum(rng.integers(0, 256, (h, w), dtype=np.int32), mask)
    return marker, mask


@given(image_pair())
@settings(**SETTINGS)
def test_morph_fixed_point_unique_across_engines(pair):
    marker, mask = pair
    ref = reconstruct_fh(marker.copy(), mask, 8)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker), jnp.asarray(mask))
    dense_out, _ = run_dense(op, state, "frontier")
    tiled_out, _ = run_tiled(op, state, tile=8, queue_capacity=4)
    np.testing.assert_array_equal(np.asarray(dense_out["J"]), ref)
    np.testing.assert_array_equal(np.asarray(tiled_out["J"]), ref)


@given(image_pair())
@settings(**SETTINGS)
def test_morph_bounds_and_idempotence(pair):
    marker, mask = pair
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker), jnp.asarray(mask))
    out, _ = run_dense(op, state, "frontier")
    J = np.asarray(out["J"])
    assert (J >= np.minimum(marker, mask)).all()    # monotone: only grows
    assert (J <= mask).all()                        # clamped by the mask
    # idempotence: a second run changes nothing and does zero rounds
    out2, stats2 = run_dense(op, dict(out), "frontier")
    np.testing.assert_array_equal(np.asarray(out2["J"]), J)
    assert int(stats2.rounds) == 0


@given(st.integers(0, 2**31 - 1), st.integers(4, 24), st.integers(4, 24))
@settings(**SETTINGS)
def test_edt_lipschitz_and_zero_background(seed, h, w):
    rng = np.random.default_rng(seed)
    fg = rng.random((h, w)) < 0.6
    op = EdtOp(connectivity=8)
    out, _ = run_dense(op, op.make_state(jnp.asarray(fg)), "frontier")
    M = np.sqrt(np.asarray(distance_map(out)).astype(np.float64))
    if (~fg).any():
        assert (M[~fg] == 0).all()                  # background distance 0
        # neighbor Lipschitz: |d(p) - d(q)| <= sqrt(2) for 8-neighbors
        for dr, dc in ((0, 1), (1, 0), (1, 1), (1, -1)):
            a = M[max(0, -dr):h - max(0, dr), max(0, -dc):w - max(0, dc)]
            b = M[max(0, dr):h - max(0, -dr), max(0, dc):w - max(0, -dc)]
            assert (np.abs(a - b) <= np.sqrt(2) + 1e-9).all()
        ref_M, _ = edt_wavefront(fg, 8)
        np.testing.assert_array_equal(np.asarray(distance_map(out)), ref_M)


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_clamp_compose_is_associative(seed):
    """The FH directional scan relies on clamp composition associativity."""
    rng = np.random.default_rng(seed)
    trips = [tuple(jnp.asarray(rng.normal(size=7).astype(np.float32))
                   for _ in range(2)) for _ in range(3)]
    f, g, h = trips
    left = _clamp_compose(_clamp_compose(f, g), h)
    right = _clamp_compose(f, _clamp_compose(g, h))
    for l, r in zip(left, right):
        np.testing.assert_allclose(np.asarray(l), np.asarray(r), rtol=1e-6)
    # and it encodes function application: apply composed == apply seq
    x = jnp.asarray(rng.normal(size=7).astype(np.float32))
    seq = x
    for A, B in trips:
        seq = jnp.minimum(B, jnp.maximum(A, seq))
    A, B = _clamp_compose(_clamp_compose(f, g), h)
    np.testing.assert_allclose(np.asarray(jnp.minimum(B, jnp.maximum(A, x))),
                               np.asarray(seq), rtol=1e-6)


@given(st.integers(0, 2**31 - 1), st.integers(1, 2048))
@settings(**SETTINGS)
def test_compression_error_bound(seed, n):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=n).astype(np.float32) * 10)
    ef = jnp.zeros_like(g)
    q, scale, new_ef = compress(g, ef)
    err = np.abs(np.asarray(decompress(q, scale) - g))
    assert err.max() <= float(scale) / 2 + 1e-6
    np.testing.assert_allclose(np.asarray(decompress(q, scale) + new_ef),
                               np.asarray(g), rtol=1e-5, atol=1e-5)


@st.composite
def image_pair_with_mask(draw, max_h=24, max_w=24):
    marker, mask = draw(image_pair(max_h, max_w))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    valid = rng.random(mask.shape) < 0.85    # non-rectangular validity
    return marker, mask, valid


@given(image_pair_with_mask(), st.integers(2, 6))
@settings(**SETTINGS)
def test_batched_drain_equals_sequential_morph(case, drain_batch):
    """The paper's parallel queue consumption: draining the compacted queue
    in concurrent batches reaches bit-for-bit the sequential scan's fixed
    point (monotone commutative updates; disjoint interior writes)."""
    marker, mask, valid = case
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker), jnp.asarray(mask),
                          jnp.asarray(valid))
    seq, _ = run_tiled(op, state, tile=8, queue_capacity=8, drain_batch=1)
    bat, _ = run_tiled(op, state, tile=8, queue_capacity=8,
                       drain_batch=drain_batch)
    np.testing.assert_array_equal(
        np.where(valid, np.asarray(seq["J"]), 0),
        np.where(valid, np.asarray(bat["J"]), 0))


@given(st.integers(0, 2**31 - 1), st.integers(6, 24), st.integers(6, 24),
       st.integers(2, 6))
@settings(**SETTINGS)
def test_batched_drain_equals_sequential_edt(seed, h, w, drain_batch):
    rng = np.random.default_rng(seed)
    fg = rng.random((h, w)) < 0.6
    op = EdtOp(connectivity=8)
    state = op.make_state(jnp.asarray(fg))
    seq, _ = run_tiled(op, state, tile=8, queue_capacity=8, drain_batch=1)
    bat, _ = run_tiled(op, state, tile=8, queue_capacity=8,
                       drain_batch=drain_batch)
    # distances are unique at the fixed point (Voronoi ties may differ)
    np.testing.assert_array_equal(np.asarray(distance_map(seq)),
                                  np.asarray(distance_map(bat)))

