"""Regression: silent partial-drain truncation (the ISSUE 3 bugfix).

`run_tiled` justifies dequeuing a tile by its (T+2)² geodesic bound — the
longest propagation path inside one halo block.  But the `tiled-pallas`
adapters used the kernels' default ``max_iters=1024``, which is *below*
that bound for any tile >= 32, and the kernels' ``iters`` output (the one
signal that would reveal the cutoff) was dropped.  A serpentine-corridor
mask whose internal geodesic exceeds 1024 therefore came back unconverged,
was dequeued without a self-requeue, and the engine reported a wrong fixed
point with no error.

Two halves of the fix, each pinned here:
  * the engine's (T+2)² bound is threaded into the kernels
    (`solve._pallas_solver_for` -> `kernels.ops.tile_solver_*(max_iters)`);
  * solvers report ``iters >= max_iters`` as an ``unconverged`` flag and
    `run_tiled` self-requeues the tile, so even an artificially starved
    bound converges to the exact fixed point (just in more drains).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.frontier import run_dense
from repro.core.tiles import _tile_local_solve, run_tiled
from repro.data.images import binary_blobs
from repro.edt.ops import EdtOp, distance_map
from repro.edt.ref import edt_wavefront
from repro.kernels.morph_tile import morph_tile_solve
from repro.kernels.ops import (tile_solver_edt, tile_solver_morph,
                               tile_solver_morph_batched)
from repro.morph.ops import MorphReconstructOp
from repro.solve import solve

LEVEL = 100


def serpentine_case(n: int):
    """A 1-px serpentine corridor with 1-px walls: corridor rows connected
    alternately at the right/left ends.  The geodesic from the seed at
    (0, 0) to the corridor's far end is ~n²/2 pixels — for n=64 that is
    ~2100, past the kernels' old 1024 default but inside (T+2)² = 4356."""
    corridor = np.zeros((n, n), bool)
    corridor[0::2, :] = True
    for i, r in enumerate(range(1, n - 1, 2)):
        corridor[r, (n - 1) if i % 2 == 0 else 0] = True
    mask = np.where(corridor, LEVEL, 0).astype(np.int32)
    marker = np.zeros((n, n), np.int32)
    marker[0, 0] = LEVEL
    # Reconstruction-by-dilation fixed point in closed form: the marker
    # floods the whole connected corridor; walls stay clamped at I=0.
    expected = np.where(corridor, LEVEL, 0).astype(np.int32)
    return marker, mask, expected


def _as_block(marker, mask):
    """(T, T) image -> (T+2, T+2) halo block with neutral halo ring."""
    neut = np.iinfo(np.int32).min
    J = jnp.asarray(np.pad(np.minimum(marker, mask), 1, constant_values=neut))
    I = jnp.asarray(np.pad(mask, 1, constant_values=neut))
    valid = jnp.asarray(np.pad(np.ones(mask.shape, bool), 1))
    return J, I, valid


def test_kernel_default_bound_truncates_serpentine():
    """The pre-fix behavior, pinned: at the kernel-default max_iters=1024
    the drain is cut off (iters == 1024) and the result is NOT the fixed
    point; at the engine's (T+2)² bound it converges exactly."""
    marker, mask, expected = serpentine_case(64)
    J, I, valid = _as_block(marker, mask)
    inner = (slice(1, -1), slice(1, -1))

    out, iters = morph_tile_solve(J, I, valid, connectivity=8,
                                  max_iters=1024, interpret=True)
    assert int(iters) == 1024                      # cut off at the bound...
    truncated = np.asarray(out)[inner]
    assert (truncated != expected).any()           # ...and visibly partial

    out, iters = morph_tile_solve(J, I, valid, connectivity=8,
                                  max_iters=66 ** 2, interpret=True)
    assert int(iters) < 66 ** 2                    # genuine convergence
    np.testing.assert_array_equal(np.asarray(out)[inner], expected)


def test_tiled_pallas_serpentine_matches_ref():
    """The engine-level regression (failed before the fix): one tile=64
    drain over the serpentine, dispatched through solve()."""
    marker, mask, expected = serpentine_case(64)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker), jnp.asarray(mask))
    ref, _ = run_dense(op, state, "frontier")
    np.testing.assert_array_equal(np.asarray(ref["J"]), expected)  # sanity
    out, stats = solve(op, state, engine="tiled-pallas", tile=64,
                       queue_capacity=4)
    np.testing.assert_array_equal(np.asarray(out["J"]), expected)


@pytest.mark.parametrize("drain_batch", [1, 2])
def test_starved_pallas_bound_requeues_until_exact(drain_batch):
    """An artificially low max_iters must only cost extra drains, never
    correctness: the unconverged flag self-requeues the tile."""
    marker, mask, expected = serpentine_case(32)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker), jnp.asarray(mask))
    out, stats = run_tiled(
        op, state, tile=32, queue_capacity=4, drain_batch=drain_batch,
        tile_solver=tile_solver_morph(8, interpret=True, max_iters=64),
        batched_tile_solver=(tile_solver_morph_batched(8, interpret=True,
                                                       max_iters=64)
                             if drain_batch > 1 else None))
    np.testing.assert_array_equal(np.asarray(out["J"]), expected)
    assert int(stats.tiles_requeued) > 0           # the requeue path fired


def test_starved_plain_solver_requeues_until_exact():
    """Same property for the plain (non-Pallas) tile solver."""
    marker, mask, expected = serpentine_case(32)
    op = MorphReconstructOp(connectivity=8)
    state = op.make_state(jnp.asarray(marker), jnp.asarray(mask))
    out, stats = run_tiled(
        op, state, tile=32, queue_capacity=4,
        tile_solver=lambda blk: _tile_local_solve(op, blk, max_iters=16))
    np.testing.assert_array_equal(np.asarray(out["J"]), expected)
    assert int(stats.tiles_requeued) > 0


def test_starved_edt_bound_requeues_until_exact():
    """EDT: Voronoi pointers crawl one neighbor per iteration, so a starved
    bound truncates long-range pointer propagation the same way."""
    fg = binary_blobs(48, 48, 0.97, seed=7)       # sparse background: long waves
    ref_M, _ = edt_wavefront(fg, 8)
    op = EdtOp(connectivity=8)
    state = op.make_state(jnp.asarray(fg))
    out, stats = run_tiled(
        op, state, tile=16, queue_capacity=16,
        tile_solver=tile_solver_edt(8, interpret=True, max_iters=2))
    np.testing.assert_array_equal(np.asarray(distance_map(out)), ref_M)
    assert int(stats.tiles_requeued) > 0
