"""Docs cross-reference check: every `DESIGN.md §x` citation and every
`docs/ENGINES.md` reference found in the tree must resolve to a real
heading/file, so code comments and docs cannot silently drift apart.

Scope: all .py and .md files under src/, tests/, benchmarks/, examples/,
docs/ plus the top-level .md files.  Only references that *name the
document* are checked (`DESIGN.md §2.3`, `docs/ENGINES.md#anchor`);
bare `§4` citations refer to the source paper and are left alone.
"""

import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "docs")
# DESIGN.md §2.3 / DESIGN.md §2.1/§2.3 (slash-chained citations)
_DESIGN_REF = re.compile(r"DESIGN\.md\s+§(\d+(?:\.\d+)*(?:/§\d+(?:\.\d+)*)*)")
_DESIGN_HEADING = re.compile(r"^#{1,6}\s+§(\d+(?:\.\d+)*)\b", re.M)
# markdown headings also allow a literal-section prefix, e.g. "## §BENCH ..."
_ENGINES_ANCHOR_REF = re.compile(r"docs/ENGINES\.md#([A-Za-z0-9\-_]+)")
_ENGINES_FILE_REF = re.compile(r"docs/ENGINES\.md")
_OPS_ANCHOR_REF = re.compile(r"docs/OPS\.md#([A-Za-z0-9\-_]+)")
_OPS_FILE_REF = re.compile(r"docs/OPS\.md")
_SERVING_ANCHOR_REF = re.compile(r"docs/SERVING\.md#([A-Za-z0-9\-_]+)")
_SERVING_FILE_REF = re.compile(r"docs/SERVING\.md")


def _scan_files():
    self_path = os.path.abspath(__file__)
    for d in _SCAN_DIRS:
        for dirpath, _, names in os.walk(os.path.join(ROOT, d)):
            for n in names:
                path = os.path.join(dirpath, n)
                # skip this checker itself: its docstrings hold pattern
                # examples, not real references
                if n.endswith((".py", ".md")) and path != self_path:
                    yield path
    for n in os.listdir(ROOT):
        if n.endswith(".md"):
            yield os.path.join(ROOT, n)


def _read(path):
    with open(path, encoding="utf-8") as f:
        return f.read()


def _github_anchor(heading: str) -> str:
    """GitHub-style markdown anchor slug for a heading line."""
    text = heading.strip().lstrip("#").strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def test_design_section_citations_resolve():
    design = _read(os.path.join(ROOT, "DESIGN.md"))
    headings = set(_DESIGN_HEADING.findall(design))
    assert headings, "DESIGN.md has no §-numbered headings?"
    missing = []
    for path in _scan_files():
        if path.endswith("DESIGN.md"):
            continue
        for m in _DESIGN_REF.finditer(_read(path)):
            for sec in m.group(1).split("/§"):
                if sec not in headings:
                    missing.append((os.path.relpath(path, ROOT), sec))
    assert not missing, (
        f"citations of nonexistent DESIGN.md sections: {missing}; "
        f"existing sections: {sorted(headings)}")


def _check_doc_references(filename, file_ref, anchor_ref):
    """Shared checker: docs/<filename> exists, something links to it, and
    every `docs/<filename>#anchor` reference in the tree resolves."""
    doc_path = os.path.join(ROOT, "docs", filename)
    assert os.path.exists(doc_path), f"docs/{filename} is missing"
    anchors = {_github_anchor(line)
               for line in _read(doc_path).splitlines()
               if line.startswith("#")}
    referenced = False
    missing = []
    for path in _scan_files():
        if os.path.samefile(path, doc_path):
            continue
        text = _read(path)
        if file_ref.search(text):
            referenced = True
        for m in anchor_ref.finditer(text):
            if m.group(1).lower() not in anchors:
                missing.append((os.path.relpath(path, ROOT), m.group(1)))
    assert referenced, f"nothing links to docs/{filename} (README should)"
    assert not missing, (
        f"references to nonexistent docs/{filename} anchors: {missing}; "
        f"existing anchors: {sorted(anchors)}")


def test_engines_md_references_resolve():
    _check_doc_references("ENGINES.md", _ENGINES_FILE_REF, _ENGINES_ANCHOR_REF)


def test_ops_md_references_resolve():
    _check_doc_references("OPS.md", _OPS_FILE_REF, _OPS_ANCHOR_REF)


def test_serving_md_references_resolve():
    _check_doc_references("SERVING.md", _SERVING_FILE_REF,
                          _SERVING_ANCHOR_REF)


def test_serving_docs_pinned():
    """The serving layer (ISSUE 10) must stay documented everywhere it is
    user-visible: DESIGN.md §2.9 exists and describes the coalescing /
    caching / admission design, docs/SERVING.md covers the API and the SLO
    metric definitions, EXPERIMENTS.md carries the batched-vs-serialized
    table, README carries the serving quickstart."""
    design = _read(os.path.join(ROOT, "DESIGN.md"))
    m = re.search(r"^###\s+§2\.9\b.*$", design, re.M)
    assert m and "serving" in m.group(0).lower(), \
        "DESIGN.md lacks the §2.9 serving layer section"
    sec = design[m.start():]
    for term in ("solve_batch", "coalesc", "single-flight",
                 "content_fingerprint", "pad-to-bucket", "retry_after_s",
                 "BATCHABLE_ENGINES"):
        assert term in sec, f"DESIGN.md §2.9 no longer mentions {term!r}"
    serving = _read(os.path.join(ROOT, "docs", "SERVING.md"))
    for term in ("IwppService", "submit", "max_queue_depth",
                 "max_inflight_per_tenant", "bucket_multiple",
                 "cache_hit_rate", "latency_p99_s", "Rejected"):
        assert term in serving, f"docs/SERVING.md no longer mentions {term!r}"
    experiments = _read(os.path.join(ROOT, "EXPERIMENTS.md"))
    assert "speedup_vs_serial" in experiments, \
        "EXPERIMENTS.md lacks the batched-vs-serialized serving table"
    readme = _read(os.path.join(ROOT, "README.md"))
    assert "IwppService" in readme, "README lacks the serving quickstart"


def test_every_engine_has_a_reference_section():
    """docs/ENGINES.md must stay complete: one `## \\`engine\\`` section per
    member of repro.solve.ENGINES."""
    from repro.solve import ENGINES
    text = _read(os.path.join(ROOT, "docs", "ENGINES.md"))
    missing = [e for e in ENGINES
               if not re.search(rf"^##\s+`{re.escape(e)}`", text, re.M)]
    assert not missing, f"docs/ENGINES.md lacks sections for: {missing}"


def test_kernel_queue_docs_pinned():
    """The in-kernel queue (ISSUE 6) must stay documented everywhere it is
    user-visible: DESIGN.md §2.5 exists and describes the push/spill
    design, docs/ENGINES.md documents both solve() knobs, EXPERIMENTS.md
    carries the dense-vs-queued table."""
    design = _read(os.path.join(ROOT, "DESIGN.md"))
    m = re.search(r"^###\s+§2\.5\b.*$", design, re.M)
    assert m and "queue" in m.group(0).lower(), \
        "DESIGN.md lacks the §2.5 in-kernel queue section"
    sec = design[m.start():]
    for term in ("compact_mask", "spill", "push"):
        assert term in sec, f"DESIGN.md §2.5 no longer mentions {term!r}"
    engines = _read(os.path.join(ROOT, "docs", "ENGINES.md"))
    assert "kernel_queue_capacity" in engines and "kernel_queue" in engines, \
        "docs/ENGINES.md lacks the kernel_queue knob rows"
    experiments = _read(os.path.join(ROOT, "EXPERIMENTS.md"))
    assert "speedup_vs_dense" in experiments, \
        "EXPERIMENTS.md lacks the dense-vs-queued kernel table"


def test_runstate_docs_pinned():
    """Persistent round state (ISSUE 7) must stay documented everywhere it
    is user-visible: DESIGN.md §2.6 exists and describes the donated
    carrier + overlap invariants, docs/ENGINES.md documents the
    `recompiles` stats field, EXPERIMENTS.md carries the compose table."""
    design = _read(os.path.join(ROOT, "DESIGN.md"))
    m = re.search(r"^###\s+§2\.6\b.*$", design, re.M)
    assert m and "round state" in m.group(0).lower(), \
        "DESIGN.md lacks the §2.6 persistent round state section"
    sec = design[m.start():]
    for term in ("TiledRunState", "donate", "ppermute", "recompiles",
                 "initial_queue"):
        assert term in sec, f"DESIGN.md §2.6 no longer mentions {term!r}"
    engines = _read(os.path.join(ROOT, "docs", "ENGINES.md"))
    assert "recompiles" in engines, \
        "docs/ENGINES.md lacks the recompiles stats row"
    experiments = _read(os.path.join(ROOT, "EXPERIMENTS.md"))
    assert "speedup_vs_flat" in experiments, \
        "EXPERIMENTS.md lacks the composed-vs-flat table"


def test_geometry_docs_pinned():
    """The N-D geometry layer must stay documented everywhere it is
    user-visible: DESIGN.md §2.7 exists and describes the Neighborhood/
    Geometry contract, the conn26 halo/corner semantics and the
    generalized truncation bound; docs/OPS.md carries the op × ndim
    matrix; docs/ENGINES.md documents the connectivity knob."""
    design = _read(os.path.join(ROOT, "DESIGN.md"))
    m = re.search(r"^###\s+§2\.7\b.*$", design, re.M)
    assert m and "geometry" in m.group(0).lower(), \
        "DESIGN.md lacks the §2.7 N-D geometry section"
    sec = design[m.start():]
    for term in ("Neighborhood", "conn26", "geodesic_bound",
                 "supported_ndims", "order-dependent"):
        assert term in sec, f"DESIGN.md §2.7 no longer mentions {term!r}"
    ops = _read(os.path.join(ROOT, "docs", "OPS.md"))
    assert re.search(r"^##\s+Op\b.*ndim", ops, re.M), \
        "docs/OPS.md lacks the op × ndim support matrix"
    for term in ("conn6", "conn26", "supported_ndims"):
        assert term in ops, f"docs/OPS.md no longer mentions {term!r}"
    engines = _read(os.path.join(ROOT, "docs", "ENGINES.md"))
    assert "connectivity" in engines and "conn26" in engines, \
        "docs/ENGINES.md lacks the connectivity knob rows"


def test_calibration_docs_pinned():
    """Measured cost profiles (ISSUE 9) must stay documented everywhere
    they are user-visible: DESIGN.md §2.8 exists and describes the
    measured curves + cold-start contract, EXPERIMENTS.md carries the
    analytic-vs-calibrated selection scorecard, README carries the
    calibration quickstart."""
    design = _read(os.path.join(ROOT, "DESIGN.md"))
    m = re.search(r"^###\s+§2\.8\b.*$", design, re.M)
    assert m and "cost profile" in m.group(0).lower(), \
        "DESIGN.md lacks the §2.8 measured cost profiles section"
    sec = design[m.start():]
    for term in ("MeasuredCostModel", "run_calibration", "rounds_per_extent",
                 "drain_grid", "batch_factor", "cold-start", "solve_guard",
                 "CALIBRATION.json"):
        assert term in sec, f"DESIGN.md §2.8 no longer mentions {term!r}"
    experiments = _read(os.path.join(ROOT, "EXPERIMENTS.md"))
    assert "calibrated pick" in experiments, \
        "EXPERIMENTS.md lacks the analytic-vs-calibrated selection table"
    readme = _read(os.path.join(ROOT, "README.md"))
    assert "calibrate.py" in readme and "cost_model" in readme, \
        "README lacks the calibration quickstart"


def test_every_op_has_a_catalog_section():
    """docs/OPS.md must stay complete: one `## \\`op\\`` section per
    registered op — a new register_op() without a catalog entry fails
    here, the same pact docs/ENGINES.md has with ENGINES."""
    from repro.ops import list_ops
    text = _read(os.path.join(ROOT, "docs", "OPS.md"))
    missing = [o for o in list_ops()
               if not re.search(rf"^##\s+`{re.escape(o)}`", text, re.M)]
    assert not missing, f"docs/OPS.md lacks sections for: {missing}"
