"""Model-substrate correctness: attention equivalences, recurrent cell
parallel-vs-step equivalence, MoE dispatch vs reference, and the strongest
end-to-end invariant: prefill+decode logits == teacher-forced forward."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeSpec
from repro.configs.registry import ARCHS, input_specs, smoke_config
from repro.models import attention as A
from repro.models import recurrent as R
from repro.models.moe import make_moe, moe_apply, moe_ref
from repro.models.transformer import (decode_step, forward, init_decode_cache,
                                      init_params, logits_from_hidden, prefill)
from repro.configs.base import MoEConfig


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_chunked_matches_full(hq, hkv, window, softcap):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, D = 2, 64, 16
    q = jax.random.normal(k1, (B, S, hq, D), jnp.float32)
    k = jax.random.normal(k2, (B, S, hkv, D), jnp.float32)
    v = jax.random.normal(k3, (B, S, hkv, D), jnp.float32)
    ref = A.full_attention(q, k, v, causal=True, window=window, softcap=softcap)
    out = A.chunked_attention(q, k, v, causal=True, window=window,
                              softcap=softcap, chunk_q=16, chunk_kv=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_full():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, Hq, Hkv, D = 2, 32, 4, 2, 16
    q = jax.random.normal(k1, (B, 1, Hq, D), jnp.float32)
    kc = jax.random.normal(k2, (B, S, Hkv, D), jnp.float32)
    vc = jax.random.normal(k3, (B, S, Hkv, D), jnp.float32)
    # valid length 20: full attention over the first 20 positions
    ref = A.full_attention(q, kc[:, :20], vc[:, :20], causal=False)
    out = A.decode_attention(q, kc, vc, jnp.int32(20))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# recurrent cells: parallel form == sequential step form
# ---------------------------------------------------------------------------

def test_rglru_parallel_equals_steps():
    key = jax.random.PRNGKey(2)
    B, S, D = 2, 24, 8
    p = R.make_rglru(key, D)
    x = jax.random.normal(key, (B, S, D), jnp.float32)
    par = R.rglru_apply(p, x)
    h = jnp.zeros((B, D), jnp.float32)
    outs = []
    for t in range(S):
        y, h = R.rglru_step(p, h, x[:, t])
        outs.append(y)
    seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(par), np.asarray(seq),
                               rtol=1e-5, atol=1e-5)


def test_conv1d_parallel_equals_steps():
    key = jax.random.PRNGKey(3)
    B, S, D, K = 2, 10, 6, 4
    p = R.make_conv1d(key, D, K)
    x = jax.random.normal(key, (B, S, D), jnp.float32)
    par = R.conv1d_causal(p, x)
    win = jnp.zeros((B, K - 1, D), jnp.float32)
    outs = []
    for t in range(S):
        y, win = R.conv1d_step(p, win, x[:, t])
        outs.append(y)
    np.testing.assert_allclose(np.asarray(par), np.asarray(jnp.stack(outs, 1)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_mlstm_chunked_equals_sequential(chunk):
    key = jax.random.PRNGKey(4)
    B, S, H, D = 2, 32, 2, 8
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    ig = jax.random.normal(ks[3], (B, S, H), jnp.float32)
    fg = jax.random.normal(ks[4], (B, S, H), jnp.float32) + 2.0
    ref = R.mlstm_ref(q, k, v, ig, fg)
    out = R.mlstm_chunked(q, k, v, ig, fg, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_step_equals_sequential():
    key = jax.random.PRNGKey(5)
    B, S, H, D = 1, 12, 2, 4
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H))
    ref = R.mlstm_ref(q, k, v, ig, fg)
    st = {"C": jnp.zeros((B, H, D, D)), "n": jnp.zeros((B, H, D)),
          "m": jnp.full((B, H), -1e30)}
    for t in range(S):
        h, st = R.mlstm_step(st, q[:, t], k[:, t], v[:, t], ig[:, t], fg[:, t])
        np.testing.assert_allclose(np.asarray(h), np.asarray(ref[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_slstm_parallel_equals_steps():
    key = jax.random.PRNGKey(6)
    B, S, H, D = 2, 16, 2, 4
    ks = jax.random.split(key, 4)
    z = jax.random.normal(ks[0], (B, S, H, D))
    i = jax.random.normal(ks[1], (B, S, H, D))
    f = jax.random.normal(ks[2], (B, S, H, D)) + 1.0
    o = jax.random.normal(ks[3], (B, S, H, D))
    par = R.slstm_apply(z, i, f, o)
    st = {"c": jnp.zeros((B, H, D)), "n": jnp.zeros((B, H, D)),
          "m": jnp.full((B, H, D), -1e30)}
    for t in range(S):
        h, st = R.slstm_step(st, z[:, t], i[:, t], f[:, t], o[:, t])
        np.testing.assert_allclose(np.asarray(h), np.asarray(par[:, t]),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("top_k,n_shared", [(1, 0), (2, 0), (2, 1)])
def test_moe_matches_reference(top_k, n_shared):
    cfg = MoEConfig(n_experts=8, top_k=top_k, d_expert=16, n_shared=n_shared,
                    capacity_factor=8.0)   # big capacity: no drops
    key = jax.random.PRNGKey(7)
    p = make_moe(key, 32, cfg, "silu")
    x = jax.random.normal(key, (4, 6, 32), jnp.float32)
    y, aux = moe_apply(p, x, cfg, "silu")
    ref = moe_ref(p, x, cfg, "silu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=0.25)
    key = jax.random.PRNGKey(8)
    p = make_moe(key, 16, cfg, "silu")
    x = jax.random.normal(key, (64, 16), jnp.float32)
    y, _ = moe_apply(p, x, cfg, "silu")
    ref = moe_ref(p, x, cfg, "silu")
    assert not np.allclose(np.asarray(y), np.asarray(ref))  # drops happened
    assert np.isfinite(np.asarray(y)).all()


# ---------------------------------------------------------------------------
# end-to-end: prefill + decode == teacher-forced forward (every arch)
# ---------------------------------------------------------------------------

def _f32(cfg):
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:
        # equivalence needs drop-free routing in the teacher-forced forward
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    return cfg


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_consistency(name):
    """logits(decode token t | prefill of t tokens) == logits from the
    teacher-forced forward at position t, for every architecture."""
    cfg = _f32(smoke_config(name))
    key = jax.random.PRNGKey(9)
    params = init_params(cfg, key)
    B, S = 2, 16
    sh = ShapeSpec("t", S + 1, B, "train")
    specs = input_specs(cfg, sh)
    batch = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32 and k != "positions":
            batch[k] = jax.random.randint(key, v.shape, 0, cfg.vocab_size)
        elif k == "positions":
            batch[k] = jnp.broadcast_to(
                jnp.arange(S + 1, dtype=jnp.int32)[None, None], (3, B, S + 1)).copy()
        else:
            batch[k] = jax.random.normal(key, v.shape, v.dtype)
    # teacher-forced forward over S+1 tokens
    h, _ = forward(params, cfg, batch)
    full_logits = logits_from_hidden(params, cfg, h)      # (B, S+1, V)

    # prefill on the first S tokens
    pf = {k: (v[:, :S] if k != "positions" and k != "frames" else v)
          for k, v in batch.items() if k != "labels"}
    if "positions" in pf:
        pf["positions"] = batch["positions"][:, :, :S]
    # xLSTM: associative-scan reduction order differs between S and S+1
    # lengths; exp/log gate stabilizers amplify fp32 noise across 24 layers.
    tol = 2e-3 if cfg.family == "ssm" else 5e-4
    cache, pf_logits = prefill(params, cfg, pf)
    np.testing.assert_allclose(np.asarray(pf_logits[:, 0]),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=tol, atol=tol)

    # decode token S against a padded cache
    dc = init_decode_cache(cfg, B, S + 4, dtype=jnp.float32)
    # write prefill cache into the padded decode cache
    def write(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        # seq axis differs: copy the S prefix
        ax = next(i for i in range(dst.ndim) if dst.shape[i] != src.shape[i])
        idx = [slice(None)] * dst.ndim
        idx[ax] = slice(0, src.shape[ax])
        return dst.at[tuple(idx)].set(src.astype(dst.dtype))
    dc = jax.tree_util.tree_map(write, dc, cache)
    if cfg.embed_inputs == "embeds":
        tok = batch["embeds"][:, S]
    else:
        tok = batch["tokens"][:, S]
    _, dec_logits = decode_step(params, cfg, dc, tok, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits[:, S]),
                               rtol=tol, atol=tol)
