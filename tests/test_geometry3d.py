"""3-D conformance for the N-D geometry refactor (DESIGN.md §2.7).

Validates the volumetric path end-to-end against scipy:

* 3-D morphological reconstruction (conn6 / conn26) matches an iterative
  ``scipy.ndimage.maximum_filter`` reference **bit-for-bit** on every
  engine — reconstruction's fixed point is exact and order-independent
  for any neighborhood, so engines must also bit-agree with each other.
* 3-D EDT under conn26 (full Moore): engines bit-agree with the frontier
  reference and stay within the Danielsson error bound vs
  ``scipy.ndimage.distance_transform_edt`` (paper Fig. 3's bound, as in
  tests/test_edt.py).  Under conn6 the face-only scan's fixed point is
  *order-dependent* (engines may legitimately differ at isolated pixels,
  each a genuine fixed point), so each engine is bounded individually
  instead of bit-compared.
* `Neighborhood`/`Geometry` unit checks: the 2-D offset tables are
  byte-identical to the historical literals (load-bearing for EDT tie
  resolution), connectivity normalization raises the documented errors,
  and the `prod(T_i + 2)` blocking math + pad/unpad round-trips hold.
* hypothesis round-trips on random 3-D masks (engine equivalence and
  second-pass idempotence), when hypothesis is installed.
"""

import jax.numpy as jnp
import numpy as np
import pytest

ndi = pytest.importorskip("scipy.ndimage")

from repro.core.geometry import (NEIGHBORHOODS, Geometry, _moore_offsets,
                                 connectivity_name, neighborhood)
from repro.edt.ops import EdtOp, distance_map
from repro.ops import run_op
from repro.solve import solve

SHAPE3 = (12, 14, 16)

# (id, engine, solve kwargs): the engine matrix of the acceptance criteria —
# sweep / frontier / tiled / tiled-pallas (dense, in-kernel queue, queued +
# batched drain) / host scheduler.  tile=8 on a 12x14x16 volume exercises
# the N-D pad-to-tiles path (padded to 16x16x16, 8 blocks of 10^3 w/ halo).
ENGINES = [
    ("sweep", "sweep", {}),
    ("frontier", "frontier", {}),
    ("tiled", "tiled", dict(tile=8, queue_capacity=16)),
    ("tiled-pallas", "tiled-pallas", dict(tile=8, queue_capacity=16)),
    ("tiled-pallas-kq", "tiled-pallas",
     dict(tile=8, queue_capacity=16, kernel_queue=True)),
    ("tiled-pallas-kq-batched", "tiled-pallas",
     dict(tile=8, queue_capacity=16, kernel_queue=True, drain_batch=4)),
    ("scheduler", "scheduler", dict(tile=8, n_workers=2)),
]
ENGINE_IDS = [e[0] for e in ENGINES]


def _footprint(conn):
    nb = NEIGHBORHOODS[connectivity_name(conn)]
    foot = np.zeros((3,) * nb.ndim, bool)
    foot[(1,) * nb.ndim] = True
    for off in nb.offsets:
        foot[tuple(o + 1 for o in off)] = True
    return foot


def _reconstruct_ref(marker, mask, conn):
    """Iterative geodesic dilation: the textbook fixed-point definition."""
    foot = _footprint(conn)
    cur = marker.copy()
    while True:
        nxt = np.minimum(ndi.maximum_filter(cur, footprint=foot), mask)
        if np.array_equal(nxt, cur):
            return cur
        cur = nxt


def _morph_case(seed=0, shape=SHAPE3):
    rng = np.random.default_rng(seed)
    mask = rng.integers(0, 200, shape).astype(np.int32)
    marker = np.where(rng.random(shape) < 0.02, mask, 0).astype(np.int32)
    return marker, mask


def _edt_case(seed=1, shape=SHAPE3):
    rng = np.random.default_rng(seed)
    return rng.random(shape) < 0.88


def _assert_edt_close(d2, fg, max_err=0.5, max_frac=0.01):
    """Danielsson bound vs the exact scipy EDT (tests/test_edt.py's
    convention): computed >= exact, max sqrt error <= 0.5 px, <= 1% of
    pixels approximate.  Face-only conn6 omits the diagonal pointer hops,
    so its callers pass a slightly looser bound (measured ~0.504 px max
    on random volumes)."""
    exact = ndi.distance_transform_edt(fg)
    d = np.sqrt(np.asarray(d2).astype(np.float64))
    err = d - exact
    assert (err >= -1e-9).all(), "computed distance below exact minimum"
    assert err.max() <= max_err, f"max error {err.max()}"
    assert (err > 1e-9).mean() <= max_frac, "too many approximate pixels"


# ---------------------------------------------------------------------------
# 3-D morphological reconstruction vs scipy.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("conn", ["conn6", "conn26"])
@pytest.mark.parametrize("eid,engine,kw", ENGINES, ids=ENGINE_IDS)
def test_morph3d_matches_iterative_scipy_reference(conn, eid, engine, kw):
    marker, mask = _morph_case()
    ref = _reconstruct_ref(marker, mask, conn)
    out, stats = run_op("morph", jnp.asarray(marker), jnp.asarray(mask),
                        engine=engine, connectivity=conn, **kw)
    np.testing.assert_array_equal(
        np.asarray(out), ref,
        err_msg=f"3D morph {conn} on {eid} vs iterative scipy reference")


# ---------------------------------------------------------------------------
# 3-D EDT vs scipy.ndimage.distance_transform_edt.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def edt26_ref():
    fg = _edt_case()
    d2, _ = run_op("edt", jnp.asarray(fg), engine="frontier",
                   connectivity="conn26")
    return fg, np.asarray(d2)


@pytest.mark.parametrize("eid,engine,kw", ENGINES, ids=ENGINE_IDS)
def test_edt3d_conn26_engines_bit_agree_and_match_scipy(edt26_ref, eid,
                                                        engine, kw):
    fg, ref_d2 = edt26_ref
    d2, _ = run_op("edt", jnp.asarray(fg), engine=engine,
                   connectivity="conn26", **kw)
    # full Moore connectivity: the distance fixed point is schedule-
    # independent, so every engine must bit-agree with the reference...
    np.testing.assert_array_equal(
        np.asarray(d2), ref_d2,
        err_msg=f"3D EDT conn26 on {eid} vs frontier fixed point")
    # ...and the shared fixed point stays within the Danielsson bound.
    _assert_edt_close(d2, fg)


@pytest.mark.parametrize("eid,engine,kw", ENGINES, ids=ENGINE_IDS)
def test_edt3d_conn6_each_engine_within_danielsson_bound(eid, engine, kw):
    """conn6's face-only scan makes the EDT fixed point order-dependent:
    engines may legitimately disagree at isolated pixels (each output is a
    genuine fixed point — one more dense round improves neither), so each
    engine is held to the error bound individually, not bit-compared."""
    fg = _edt_case()
    d2, _ = run_op("edt", jnp.asarray(fg), engine=engine,
                   connectivity="conn6", **kw)
    _assert_edt_close(d2, fg, max_err=0.75, max_frac=0.02)


def test_edt3d_background_conventions():
    op = EdtOp(connectivity="conn26")
    out, _ = solve(op, op.make_state(jnp.zeros(SHAPE3, bool)),
                   engine="frontier")
    assert np.asarray(distance_map(out)).max() == 0
    out, stats = solve(op, op.make_state(jnp.ones(SHAPE3, bool)),
                       engine="frontier")
    assert int(stats.rounds) == 0
    assert (np.asarray(distance_map(out)) > np.prod(SHAPE3)).all()


# ---------------------------------------------------------------------------
# Neighborhood / Geometry unit checks.
# ---------------------------------------------------------------------------

def test_2d_offset_tables_match_historical_literals():
    """product((-1,0,1), repeat=2) order — byte-identical to the former
    N8_OFFSETS/N4_OFFSETS constants (EDT tie resolution depends on it)."""
    assert NEIGHBORHOODS["conn8"].offsets == (
        (-1, -1), (-1, 0), (-1, 1), (0, -1),
        (0, 1), (1, -1), (1, 0), (1, 1))
    assert NEIGHBORHOODS["conn4"].offsets == (
        (-1, 0), (0, -1), (0, 1), (1, 0))


def test_3d_offset_tables_counts_and_rank():
    for name, n in (("conn6", 6), ("conn18", 18), ("conn26", 26)):
        nb = NEIGHBORHOODS[name]
        assert (nb.ndim, nb.n_offsets) == (3, n)
        assert all(len(o) == 3 and any(o) for o in nb.offsets)
    assert NEIGHBORHOODS["conn26"].offsets == _moore_offsets(3, 3)
    # faces of conn6 are the exactly-one-nonzero-axis subset of conn26
    assert set(NEIGHBORHOODS["conn6"].offsets) <= \
        set(NEIGHBORHOODS["conn26"].offsets)


def test_connectivity_name_normalization_and_errors():
    assert connectivity_name(4) == "conn4"
    assert connectivity_name(8) == "conn8"
    assert connectivity_name("conn18") == "conn18"
    assert neighborhood("conn26").n_offsets == 26
    with pytest.raises(ValueError, match="known neighborhoods"):
        connectivity_name("conn7")
    with pytest.raises(ValueError, match="got 5"):
        connectivity_name(5)
    with pytest.raises(ValueError):
        connectivity_name(True)     # bool is an int; rejected explicitly


def test_geometry_blocking_math():
    g = Geometry.of(3, 8)
    assert g.tile == (8, 8, 8) and g.block == (10, 10, 10)
    assert g.geodesic_bound == 10 * 10 * 10       # prod(T_i + 2), not (T+2)^2
    assert g.grid(SHAPE3) == (2, 2, 2)
    assert g.padded_shape(SHAPE3) == (16, 16, 16)
    with pytest.raises(ValueError, match="ndim"):
        Geometry(ndim=3, tile=(8, 8))


def test_geometry_pad_unpad_round_trip():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(0, 9, (3, 5, 6, 7)).astype(np.int32))
    g = Geometry.of(3, 4)
    padded = g.pad_state({"x": x}, {"x": 0})
    # leading (pointer) axis rides along; trailing axes pad to tiles + halo
    assert padded["x"].shape == (3, 10, 10, 10)
    back = g.unpad_state(padded, (5, 6, 7))
    np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(x))


# ---------------------------------------------------------------------------
# hypothesis round-trips on random 3-D masks.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("conn", ["conn6", "conn26"])
def test_morph3d_random_masks_round_trip(conn):
    pytest.importorskip("hypothesis", reason="install via requirements-dev.txt")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(0, 2**31 - 1), st.integers(4, 10),
           st.integers(4, 10), st.integers(4, 10))
    @settings(max_examples=10, deadline=None)
    def check(seed, d, h, w):
        marker, mask = _morph_case(seed, (d, h, w))
        ref = _reconstruct_ref(marker, mask, conn)
        out, _ = run_op("morph", jnp.asarray(marker), jnp.asarray(mask),
                        engine="frontier", connectivity=conn)
        np.testing.assert_array_equal(np.asarray(out), ref)
        # engine equivalence on the same random volume
        tiled, _ = run_op("morph", jnp.asarray(marker), jnp.asarray(mask),
                          engine="tiled", connectivity=conn, tile=4,
                          queue_capacity=8)
        np.testing.assert_array_equal(np.asarray(tiled), ref)

    check()


def test_edt3d_random_masks_idempotent_and_bounded():
    pytest.importorskip("hypothesis", reason="install via requirements-dev.txt")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(0, 2**31 - 1), st.integers(4, 10),
           st.integers(4, 10), st.integers(4, 10))
    @settings(max_examples=8, deadline=None)
    def check(seed, d, h, w):
        fg = _edt_case(seed, (d, h, w))
        op = EdtOp(connectivity="conn26")
        out, _ = solve(op, op.make_state(jnp.asarray(fg)), engine="frontier")
        _assert_edt_close(distance_map(out), fg)
        # round trip: a second pass from the fixed point is a no-op
        out2, stats2 = solve(op, out, engine="frontier")
        assert int(stats2.rounds) == 0
        np.testing.assert_array_equal(np.asarray(distance_map(out2)),
                                      np.asarray(distance_map(out)))

    check()
