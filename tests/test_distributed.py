"""Multi-device tests.  Each test runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its single-device view (per the dry-run protocol)."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_morph_matches_ref():
    """E3 engine (shard_map + ppermute halo + psum convergence) == FH ref."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import run_sharded
        from repro.data.images import tissue_image
        from repro.morph.ops import MorphReconstructOp
        from repro.morph.ref import reconstruct_fh
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        marker, mask = tissue_image(64, 96, 0.7, seed=0)
        ref = reconstruct_fh(marker, mask, 8)
        op = MorphReconstructOp(connectivity=8)
        state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                              jnp.asarray(mask.astype(np.int32)))
        out, stats = run_sharded(op, state, mesh)
        np.testing.assert_array_equal(np.asarray(out["J"]), ref.astype(np.int32))
        assert int(stats.bp_rounds) >= 1
        assert int(stats.tiles_processed) == 0   # dense TP drain: no tile queue
        print("OK rounds=", int(stats.bp_rounds))
    """)


def test_sharded_edt_matches_ref():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import run_sharded
        from repro.data.images import binary_blobs
        from repro.edt.ops import EdtOp, distance_map
        from repro.edt.ref import edt_wavefront
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        fg = binary_blobs(64, 64, 0.5, seed=1)
        ref_M, _ = edt_wavefront(fg, 8)
        op = EdtOp(connectivity=8)
        out, stats = run_sharded(op, op.make_state(jnp.asarray(fg)), mesh)
        np.testing.assert_array_equal(np.asarray(distance_map(out)), ref_M)
        assert int(stats.bp_rounds) >= 1
        print("OK")
    """)


def test_composed_shard_map_tiled_matches_ref_across_meshes():
    """The paper's full two-level hierarchy: per-shard active-tile queues
    (E2) inside the mesh TP/BP pipeline (E3).  Bit-exact with the FH
    reference (morph) / distance-exact (EDT) on 1x1, 2x2 and 1x8 meshes,
    with the BP rounds re-seeding only halo-improved tiles."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import run_sharded
        from repro.data.images import binary_blobs, tissue_image, seeded_marker
        from repro.edt.ops import EdtOp, distance_map
        from repro.edt.ref import edt_wavefront
        from repro.morph.ops import MorphReconstructOp
        from repro.morph.ref import reconstruct_fh
        marker, mask = tissue_image(48, 64, 0.7, seed=0)
        marker = seeded_marker(mask, n_seeds=4, seed=0)
        ref = reconstruct_fh(marker.copy(), mask, 8).astype(np.int32)
        mop = MorphReconstructOp(connectivity=8)
        mstate = mop.make_state(jnp.asarray(marker.astype(np.int32)),
                                jnp.asarray(mask.astype(np.int32)))
        fg = binary_blobs(48, 64, 0.5, seed=1)
        ref_M, _ = edt_wavefront(fg, 8)
        eop = EdtOp(connectivity=8)
        estate = eop.make_state(jnp.asarray(fg))
        for shape in ((1, 1), (2, 2), (1, 8)):
            mesh = jax.make_mesh(shape, ("data", "model"))
            out, st = run_sharded(mop, mstate, mesh, tile=16,
                                  queue_capacity=8, drain_batch=2)
            np.testing.assert_array_equal(np.asarray(out["J"]), ref)
            assert int(st.tiles_processed) > 0
            assert np.asarray(st.per_device_tiles).shape == shape
            out, st = run_sharded(eop, estate, mesh, tile=16, queue_capacity=8)
            np.testing.assert_array_equal(np.asarray(distance_map(out)), ref_M)
            print("OK", shape, int(st.bp_rounds), int(st.tiles_processed))
    """)


def test_composed_engine_pallas_backed_drain():
    """run_sharded's TP drain accepts the Pallas kernel solvers (with the
    threaded (T+2)^2 bound) — the VMEM drain inside the mesh pipeline."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import run_sharded
        from repro.data.images import tissue_image, seeded_marker
        from repro.kernels.ops import tile_solver_morph, tile_solver_morph_batched
        from repro.morph.ops import MorphReconstructOp
        from repro.morph.ref import reconstruct_fh
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        marker, mask = tissue_image(48, 48, 0.7, seed=2)
        marker = seeded_marker(mask, n_seeds=4, seed=2)
        ref = reconstruct_fh(marker.copy(), mask, 8).astype(np.int32)
        op = MorphReconstructOp(connectivity=8)
        state = op.make_state(jnp.asarray(marker.astype(np.int32)),
                              jnp.asarray(mask.astype(np.int32)))
        out, st = run_sharded(
            op, state, mesh, tile=16, queue_capacity=8, drain_batch=2,
            tile_solver=tile_solver_morph(8, True, 18 ** 2),
            batched_tile_solver=tile_solver_morph_batched(8, True, 18 ** 2))
        np.testing.assert_array_equal(np.asarray(out["J"]), ref)
        print("OK tiles=", int(st.tiles_processed))
    """, devices=4)


def test_composed_engine_solve_nondivisible_and_masked():
    """solve(engine="shard_map-tiled") end-to-end: a grid no mesh divides
    (exercising _pad_to_multiple) under a non-rectangular valid mask, on 8
    devices — full-array comparable with the E1 reference (the invalid-
    pixel contract)."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.frontier import run_dense
        from repro.data.images import bg_disks
        from repro.edt.ops import EdtOp, distance_map
        from repro.solve import solve
        H, W = 37, 51
        yy, xx = np.mgrid[:H, :W]
        valid = ((yy - H / 2) ** 2 + (xx - W / 2) ** 2) < (0.45 * max(H, W)) ** 2
        fg = bg_disks(H, W, coverage=0.9, n_disks=2, seed=4)
        op = EdtOp(connectivity=8)
        state = op.make_state(jnp.asarray(fg), jnp.asarray(valid))
        ref_out, _ = run_dense(op, state, "frontier")
        out, stats = solve(op, state, engine="shard_map-tiled", tile=16,
                           queue_capacity=8)
        assert stats.engine == "shard_map-tiled" and stats.n_devices == 8
        assert stats.tiles_processed > 0
        np.testing.assert_array_equal(np.asarray(distance_map(out)),
                                      np.asarray(distance_map(ref_out)))
        # invalid cells hold their input values (contract)
        np.testing.assert_array_equal(np.asarray(out["vr"])[:, ~valid],
                                      np.asarray(state["vr"])[:, ~valid])
        print("OK")
    """)


def test_invalid_band_at_shard_border_cannot_source():
    """Regression: the BP halo round used to seed the WHOLE exchanged ring
    as frontier — a poisoned invalid band sitting exactly on a shard
    boundary was handed to the neighbor device's halo, marked as a source,
    and corrupted its valid region.  The seed is now masked by valid."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import run_sharded
        from repro.core.frontier import run_dense
        from repro.morph.ops import MorphReconstructOp
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        H, W = 16, 32
        valid = np.ones((H, W), bool)
        valid[:, 15:17] = False          # invalid band straddling the border
        mask = np.where(valid, 100, 255).astype(np.int32)
        marker = np.zeros((H, W), np.int32)
        marker[0, 0] = 50
        marker = np.where(valid, marker, 255)   # poisoned to the max
        op = MorphReconstructOp(connectivity=8)
        state = op.make_state(jnp.asarray(marker), jnp.asarray(mask),
                              jnp.asarray(valid))
        ref, _ = run_dense(op, state, "frontier")
        for kw in ({}, dict(tile=8, queue_capacity=8)):
            out, _ = run_sharded(op, state, mesh, **kw)
            np.testing.assert_array_equal(np.asarray(out["J"]),
                                          np.asarray(ref["J"]))
        print("OK")
    """, devices=2)


def test_per_device_tile_counters_psum_to_stats():
    """Hypothesis property: the per-device drain counters (out_spec sharded
    over the mesh) always sum to the psum'd tiles_processed total in the
    stats record, and the composed output matches the E1 reference."""
    pytest.importorskip("hypothesis", reason="install via requirements-dev.txt")
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from hypothesis import given, settings, strategies as st
        from repro.core.distributed import run_sharded
        from repro.core.frontier import run_dense
        from repro.morph.ops import MorphReconstructOp
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        op = MorphReconstructOp(connectivity=8)
        @given(st.integers(0, 2**31 - 1))
        @settings(max_examples=5, deadline=None)
        def prop(seed):
            rng = np.random.default_rng(seed)
            mask = rng.integers(0, 256, (32, 32)).astype(np.int32)
            marker = np.minimum(
                rng.integers(0, 256, (32, 32)).astype(np.int32), mask)
            state = op.make_state(jnp.asarray(marker), jnp.asarray(mask))
            out, stc = run_sharded(op, state, mesh, tile=8, queue_capacity=8)
            per_dev = np.asarray(stc.per_device_tiles)
            assert per_dev.shape == (2, 4)
            assert int(per_dev.sum()) == int(stc.tiles_processed)
            ref, _ = run_dense(op, state, "frontier")
            np.testing.assert_array_equal(np.asarray(out["J"]),
                                          np.asarray(ref["J"]))
        prop()
        print("OK")
    """)


def test_pjit_train_step_matches_single_device():
    """The production sharded train step computes the same update as the
    single-device step (2x2 mesh, fp32, drop-free MoE island)."""
    run_sub("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ShapeSpec
        from repro.configs.registry import smoke_config
        from repro.data.pipeline import batch_for_step
        from repro.distributed import sharding as shd
        from repro.distributed.context import ParallelCtx, parallel_ctx
        from repro.models.transformer import init_params
        from repro.train.optim import OptConfig, init_opt_state
        from repro.train.step import make_train_step
        cfg = dataclasses.replace(smoke_config("deepseek-v2-lite-16b"),
                                  dtype="float32")
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, n_experts=4,
                                         capacity_factor=64.0))
        shape = ShapeSpec("t", 16, 4, "train")
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        batch = {k: jnp.asarray(v) for k, v in
                 batch_for_step(cfg, shape, 0).items()}
        # single device
        p1, o1, m1 = jax.jit(make_train_step(cfg, OptConfig()))(params, opt, batch)
        # sharded
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        pspec = shd.named(mesh, shd.param_specs(cfg, params, mesh))
        oshard = {"m": pspec, "v": pspec,
                  "step": shd.named(mesh, jax.sharding.PartitionSpec())}
        bshard = shd.named(mesh, shd.batch_specs(cfg, batch, mesh))
        with parallel_ctx(ParallelCtx(mesh, ("data",))), mesh:
            fn = jax.jit(make_train_step(cfg, OptConfig()),
                         in_shardings=(pspec, oshard, bshard))
            p2, o2, m2 = fn(params, opt, batch)
        # cross-shard reduction order and the MoE island's pmean'd aux give
        # ~1e-4 relative fp32 noise
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-3)
        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
        assert max(jax.tree_util.tree_leaves(d)) < 1e-3, sorted(
            jax.tree_util.tree_leaves(d))[-3:]
        print("OK loss=", float(m2["loss"]))
    """)


def test_compressed_dp_psum_close_to_exact():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from repro.distributed.compression import compressed_psum, init_error_feedback
        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 33), jnp.float32)
        ef = jnp.zeros((8, 64, 33), jnp.float32)
        def f(gl, efl):
            out, ef2 = compressed_psum(gl, efl, "data")
            return out, ef2
        from repro.core.distributed import shard_map_compat
        fn = jax.jit(shard_map_compat(f, mesh,
            (jax.sharding.PartitionSpec("data"),) * 2,
            (jax.sharding.PartitionSpec("data"),) * 2))
        out, ef2 = fn(g, ef)
        exact = jnp.mean(g, axis=0, keepdims=True)
        rel = float(jnp.max(jnp.abs(out[0] - exact[0]))) / float(jnp.max(jnp.abs(exact)))
        assert rel < 0.2, rel          # single round: one int8 bucket of noise
        # the real claim: error feedback makes the scheme unbiased over time —
        # the running mean of repeated reductions converges to the exact mean
        # at rate ~1/T (the residual ef_T is bounded, and the telescoped sum
        # of outputs equals T*exact + O(ef_T)).
        def run_mean_err(T):
            acc = jnp.zeros_like(out)
            efr = ef
            for _ in range(T):
                o, efr = fn(g, efr)
                acc = acc + o
            return float(jnp.max(jnp.abs(acc[0] / T - exact[0]))) \
                / float(jnp.max(jnp.abs(exact)))
        e4, e64 = run_mean_err(4), run_mean_err(64)
        assert e64 < e4 / 4, (e4, e64)      # ~1/T decay
        assert e64 < 0.02, e64
        print("OK rel=", rel, "e4=", e4, "e64=", e64)
    """)


def test_elastic_reshard_across_mesh_sizes():
    """Save under a 4x2 mesh, restore under 2x2 and 8x1 — elastic restart."""
    run_sub("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.ckpt.checkpoint import save
        from repro.ckpt.elastic import restore_elastic
        from repro.configs.registry import smoke_config
        from repro.distributed import sharding as shd
        from repro.models.transformer import init_params
        cfg = smoke_config("gemma2-27b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        p1 = shd.reshard_tree = jax.device_put(
            params, jax.tree_util.tree_map(
                lambda s: jax.sharding.NamedSharding(mesh1, s),
                shd.param_specs(cfg, params, mesh1)))
        with tempfile.TemporaryDirectory() as d:
            save(d, 7, p1)
            for shape_ in ((2, 2), (8, 1)):
                mesh2 = jax.make_mesh(shape_, ("data", "model"))
                specs2 = shd.param_specs(cfg, params, mesh2)
                step, p2, _ = restore_elastic(d, params, mesh2, specs2)
                assert step == 7
                chk = jax.tree_util.tree_map(
                    lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)),
                    params, p2)
                assert all(jax.tree_util.tree_leaves(chk))
        print("OK")
    """)


def test_mini_dryrun_lower_compile():
    """The dry-run pipeline end-to-end on a small mesh: every step kind."""
    run_sub("""
        import jax
        from repro.launch import dryrun
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        from repro.distributed.context import parallel_ctx
        for arch, shape in [("gemma2-27b", "train_4k"),
                            ("deepseek-v2-lite-16b", "prefill_32k"),
                            ("recurrentgemma-2b", "long_500k")]:
            cfg, ctx, fn, args, in_sh, out_sh, donate = dryrun.build_cell(
                arch, shape, mesh)
            with parallel_ctx(ctx), mesh:
                c = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                            donate_argnums=donate).lower(*args).compile()
            ca = c.cost_analysis()
            if isinstance(ca, (list, tuple)):   # older jax: list of dicts
                ca = ca[0]
            assert ca.get("flops", 0) > 0
            print("OK", arch, shape)
    """, devices=4, timeout=560)
