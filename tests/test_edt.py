"""Euclidean distance transform: engines vs the paper's Algorithm 3 reference
and the exact brute force (Danielsson 8-neighborhood is near-exact; paper
Fig. 3 bounds the rare approximation error)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.frontier import run_dense
from repro.core.tiles import run_tiled
from repro.data.images import binary_blobs
from repro.edt.ops import EdtOp, distance_map
from repro.edt.ref import edt_bruteforce, edt_wavefront
from repro.kernels.ops import tile_solver_edt


def _assert_edt_close(d2, exact2):
    """Danielsson bound: sqrt distances may deviate by a small fraction of a
    pixel in rare configurations (paper Fig. 3: sqrt(170) vs sqrt(169))."""
    d = np.sqrt(d2.astype(np.float64))
    e = np.sqrt(exact2.astype(np.float64))
    assert (d >= e - 1e-9).all(), "computed distance below exact minimum"
    err = d - e
    assert err.max() <= 0.5, f"max error {err.max()}"
    assert (err > 1e-9).mean() <= 0.01, "too many approximate pixels"


@pytest.mark.parametrize("conn", [8])
@pytest.mark.parametrize("coverage", [0.3, 0.6, 0.9])
def test_ref_wavefront_vs_bruteforce(conn, coverage):
    fg = binary_blobs(40, 40, coverage, seed=0)
    M, _ = edt_wavefront(fg, conn)
    exact = edt_bruteforce(fg)
    _assert_edt_close(M, exact)


@pytest.mark.parametrize("engine", ["frontier", "sweep"])
def test_dense_engine_matches_ref(engine):
    fg = binary_blobs(48, 48, 0.55, seed=1)
    ref_M, _ = edt_wavefront(fg, 8)
    op = EdtOp(connectivity=8)
    state = op.make_state(jnp.asarray(fg))
    out, _ = run_dense(op, state, engine)
    M = np.asarray(distance_map(out))
    np.testing.assert_array_equal(M, ref_M)


@pytest.mark.parametrize("tile,cap", [(16, 64), (32, 8)])
def test_tiled_engine_matches_ref(tile, cap):
    fg = binary_blobs(64, 64, 0.5, seed=2)
    ref_M, _ = edt_wavefront(fg, 8)
    op = EdtOp(connectivity=8)
    state = op.make_state(jnp.asarray(fg))
    out, stats = run_tiled(op, state, tile=tile, queue_capacity=cap)
    M = np.asarray(distance_map(out))
    np.testing.assert_array_equal(M, ref_M)


def test_tiled_with_pallas_solver():
    fg = binary_blobs(64, 64, 0.5, seed=3)
    ref_M, _ = edt_wavefront(fg, 8)
    op = EdtOp(connectivity=8)
    state = op.make_state(jnp.asarray(fg))
    out, _ = run_tiled(op, state, tile=32, queue_capacity=32,
                       tile_solver=tile_solver_edt(8, interpret=True))
    M = np.asarray(distance_map(out))
    np.testing.assert_array_equal(M, ref_M)


def test_no_background_and_all_background():
    op = EdtOp(connectivity=8)
    # all background -> all distances zero
    state = op.make_state(jnp.zeros((16, 16), bool))
    out, _ = run_dense(op, state, "frontier")
    assert np.asarray(distance_map(out)).max() == 0
    # all foreground -> sentinel distances everywhere (no propagation source)
    state = op.make_state(jnp.ones((16, 16), bool))
    out, stats = run_dense(op, state, "frontier")
    assert int(stats.rounds) == 0
    assert (np.asarray(distance_map(out)) > 16 * 16).all()
